"""Kernel microbenchmarks: Pallas (interpret-validated) vs jnp reference.

This container has no TPU, so Pallas wall-times are meaningless (interpret
mode runs the kernel body in Python).  What IS measurable and meaningful:

- numerics: max |kernel − oracle| over production-like shapes (also covered
  by tests; repeated here so the bench output records it),
- the jnp reference wall time on CPU (tracks regressions in the ref paths
  the training stack actually runs here),
- the kernels' VMEM working set per BlockSpec tile vs the 16 MiB budget —
  a static check that the chosen block shapes are TPU-valid.

Output CSV: ``kernel,<name>,<shape>,<ref_ms>,<max_err>,<vmem_kib>``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _timeit(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e3


def bench_flash() -> list:
    from repro.kernels.flash_attention.flash import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    rows = []
    key = jax.random.key(0)
    for (B, S, H, K, D, bq, bk) in [(1, 512, 8, 2, 64, 128, 128),
                                    (2, 1024, 4, 4, 128, 128, 256)]:
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D),
                              jnp.float32)
        ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        t_ref = _timeit(ref, q, k, v)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        err = float(jnp.abs(out - ref(q, k, v)).max())
        # VMEM tile: q (bq, G·D) + kv rows (S, D)×2 + acc (bq·G, D), f32
        G = H // K
        vmem = (bq * G * D + 2 * S * D + bq * G * D * 2) * 4 / 1024
        rows.append(("flash_attention", f"B{B}S{S}H{H}K{K}D{D}",
                     t_ref, err, vmem))
    return rows


def bench_xent() -> list:
    from repro.kernels.xent.ref import xent_ref
    from repro.kernels.xent.xent import xent_fwd
    rows = []
    key = jax.random.key(1)
    for (T, E, V, bt, bv) in [(512, 256, 8192, 128, 512),
                              (256, 512, 32768, 128, 1024)]:
        h = jax.random.normal(key, (T, E), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (E, V),
                              jnp.float32) * 0.05
        lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)
        ref = jax.jit(lambda h, w, l: xent_ref(h, w, l)[0])
        t_ref = _timeit(ref, h, w, lab)
        nll, _ = xent_fwd(h, w, lab, block_t=bt, block_v=bv, interpret=True)
        err = float(jnp.abs(nll - ref(h, w, lab)).max())
        vmem = (bt * E + E * bv + bt * bv) * 4 / 1024
        rows.append(("xent", f"T{T}E{E}V{V}", t_ref, err, vmem))
    return rows


def bench_ssd() -> list:
    from repro.kernels.ssd.ref import ssd_ref
    from repro.kernels.ssd.ssd import ssd_scan_pallas
    rows = []
    key = jax.random.key(2)
    for (B, S, H, P, N, C) in [(1, 512, 4, 64, 64, 128),
                               (2, 256, 8, 32, 16, 64)]:
        x = jax.random.normal(key, (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(
            jax.random.fold_in(key, 1), (B, S, H)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,))
                     * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N)) * .3
        Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, 1, N)) * .3
        ref = jax.jit(lambda *a: ssd_ref(*a)[0])
        t_ref = _timeit(ref, x, dt, A, Bm, Cm)
        y, _ = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=C, interpret=True)
        err = float(jnp.abs(y - ref(x, dt, A, Bm, Cm)).max())
        vmem = (C * P + 2 * C * N + C * C + P * N) * 4 / 1024
        rows.append(("ssd", f"B{B}S{S}H{H}P{P}N{N}", t_ref, err, vmem))
    return rows


def bench_quant() -> list:
    from repro.kernels.quant.quant import dequantize, quantize
    from repro.kernels.quant.ref import quant_ref
    rows = []
    x = jax.random.normal(jax.random.key(3), (1 << 16,), jnp.float32) * 3
    ref = jax.jit(lambda x: quant_ref(x, block=256)[0])
    t_ref = _timeit(ref, x)
    q, s = quantize(x, block=256, interpret=True)
    err = int(jnp.abs(q.astype(jnp.int32)
                      - ref(x).astype(jnp.int32)).max())
    xd = dequantize(q, s, block=256, interpret=True)
    rt = float(jnp.abs(xd - x).max() / jnp.abs(x).max())
    rows.append(("quant", "T65536", t_ref, float(err), 256 * 4 / 1024))
    rows.append(("quant-roundtrip", "T65536", t_ref, rt, 256 * 4 / 1024))
    return rows


def main(csv=True) -> list:
    rows = bench_flash() + bench_xent() + bench_ssd() + bench_quant()
    if csv:
        print("kernel,shape,ref_ms,max_err,vmem_kib")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.2f},{r[3]:.3e},{r[4]:.0f}")
        assert all(r[3] < 1e-2 for r in rows), "kernel numerics regression"
        assert all(r[4] < 16 * 1024 for r in rows), "VMEM budget exceeded"
        print("# all kernels allclose vs oracle; all tiles within 16 MiB VMEM")
    return rows


if __name__ == "__main__":
    main()

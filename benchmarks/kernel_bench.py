"""Kernel microbenchmarks: Pallas (interpret-validated) vs jnp reference.

This container has no TPU, so Pallas wall-times are meaningless (interpret
mode runs the kernel body in Python).  What IS measurable and meaningful:

- numerics: max |kernel − oracle| over production-like shapes (also covered
  by tests; repeated here so the bench output records it),
- the jnp reference wall time on CPU (tracks regressions in the ref paths
  the training stack actually runs here),
- the kernels' VMEM working set per BlockSpec tile vs the 16 MiB budget —
  a static check that the chosen block shapes are TPU-valid,
- **analytic roofline speedups** (:func:`roofline`): fused vs reference
  step time per :class:`~repro.core.cost_model.Hardware` entry, computed
  the repo's meta-driven way — t = max(FLOPs/(peak·eff), HBM-bytes/bw) —
  with HBM traffic counted from the actual kernel dataflow (the ref paths
  materialise the (S, S) score / (T, V) logits tensors; the fused paths
  stream tiles, with re-read factors set by the *autotuned* block sizes).
  These are deterministic, so bench_ci gates per-kernel floors on them.

Output CSV: ``kernel,<name>,<shape>,<ref_ms>,<max_err>,<vmem_kib>``.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp


def _timeit(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e3


def bench_flash() -> list:
    from repro.kernels.flash_attention.flash import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    rows = []
    key = jax.random.key(0)
    for (B, S, H, K, D, bq, bk) in [(1, 512, 8, 2, 64, 128, 128),
                                    (2, 1024, 4, 4, 128, 128, 256)]:
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D),
                              jnp.float32)
        ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        t_ref = _timeit(ref, q, k, v)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        err = float(jnp.abs(out - ref(q, k, v)).max())
        # VMEM tile: q (bq, G·D) + kv rows (S, D)×2 + acc (bq·G, D), f32
        G = H // K
        vmem = (bq * G * D + 2 * S * D + bq * G * D * 2) * 4 / 1024
        rows.append(("flash_attention", f"B{B}S{S}H{H}K{K}D{D}",
                     t_ref, err, vmem))
    return rows


def bench_xent() -> list:
    from repro.kernels.xent.ref import xent_ref
    from repro.kernels.xent.xent import xent_fwd
    rows = []
    key = jax.random.key(1)
    for (T, E, V, bt, bv) in [(512, 256, 8192, 128, 512),
                              (256, 512, 32768, 128, 1024)]:
        h = jax.random.normal(key, (T, E), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (E, V),
                              jnp.float32) * 0.05
        lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)
        ref = jax.jit(lambda h, w, l: xent_ref(h, w, l)[0])
        t_ref = _timeit(ref, h, w, lab)
        nll, _ = xent_fwd(h, w, lab, block_t=bt, block_v=bv, interpret=True)
        err = float(jnp.abs(nll - ref(h, w, lab)).max())
        vmem = (bt * E + E * bv + bt * bv) * 4 / 1024
        rows.append(("xent", f"T{T}E{E}V{V}", t_ref, err, vmem))
    return rows


def bench_ssd() -> list:
    from repro.kernels.ssd.ref import ssd_ref
    from repro.kernels.ssd.ssd import ssd_scan_pallas
    rows = []
    key = jax.random.key(2)
    for (B, S, H, P, N, C) in [(1, 512, 4, 64, 64, 128),
                               (2, 256, 8, 32, 16, 64)]:
        x = jax.random.normal(key, (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(
            jax.random.fold_in(key, 1), (B, S, H)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,))
                     * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N)) * .3
        Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, 1, N)) * .3
        ref = jax.jit(lambda *a: ssd_ref(*a)[0])
        t_ref = _timeit(ref, x, dt, A, Bm, Cm)
        y, _ = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=C, interpret=True)
        err = float(jnp.abs(y - ref(x, dt, A, Bm, Cm)).max())
        vmem = (C * P + 2 * C * N + C * C + P * N) * 4 / 1024
        rows.append(("ssd", f"B{B}S{S}H{H}P{P}N{N}", t_ref, err, vmem))
    return rows


def bench_quant() -> list:
    from repro.kernels.quant.quant import dequantize, quantize
    from repro.kernels.quant.ref import quant_ref
    rows = []
    x = jax.random.normal(jax.random.key(3), (1 << 16,), jnp.float32) * 3
    ref = jax.jit(lambda x: quant_ref(x, block=256)[0])
    t_ref = _timeit(ref, x)
    q, s = quantize(x, block=256, interpret=True)
    err = int(jnp.abs(q.astype(jnp.int32)
                      - ref(x).astype(jnp.int32)).max())
    xd = dequantize(q, s, block=256, interpret=True)
    rt = float(jnp.abs(xd - x).max() / jnp.abs(x).max())
    rows.append(("quant", "T65536", t_ref, float(err), 256 * 4 / 1024))
    rows.append(("quant-roundtrip", "T65536", t_ref, rt, 256 * 4 / 1024))
    return rows


# ---------------------------------------------------------------------------
# analytic roofline: fused vs ref per Hardware entry (deterministic, CI-gated)
# ---------------------------------------------------------------------------

def _rt(hw, flops: float, bytes_: float) -> float:
    """Roofline step time: compute-bound or bandwidth-bound, whichever wins."""
    return max(flops / (hw.peak_flops * hw.mxu_eff), bytes_ / hw.hbm_bw)


def roofline(*, batch=8, seq=2048, heads=16, kv_heads=16, head_dim=128,
             d_model=2048, vocab=32768, ssd_heads=32, ssd_p=64,
             ssd_n=128) -> dict:
    """Per-``Hardware`` fused-vs-ref training-step speedups (fwd+bwd).

    HBM traffic model (f32 intermediates, bf16 streams):

    - *flash ref*: materialises the causal (S, S)/2 score matrix per head —
      3 passes forward (write s, softmax, read p) and 5 backward.
      *flash fused*: streams q/o once; k/v re-read once per q-block program
      (whole-row BlockSpec), q/do re-read once per kv-block program in the
      dk/dv kernel — so the autotuned block size sets the re-read factor.
    - *xent ref*: materialises (T, V) logits f32, 2 passes fwd + 2 bwd.
      *xent fused*: head tiles re-read T/block_t times fwd, once bwd (+dW
      write); logits recomputed (extra FLOPs) but never stored.
    - *ssd ref*: the quadratic masked-attention expansion (S, S)/2 per
      head, 3 passes.  *fused*: chunked scan, intra-chunk (C, C) lives in
      VMEM; HBM sees only the io streams and the (H, P, N) states per
      chunk boundary.

    Returns {kernel: {hw_name: speedup}} plus autotuned tiles per part.
    """
    from repro.core.cost_model import P100_16G, T4_16G, TPU_V5E, V100_PAPER
    from repro.kernels.autotune import autotune

    B, S, H, K, D = batch, seq, heads, kv_heads, head_dim
    E, V = d_model, vocab
    T = B * S
    out: dict = {"flash": {}, "xent": {}, "ssd": {}, "tiles": {}}
    for hw in (TPU_V5E, V100_PAPER, P100_16G, T4_16G):
        tiles = autotune(hw, head_dim=D, group=H // K, d_model=E,
                         vocab=V, seq=S)
        out["tiles"][hw.name] = dataclasses.asdict(tiles)
        nq, nk = S // tiles.block_q, S // tiles.block_k

        # ---- flash attention, training step (bwd ≈ 2.5× fwd FLOPs) ----
        fl_flops = 3.5 * (4 * B * H * S * S * D) / 2          # causal half
        io = 2 * B * H * S * D                                 # one bf16 stream
        scores = 4 * B * H * S * S / 2                         # one f32 pass
        fused = (2 * io + 2 * io * nq            # fwd: q,o + kv×nq
                 + 3 * io + 2 * io * nq          # bwd dq: q,do,dq + kv×nq
                 + 2 * io * nk + 2 * io)         # bwd dkv: q,do×nk + dk,dv
        ref = 10 * io + 8 * scores               # streams + 3 fwd/5 bwd passes
        out["flash"][hw.name] = _rt(hw, fl_flops, ref) / _rt(hw, fl_flops,
                                                             fused)

        # ---- fused xent, training step.  Both paths recompute logits in
        # the backward (the jnp ref is @jax.checkpoint-ed), so FLOPs are
        # equal — the fused win is pure HBM traffic/footprint.
        x_flops = 8 * T * E * V                  # fwd 2TEV + bwd recompute+grads
        w_pass = 4 * E * V                       # one f32 head pass
        h_pass = 4 * T * E
        logits = 4 * T * V
        x_fused = (3 * h_pass + w_pass * (T // tiles.xent_block_t)
                   + 3 * w_pass)                 # W fwd re-reads + bwd rd/wr
        x_ref = 3 * h_pass + 3 * w_pass + 4 * logits
        out["xent"][hw.name] = _rt(hw, x_flops, x_ref) / _rt(
            hw, x_flops, x_fused)

        # ---- SSD chunked scan vs quadratic expansion ----
        Hs, P, N, C = ssd_heads, ssd_p, ssd_n, tiles.ssd_chunk
        s_flops_ref = 3 * 2 * B * Hs * S * S * (P + N) / 2
        s_flops_fused = 3 * 2 * B * Hs * S * (C * (P + N) / 2
                                              + 2 * N * P)
        s_io = 4 * B * S * Hs * (P + N)
        s_states = 4 * B * Hs * P * N * (S // C)
        s_fused = 3 * (2 * s_io + s_states)
        s_ref = 3 * (2 * s_io + 3 * 4 * B * Hs * S * S / 2)
        out["ssd"][hw.name] = _rt(hw, s_flops_ref, s_ref) / _rt(
            hw, s_flops_fused, s_fused)

        # HBM traffic ratio (recorded, not gated: tiny tiles on small-VMEM
        # parts genuinely re-read more than the ref's streaming passes —
        # the roofline time above already prices that in)
        out.setdefault("flash_traffic", {})[hw.name] = ref / fused
        out.setdefault("xent_traffic", {})[hw.name] = x_ref / x_fused
        out.setdefault("ssd_traffic", {})[hw.name] = s_ref / s_fused
        # xent live-footprint reduction — the fused loss head's real win
        # on compute-bound parts: the chunked jnp ref keeps a (chunk, V)
        # f32 logits block alive; the kernel keeps three VMEM tiles.
        chunk = 512                              # LMCfg.loss_chunk default
        bt, bv = tiles.xent_block_t, tiles.xent_block_v
        out.setdefault("xent_footprint", {})[hw.name] = (
            (chunk * V) / (bt * bv + bt * E + E * bv))
    for kern in ("flash", "xent", "ssd"):
        out[f"{kern}_speedup_min"] = min(out[kern].values())
        out[f"{kern}_speedup_max"] = max(out[kern].values())
    out["flash_speedup_tpu"] = out["flash"]["tpu_v5e"]
    out["ssd_speedup_tpu"] = out["ssd"]["tpu_v5e"]
    out["xent_footprint_min"] = min(out["xent_footprint"].values())
    return out


def main(csv=True) -> list:
    rows = bench_flash() + bench_xent() + bench_ssd() + bench_quant()
    if csv:
        print("kernel,shape,ref_ms,max_err,vmem_kib")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.2f},{r[3]:.3e},{r[4]:.0f}")
        assert all(r[3] < 1e-2 for r in rows), "kernel numerics regression"
        assert all(r[4] < 16 * 1024 for r in rows), "VMEM budget exceeded"
        print("# all kernels allclose vs oracle; all tiles within 16 MiB VMEM")
        rl = roofline()
        print("kernel,hw,roofline_speedup")
        for kern in ("flash", "xent", "ssd"):
            for hw_name, s in rl[kern].items():
                print(f"{kern},{hw_name},{s:.2f}")
    return rows


if __name__ == "__main__":
    main()

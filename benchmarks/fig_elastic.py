"""Self-healing elastic runtime — straggler → evict → rebalance → resume.

Whale's third pillar (§5, "resource adaptability"): when a host degrades
(failing HBM, thermal throttle, noisy neighbour), a *naive* synchronous
job is dragged down to the straggler's pace forever; the self-healing
controller (DESIGN.md §7) detects the sustained outlier, evicts the host,
re-plans on the surviving hardware mix with the heterogeneity-aware
search, and resumes from the committed checkpoint.

This benchmark plays both arms on the deterministic simulated multi-host
clock (:mod:`repro.runtime.faults`) with step times from the analytic
cost model — the same detection/eviction machinery the live
:class:`~repro.launch.train.TrainController` runs, minus the jax
execution, so it is CI-gateable:

- **naive**: the straggler stays; every step costs the slowest host.
- **self-healing**: :class:`HostStragglerAggregator` flags the host,
  eviction pays an explicit downtime (checkpoint restore + re-compile),
  and post-heal steps run at the *rebalanced* plan's pace.

Headline metrics (recorded in BENCH_PR5.json by benchmarks/bench_ci.py):

- ``selfheal_vs_naive``: end-to-end throughput ratio (> 1 required);
- ``recovery_ratio``: predicted step time of the rebalanced plan /
  achieved post-heal mean — the run recovers to within the cost model's
  prediction (≈ 1.0, jitter-bounded).

Scenarios cover a homogeneous pool (evict → smaller same-hardware mesh)
and a mixed V100/T4 pool where a V100 host degrades, so the survivors are
a *heterogeneous* mix and the re-plan exercises the balanced placement.

Output: CSV rows ``fig_elastic,<scenario>,<arm>,...``.
"""
from __future__ import annotations

import dataclasses
import statistics

from repro.core.cost_model import T4_16G, V100_PAPER
from repro.models.lm import model_graph
from repro.runtime.elastic import HostTopology, SimHost, search_cluster
from repro.runtime.faults import FaultInjector, SimClock, SlowHost
from repro.runtime.straggler import HostStragglerAggregator

from benchmarks.fig7_heterogeneous import bert_large_cfg

# downtime paid at eviction: restore params+optimizer from the checkpoint
# store and re-jit — charged on the simulated clock so the self-healing arm
# does not get its recovery for free
DISK_BW = 1.0e9               # checkpoint-store read bandwidth, B/s
RECOMPILE_S = 60.0            # re-jit on the re-planned mesh
N_STEPS = 2000
SLOW_AT = 200                 # the host degrades at this step


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    topology: HostTopology
    slow: SlowHost
    per_device_batch: int = 24
    seq: int = 128


SCENARIOS = (
    # homogeneous pool: evict → smaller same-hardware cluster
    Scenario("4hostx4xV100", HostTopology.uniform(4, 4, V100_PAPER),
             SlowHost(host=3, start_step=SLOW_AT, factor=3.0)),
    # mixed pool: a V100 host degrades → survivors are 8×V100 + 8×T4 and
    # the re-plan runs the heterogeneity-aware balanced placement
    Scenario("2x8xV100+8xT4",
             HostTopology(hosts=(SimHost(0, V100_PAPER, 8),
                                 SimHost(1, V100_PAPER, 8),
                                 SimHost(2, T4_16G, 8))),
             SlowHost(host=1, start_step=SLOW_AT, factor=4.0)),
)

# live re-plans stay in the checkpoint's non-pipelined parameter layout
# (same constraint the TrainController applies)
SEARCH_KW = {"max_pp": 1}


def _plan_step_time(meta, spec) -> float:
    return float(search_cluster(meta, spec, overlap=0.5,
                                search_kw=SEARCH_KW).total)


def simulate(sc: Scenario, *, self_heal: bool, n_steps: int = N_STEPS,
             patience: int = 3, warmup: int = 5) -> dict:
    """One arm of the scenario on the simulated clock."""
    cfg = bert_large_cfg()
    topo = sc.topology
    meta = model_graph(cfg, sc.per_device_batch * topo.n_devices, sc.seq).workload_meta()
    injector = FaultInjector(scenarios=(sc.slow,), seed=7)
    agg = HostStragglerAggregator(n_hosts=len(topo.hosts),
                                  patience=patience, warmup=warmup)
    agg.reset(topo.host_ids)
    t_step = _plan_step_time(meta, topo.cluster_spec())
    t_initial = t_step
    clock = SimClock()
    events = []
    post_heal_times = []
    for step in range(n_steps):
        times = injector.host_times(step, base=t_step, hosts=topo.host_ids)
        clock.advance(times)
        if events and events[-1]["kind"] == "rebalance":
            post_heal_times.append(max(times.values()))
        if not self_heal:
            continue
        for h in agg.observe(times):
            events.append({"kind": "evict", "step": step, "host": h})
            agg.evict(h)
            topo = topo.without({h})
            t_step = _plan_step_time(meta, topo.cluster_spec())
            clock.charge(3 * meta.param_bytes / DISK_BW + RECOMPILE_S)
            agg.reset(topo.host_ids)
            events.append({"kind": "rebalance", "step": step,
                           "predicted_step_s": t_step})
            post_heal_times = []
    return {
        "throughput": n_steps / clock.t,
        "wall_s": clock.t,
        "events": events,
        "t_initial": t_initial,
        "t_rebalanced": t_step,
        "post_heal_mean": (statistics.fmean(post_heal_times)
                          if post_heal_times else None),
        "surviving": topo,
    }


def rows(strict: bool = True) -> list:
    out = []
    for sc in SCENARIOS:
        naive = simulate(sc, self_heal=False)
        heal = simulate(sc, self_heal=True)
        evicts = [e for e in heal["events"] if e["kind"] == "evict"]
        if strict:
            assert evicts, f"{sc.name}: straggler never flagged"
            assert evicts[0]["host"] == sc.slow.host, \
                f"{sc.name}: evicted host {evicts[0]['host']}, " \
                f"injected {sc.slow.host}"
            assert evicts[0]["step"] <= SLOW_AT + 3 * (5 + 3), \
                f"{sc.name}: detection too slow (step {evicts[0]['step']})"
        # no rebalance (detection broke) → recovery 0.0: the gate's floor
        # fails loudly with the metric recorded instead of a traceback
        recovery = (heal["t_rebalanced"] / heal["post_heal_mean"]
                    if heal["post_heal_mean"] else 0.0)
        out.append({
            "scenario": sc.name,
            "naive_throughput": naive["throughput"],
            "selfheal_throughput": heal["throughput"],
            "selfheal_vs_naive": heal["throughput"] / naive["throughput"],
            "recovery_ratio": recovery,
            "evict_step": evicts[0]["step"] if evicts else -1,
            "predicted_ms": heal["t_rebalanced"] * 1e3,
            "achieved_ms": (heal["post_heal_mean"] or 0.0) * 1e3,
        })
    return out


def main(csv: bool = True, strict: bool = True) -> dict:
    """``strict=False`` (bench_ci) skips the hard asserts so the gate can
    record the regressed metrics in the JSON artifact and report them
    through its own floor/ceiling machinery instead of a raw traceback."""
    rs = rows(strict=strict)
    if csv:
        print("table,scenario,arm,steps_per_s,evict_step,"
              "predicted_ms,achieved_ms,recovery")
        for r in rs:
            print(f"fig_elastic,{r['scenario']},naive,"
                  f"{r['naive_throughput']:.2f},,,,")
            print(f"fig_elastic,{r['scenario']},self-heal,"
                  f"{r['selfheal_throughput']:.2f},{r['evict_step']},"
                  f"{r['predicted_ms']:.1f},{r['achieved_ms']:.1f},"
                  f"{r['recovery_ratio']:.3f}")
    speedup = min(r["selfheal_vs_naive"] for r in rs)
    recovery = min(r["recovery_ratio"] for r in rs)
    recovery_max = max(r["recovery_ratio"] for r in rs)
    if strict:
        # the self-healing arm must beat riding out the straggler on
        # every scenario, and post-heal throughput must land on the
        # rebalanced plan's cost-model prediction (jitter-bounded)
        assert speedup > 1.0, f"self-healing lost to naive ({speedup:.3f}×)"
        for r in rs:
            assert 0.9 <= r["recovery_ratio"] <= 1.1, \
                f"{r['scenario']}: post-heal throughput " \
                f"{r['achieved_ms']:.1f}ms off the predicted " \
                f"{r['predicted_ms']:.1f}ms"
    if csv:
        print(f"# headline: self-healing ≥{speedup:.2f}× naive-with-"
              f"straggler; recovery within {abs(1-recovery)*100:.1f}% of "
              f"the cost-model prediction")
    return {
        "selfheal_vs_naive_speedup": speedup,
        "recovery_ratio": recovery,
        "recovery_ratio_max": recovery_max,
        "per_scenario": {r["scenario"]: r for r in rs},
    }


if __name__ == "__main__":
    main()

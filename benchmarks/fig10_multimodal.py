"""Fig. 10 — segment-aware auto-search on the M6 multimodal workloads.

Whale's M6 case study (paper §5.3): industrial multimodal models — a
frontend feeding an encoder feeding a decoder, with wildly different
per-layer arithmetic in each tower — on mixed GPU pools.  A hand-tuned
"even" pipeline split (same layer count per stage, even batch shares)
prices every stage as if the model were homogeneous; the segment-aware
:class:`~repro.core.cost_model.ModelGraph` lets the planner see the real
per-segment costs, so stage boundaries land where the work actually is.

Three workloads on the mixed V100+T4 cluster, all from the analytic cost
model (meta-driven — nothing executes):

- ``seamless-m4t-medium`` (speech encdec): audio-frontend → 12-layer
  encoder → 12-layer decoder.  The decoder's cross-attention + LM head
  make its layers ~2× an encoder layer — the even split starves the
  fast cards and the headline speedup comes from re-cutting the towers.
- ``qwen2-vl-2b`` (vlm): atomic vision-frontend prefix + 28 decoder
  layers; the search may cut anywhere except inside the frontend.
- ``jamba-v0.1-52b`` (MoE hybrid, 52B): on 32 mixed cards the hand-even
  split does not fit at all (inf) — only the searched plan (pipeline ×
  sharded-DP × adafactor) is feasible.  "Auto finds a plan where the
  hand split cannot" is the Whale giant-model claim in one row.

Sanity anchors asserted in :func:`main`:

- segment-aware auto ≥ 1.2× the hand-even split on seamless (measured
  ≈2.6×);
- segment-aware auto is never worse than auto on the flattened
  :class:`~repro.core.cost_model.WorkloadMeta` of the same model (the
  flat meta is the graph with its boundaries erased);
- balanced placement of the SAME hand strategy already beats even (the
  graph's layer costs feed :func:`~repro.core.hetero.balance_stages`).

Output: CSV rows ``fig10,<model>,<even_ms>,<auto_ms>,<speedup>,<strategy>``.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.auto import search
from repro.core.cost_model import (ClusterSpec, DeviceGroup, StrategySpec,
                                   T4_16G, V100_PAPER)
from repro.core.hetero import plan_placement
from repro.models.lm import model_graph

MIXED_16 = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                               DeviceGroup("t4", T4_16G, 8)))
MIXED_32 = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 16),
                               DeviceGroup("t4", T4_16G, 16)))

# (arch, batch, seq, cluster, hand StrategySpec for the even comparator)
WORKLOADS = (
    ("seamless-m4t-medium", 128, 256, MIXED_16,
     StrategySpec(dp=4, pp=4, micro_batches=8)),
    ("qwen2-vl-2b", 64, 1024, MIXED_16,
     StrategySpec(dp=4, pp=4, micro_batches=8)),
    ("jamba-v0.1-52b", 64, 1024, MIXED_32,
     StrategySpec(dp=4, pp=8, micro_batches=16)),
)


def workload_rows(overlap: float = 0.5):
    """One row per workload: (name, graph, even_s, balanced_s, auto_s,
    auto_strategy, flat_auto_s)."""
    out = []
    for arch, batch, seq, spec, hand in WORKLOADS:
        cfg = get_config(arch)
        graph = model_graph(cfg, batch, seq)
        even = plan_placement(graph, hand, spec, overlap=overlap,
                              balanced=False)
        balanced = plan_placement(graph, hand, spec, overlap=overlap)
        cands = search(graph, spec, top_k=1, overlap=overlap)
        auto_t = cands[0].total if cands else float("inf")
        auto_desc = cands[0].strategy.describe() if cands else "infeasible"
        flat = search(graph.workload_meta(), spec, top_k=1, overlap=overlap)
        flat_t = flat[0].total if flat else float("inf")
        out.append((arch, graph, even.step_time, balanced.step_time,
                    auto_t, auto_desc, flat_t))
    return out


def main(csv: bool = True) -> dict:
    rows = workload_rows()
    out = []
    for arch, graph, even_t, bal_t, auto_t, desc, flat_t in rows:
        speed = even_t / auto_t
        out.append(("fig10", arch, even_t * 1e3, auto_t * 1e3, speed, desc))
        if csv:
            print(f"# {graph.describe()}")
    if csv:
        print("table,model,even_ms,auto_ms,speedup,auto_strategy")
        for r in out:
            print(f"{r[0]},{r[1]},{r[2]:.1f},{r[3]:.1f},{r[4]:.2f},{r[5]}")

    by = {r[0]: r for r in rows}

    # headline: segment-aware auto beats the hand-even split on the
    # multimodal encdec workload (measured ≈2.6×; floor 1.2× for CI)
    arch, graph, even_t, bal_t, auto_t, desc, flat_t = by[
        "seamless-m4t-medium"]
    assert auto_t * 1.2 <= even_t, \
        f"fig10 headline: auto {auto_t:.3f}s must beat even {even_t:.3f}s " \
        f"by >= 1.2x on seamless-m4t-medium"
    # mechanism check: balancing the SAME hand strategy from per-segment
    # layer costs already beats the even split (and never loses)
    assert bal_t <= even_t + 1e-9, \
        "balanced placement of the hand strategy must never lose to even"
    # the flat meta is the graph with boundaries erased: seeing segments
    # must never cost the search anything
    for arch2, _g, _e, _b, a_t, _d, f_t in rows:
        if f_t != float("inf"):
            assert a_t <= f_t + 1e-9, \
                f"{arch2}: graph-aware auto ({a_t:.3f}s) must be <= " \
                f"flat-meta auto ({f_t:.3f}s)"

    # vlm row: auto must respect the atomic frontend and still win
    _, _, q_even, _, q_auto, _, _ = by["qwen2-vl-2b"]
    assert q_auto < q_even, "qwen2-vl: auto must beat the hand-even split"

    # giant-model row: the hand split does not fit; the search must
    # still find a feasible plan for the 52B MoE hybrid
    _, _, j_even, _, j_auto, j_desc, _ = by["jamba-v0.1-52b"]
    assert j_even == float("inf"), \
        "jamba-v0.1-52b hand-even split unexpectedly fits 32x16GiB"
    assert j_auto != float("inf"), \
        "jamba-v0.1-52b: the auto-search must find a feasible plan"

    if csv:
        print(f"# headline: segment-aware auto {even_t / auto_t:.2f}x over "
              f"hand-even split on seamless-m4t-medium ({desc}); "
              f"jamba-52B feasible only via auto ({j_desc})")
    return {
        "fig10_auto_vs_even": even_t / auto_t,
        "fig10_vlm_auto_vs_even": q_even / q_auto,
        "fig10_balanced_vs_even": even_t / bal_t,
        "fig10_graph_vs_flat_min": min(
            f_t / a_t for _a, _g, _e, _b, a_t, _d, f_t in rows
            if f_t != float("inf")),
        "fig10_jamba_even_infeasible": j_even == float("inf"),
        "fig10_jamba_auto_feasible": j_auto != float("inf"),
        "fig10_step_ms": {r[1]: r[3] for r in out},
        "fig10_auto_strategy": {r[1]: r[5] for r in out},
    }


if __name__ == "__main__":
    main()

"""CI benchmark-regression gate: run the analytic benchmarks, record the
headline numbers, fail on regression below the recorded floors.

    PYTHONPATH=src python -m benchmarks.bench_ci [--out BENCH_PR10.json]

The analytic (cost-model / simulated-clock) benchmarks are deterministic —
pure arithmetic over hardware tables, no execution, no timing noise — so
they can be gated hard in CI.  This script runs fig2 (schedule grid), fig7
(heterogeneous balancing), fig9 (nested DP×EP MoE), fig_elastic
(self-healing straggler eviction), fig_spot (spot-fleet drain-and-grow vs
restart-from-checkpoint), fig_calibration (profile-calibrated cost model +
drift-triggered rebalance), and the kernel roofline pass
(benchmarks.kernel_bench — fused Pallas kernels vs jnp refs per Hardware
entry, with interpret-mode numerics), writes every headline metric to a
JSON artifact, and exits non-zero if any gated metric falls below its
floor:

    fig7_hetero_speedup      >= 2.5   (aware vs naive on mixed V100/P100)
    fig2_uneven_speedup      >= 2.5   (uneven vs even stages, mixed cluster)
    fig9_nested_vs_flat      >  1.0   (nested replica{split[experts]} vs
                                       flat DP on the M6-like MoE)
    fig_elastic_selfheal_vs_naive >= 1.5  (evict+rebalance vs riding out
                                           the straggler, worst scenario)
    fig_elastic_recovery_ratio >= 0.9     (post-heal throughput lands on
                                           the rebalanced plan's cost-model
                                           prediction; also gated <= 1.1)
    fig_spot_drain_vs_restart >= 1.3  (drain-and-grow through the outage
                                       vs idling it out fleet-rigid,
                                       worst scenario, benchmarks.fig_spot)
    fig_spot_grow_recovery   >= 0.9   (post-grow throughput lands on the
                                       full-fleet cost-model prediction;
                                       also gated <= 1.1, and the re-grown
                                       plan prices within 5% of the
                                       never-preempted one)
    kernel_flash_speedup_tpu >= 2.0   (fused flash fwd+bwd vs materialised
                                       scores on the target part)
    kernel_flash_speedup_min >= 1.0   (never analytically slower, any part)
    kernel_ssd_speedup_min   >= 5.0   (chunked scan vs quadratic, any part)
    kernel_xent_footprint_min >= 5.0  (fused loss-head live bytes vs the
                                       chunked ref's logits block)
    serve_tokens_per_s_ratio >= 1.3   (paged+disagg vs dense colocated
                                       tokens/s on the 8×V100+8×T4
                                       flagship, benchmarks.fig_serve)
    calibration_continuous_vs_oneshot >= 1.3  (drift-triggered rebalance
                                       vs one-shot on the slow-drift
                                       scenario, benchmarks.fig_calibration)
    fig10_auto_vs_even       >= 1.2   (segment-aware auto-search vs the
                                       hand-even pipeline split on the
                                       multimodal encdec flagship,
                                       benchmarks.fig10_multimodal)

Floors are deliberately below the current values (2.77 / 2.66 / 1.98 /
2.20 / 0.98 / 1.47 / 0.97 / 2.55 / 1.0 / 8.3 / 9.8 / 1.51 / 1.36 / 1.90)
so legitimate
refinements have headroom, while a change that destroys a headline win
(the balancer, the schedule memory model, the ep pricing, the eviction
loop, the kernel tiling/autotuner, the serving router/simulator, the
calibration fit) fails the ``bench`` CI job loudly.  The kernel section
additionally gates numerics (interpret-mode max |err| vs oracle) and the
static VMEM budget as structural invariants; the serving section
additionally gates p99 TTFT (disagg ≤ colocated) and parity on the
prefill-heavy scenario; the calibration section additionally gates the
final fit error and the predicted-vs-measured step-cost error (both
≤ 10% as ceilings).
"""
from __future__ import annotations

import argparse
import json
import sys

FLOORS = {
    "fig7_hetero_speedup": 2.5,
    "fig2_uneven_speedup": 2.5,
    "fig9_nested_vs_flat_speedup": 1.0,
    "fig_elastic_selfheal_vs_naive": 1.5,
    "fig_elastic_recovery_ratio": 0.9,
    "fig_spot_drain_vs_restart": 1.3,
    "fig_spot_grow_recovery": 0.9,
    "kernel_flash_speedup_tpu": 2.0,
    "kernel_flash_speedup_min": 1.0,
    "kernel_ssd_speedup_min": 5.0,
    "kernel_xent_footprint_min": 5.0,
    "serve_tokens_per_s_ratio": 1.3,
    "calibration_continuous_vs_oneshot": 1.3,
    "fig10_auto_vs_even": 1.2,
}


def collect() -> dict:
    import benchmarks.fig2_bert_pipeline as fig2
    import benchmarks.fig7_heterogeneous as fig7
    import benchmarks.fig9_m6_moe as fig9

    out: dict = {"floors": dict(FLOORS)}

    # ---- fig2: pipeline vs HDP + the schedule grid ----
    model_rows = fig2.model_rows()
    gpus, hdp, _, wpipe = model_rows[-1]
    out["fig2_pipeline_vs_hdp_at_64"] = hdp / wpipe
    grid = {r[0]: r for r in fig2.schedule_grid_rows()}
    out["fig2_uneven_speedup"] = grid["1f1b-even"][4] / grid["1f1b-uneven"][4]
    out["fig2_bubble_fraction"] = grid["gpipe-even"][2]
    out["fig2_1f1b_mem_advantage"] = (grid["gpipe-uneven"][3]
                                      / grid["1f1b-uneven"][3])
    out["fig2_step_ms"] = {k: r[4] for k, r in grid.items()}

    # ---- fig7: hardware-aware vs naive on mixed clusters ----
    f7 = fig7.rows()
    hetero = [(m, c, tn, ta) for m, c, tn, ta, _ in f7 if "homog" not in c]
    out["fig7_hetero_speedup"] = max(tn / ta for _, _, tn, ta in hetero)
    out["fig7_step_ms"] = {f"{m}/{c}": ta * 1e3 for m, c, _, ta in hetero}
    homog = [(tn, ta) for m, c, tn, ta, _ in f7 if "homog" in c]
    out["fig7_homog_speedup"] = max(tn / ta for tn, ta in homog)

    # ---- fig9: nested DP×EP vs flat DP (runs its own assertions) ----
    f9 = fig9.main(csv=False)
    out["fig9_nested_vs_flat_speedup"] = f9["nested_vs_flat_speedup"]
    out["fig9_flat_oom_on_32e"] = f9["flat_oom_on_32e"]
    out["fig9_nested_fits_32e"] = f9["nested_fits_32e"]

    # ---- fig_elastic: self-healing eviction loop (simulated clock);
    # strict=False so a regression is recorded in the artifact and
    # reported via gate() rather than aborting collect() ----
    import benchmarks.fig_elastic as fig_elastic
    fe = fig_elastic.main(csv=False, strict=False)
    out["fig_elastic_selfheal_vs_naive"] = fe["selfheal_vs_naive_speedup"]
    out["fig_elastic_recovery_ratio"] = fe["recovery_ratio"]
    out["fig_elastic_recovery_ratio_max"] = fe["recovery_ratio_max"]
    out["fig_elastic_per_scenario"] = {
        name: {k: v for k, v in r.items() if k != "scenario"}
        for name, r in fe["per_scenario"].items()}

    # ---- fig_spot: spot-fleet drain-and-grow vs restart (simulated
    # clock); strict=False for the same record-then-gate reason ----
    import benchmarks.fig_spot as fig_spot
    fsp = fig_spot.main(csv=False, strict=False)
    out["fig_spot_drain_vs_restart"] = fsp["drain_vs_restart_speedup"]
    out["fig_spot_grow_recovery"] = fsp["grow_recovery"]
    out["fig_spot_grow_recovery_max"] = fsp["grow_recovery_max"]
    out["fig_spot_post_grow_vs_initial"] = fsp["post_grow_vs_initial"]
    out["fig_spot_per_scenario"] = {
        name: {k: v for k, v in r.items() if k != "scenario"}
        for name, r in fsp["per_scenario"].items()}

    # ---- fig_serve: paged + disaggregated serving (analytic sim);
    # strict=False for the same record-then-gate reason as fig_elastic ----
    import benchmarks.fig_serve as fig_serve
    fs = fig_serve.main(csv=False, strict=False)
    out["serve_tokens_per_s_ratio"] = fs["serve_tokens_per_s_ratio"]
    out["serve_ttft_p99_ratio"] = fs["serve_ttft_p99_ratio"]
    out["serve_tokens_per_s_ratio_all"] = fs["serve_tokens_per_s_ratio_all"]
    out["serve_per_scenario"] = fs["per_scenario"]

    # ---- fig_calibration: profile-calibrated cost model (analytic);
    # strict=False for the same record-then-gate reason as fig_elastic ----
    import benchmarks.fig_calibration as fig_cal
    fcal = fig_cal.main(csv=False, strict=False)
    out["calibration_continuous_vs_oneshot"] = fcal["continuous_vs_oneshot"]
    out["calibration_error_final"] = fcal["calibration_error_final"]
    out["calibration_error_initial"] = fcal["calibration_error_initial"]
    out["calibration_stepcost_error_final"] = fcal["stepcost_error_final"]
    out["calibration_drift_fit_error"] = fcal["drift_fit_error"]
    out["calibration_rebalances"] = fcal["continuous_rebalances"]
    out["calibration_curve"] = fcal["curve"]

    # ---- fig10: segment-aware auto-search on the M6 multimodal
    # workloads (runs its own assertions against the graph invariants) ----
    import benchmarks.fig10_multimodal as fig10
    f10 = fig10.main(csv=False)
    out.update({k: v for k, v in f10.items()})

    # ---- kernel speed pass: roofline speedups + interpret numerics ----
    import benchmarks.kernel_bench as kb
    rl = kb.roofline()
    out["kernel_flash_speedup_tpu"] = rl["flash_speedup_tpu"]
    out["kernel_flash_speedup_min"] = rl["flash_speedup_min"]
    out["kernel_ssd_speedup_min"] = rl["ssd_speedup_min"]
    out["kernel_xent_footprint_min"] = rl["xent_footprint_min"]
    out["kernel_roofline"] = {k: rl[k] for k in
                              ("flash", "xent", "ssd", "tiles",
                               "flash_traffic", "xent_footprint")}
    rows = kb.main(csv=False)
    out["kernel_numerics_max_err"] = max(r[3] for r in rows)
    out["kernel_vmem_max_kib"] = max(r[4] for r in rows)
    return out


def gate(metrics: dict) -> list:
    failures = []
    for key, floor in FLOORS.items():
        val = metrics.get(key)
        strict = key.startswith("fig9")
        ok = val is not None and (val > floor if strict else val >= floor)
        if not ok:
            failures.append(f"{key} = {val} regressed below floor {floor}")
    # structural invariants the trajectory relies on
    if abs(metrics.get("fig7_homog_speedup", 1.0) - 1.0) > 1e-9:
        failures.append("homogeneous cluster no longer reduces to the "
                        "even split (fig7_homog_speedup != 1.0)")
    if not metrics.get("fig9_nested_fits_32e"):
        failures.append("nested DP×EP no longer fits the 32-expert M6 "
                        "config")
    # ceiling gates the MAX across scenarios (the floor gates the min via
    # FLOORS) — a single out-of-range scenario must fail the gate
    if metrics.get("fig_elastic_recovery_ratio_max", 1.0) > 1.1:
        failures.append("post-heal throughput exceeds the cost-model "
                        "prediction by >10% — the simulated clock and the "
                        "search disagree (fig_elastic_recovery_ratio_max "
                        "> 1.1)")
    if metrics.get("fig_spot_grow_recovery_max", 1.0) > 1.1:
        failures.append("post-grow throughput exceeds the full-fleet "
                        "cost-model prediction by >10% "
                        "(fig_spot_grow_recovery_max > 1.1)")
    if metrics.get("fig_spot_post_grow_vs_initial", 1.0) > 1.05:
        failures.append("the re-grown plan prices >5% above the "
                        "never-preempted plan — the grow round trip is "
                        "lossy (fig_spot_post_grow_vs_initial > 1.05)")
    if metrics.get("kernel_numerics_max_err", 1.0) >= 1e-2:
        failures.append("a fused kernel drifted from its jnp oracle "
                        "(kernel_numerics_max_err >= 1e-2)")
    if metrics.get("kernel_vmem_max_kib", 1e9) >= 16 * 1024:
        failures.append("a kernel tile working set exceeds the 16 MiB "
                        "VMEM budget (kernel_vmem_max_kib)")
    # the throughput win must not be bought with a latency regression:
    # p99 TTFT of the disaggregated arm stays no worse than colocated
    if metrics.get("serve_ttft_p99_ratio", 1e9) > 1.0:
        failures.append("disaggregated serving regressed p99 TTFT vs the "
                        "colocated baseline (serve_ttft_p99_ratio > 1.0)")
    if metrics.get("serve_tokens_per_s_ratio_all", 0.0) < 0.95:
        failures.append("a serving scenario collapsed below parity with "
                        "the colocated baseline "
                        "(serve_tokens_per_s_ratio_all < 0.95)")
    # calibration must close the sim-to-measured loop: the fitted table's
    # residual errors are ceilings, not floors
    if metrics.get("calibration_error_final", 1.0) > 0.10:
        failures.append("calibration no longer recovers the ground-truth "
                        "hardware table (calibration_error_final > 10%)")
    if metrics.get("calibration_stepcost_error_final", 1.0) > 0.10:
        failures.append("predicted-vs-measured step cost on the fitted "
                        "table exceeds 10% "
                        "(calibration_stepcost_error_final)")
    if metrics.get("calibration_drift_fit_error", 1.0) > 0.10:
        failures.append("the drift scenario's fitted rates diverge >10% "
                        "from the drifted truth "
                        "(calibration_drift_fit_error)")
    if metrics.get("calibration_rebalances", 0) < 1:
        failures.append("the continuous arm never recalibrated on the "
                        "drift scenario (calibration_rebalances < 1)")
    # segment awareness must be free: erasing boundaries (the flat meta)
    # can never beat the graph-aware search
    if metrics.get("fig10_graph_vs_flat_min", 0.0) < 1.0 - 1e-9:
        failures.append("graph-aware auto-search lost to the flattened "
                        "WorkloadMeta search (fig10_graph_vs_flat_min < 1)")
    if not metrics.get("fig10_jamba_auto_feasible"):
        failures.append("the auto-search no longer finds a feasible plan "
                        "for jamba-v0.1-52b on 32 mixed cards "
                        "(fig10_jamba_auto_feasible)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR10.json")
    args = ap.parse_args(argv)
    metrics = collect()
    with open(args.out, "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")
    for k in sorted(FLOORS):
        print(f"  {k}: {metrics[k]:.3f} (floor {FLOORS[k]})")
    failures = gate(metrics)
    for msg in failures:
        print(f"BENCH REGRESSION: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Fig. 9 (ours) — the M6 recipe: nested replica{split[experts]} vs flat DP.

Whale's 10T-parameter M6 model trained with exactly two primitives —
``replicate`` and ``split`` — *nested*: data-parallel replica groups whose
MoE layers split their experts over the intra-server axis (paper §4's graph
optimizations handle the bridges).  This benchmark reproduces the why from
the analytic cost model (meta-driven — nothing executes) on the paper's own
V100 hardware table (8-GPU NVLink servers, 35 Gb/s shared Ethernet):

1. **Feasibility** (the headline M6 claim): on an M6-like MoE config, flat
   DP replicates every expert onto every device and blows the 16 GB HBM —
   the nested hybrid shards experts ep-ways and fits.  Flat DP literally
   cannot train the model.
2. **Throughput** (the regression-gated number): on a reduced config flat
   DP *can* hold, it pays the full expert-gradient all-reduce over shared
   Ethernet every step; the nested hybrid cuts that volume by ep (expert
   shards own disjoint experts) and pays only cheap intra-server
   all-to-all dispatch/combine.  Nested DP×EP must beat flat DP —
   ``BENCH_PR4.json``'s ``fig9_nested_vs_flat`` floor asserts > 1.0×.
3. **Auto-search on mixed hardware**: ``auto.search`` over a heterogeneous
   V100+P100 ClusterSpec enumerates the nested hybrids and the winner is
   hardware-balanced (batch shares ∝ group FLOP/s).

Output: CSV rows ``fig9,<config>,<strategy>,<feasible>,<ms>,<mem_gib>``
plus the nested-vs-flat speedup headline.
"""
from __future__ import annotations

import dataclasses

from repro.core.auto import search
from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   StrategySpec, V100_PAPER,
                                   step_cost)
from repro.models.lm import model_graph


def m6_cfg(n_experts: int = 32, d_ff_expert: int = 1024):
    """An M6-like MoE transformer scaled to the paper's V100-16G cluster."""
    from repro.configs import get_config
    return dataclasses.replace(
        get_config("deepseek-moe-16b"),
        n_layers=16, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, n_experts=n_experts, top_k=2, d_ff_expert=d_ff_expert,
        n_shared=0, moe_every=2, vocab=30522, remat="none",
        name=f"m6-moe-{n_experts}e")


GPUS = 64                      # 8 servers × 8 V100s
EP = 8                         # experts split inside one NVLink server


def strategies():
    return {
        "flat-dp": StrategySpec(dp=GPUS, remat=False, vocab_split=False),
        "nested-dp-ep": StrategySpec(dp=GPUS // EP, ep=EP, remat=False,
                                     vocab_split=False),
    }


def rows(per_gpu_batch: int = 16, seq: int = 512):
    """(config, strategy, feasible, step_s, mem_bytes) per point.

    Two configs: ``m6-moe-32e`` (flat DP OOMs — the feasibility story) and
    ``m6-moe-16e`` (both fit — the speedup story).
    """
    out = []
    for cfg in (m6_cfg(n_experts=32), m6_cfg(n_experts=16)):
        meta = model_graph(cfg, per_gpu_batch * GPUS, seq).workload_meta()
        for sname, strat in strategies().items():
            c = step_cost(meta, strat, V100_PAPER, overlap=0.5)
            out.append((cfg.name, sname, c.feasible, c.total, c.mem_bytes))
    return out


def nested_vs_flat_speedup(rws=None) -> float:
    """The regression-gated headline: nested/flat on the config both fit."""
    rws = rws if rws is not None else rows()
    by = {(c, s): (f, t) for c, s, f, t, _ in rws}
    feas_f, t_flat = by[("m6-moe-16e", "flat-dp")]
    feas_n, t_nested = by[("m6-moe-16e", "nested-dp-ep")]
    assert feas_f and feas_n, "both strategies must fit the 16-expert config"
    return t_flat / t_nested


def auto_rows(per_gpu_batch: int = 16, seq: int = 512):
    """auto.search prices the nested hybrid on a mixed V100/P100 cluster."""
    cfg = m6_cfg(n_experts=16)
    out = []
    for cname, spec in {
        "64xV100": ClusterSpec.homogeneous(V100_PAPER, GPUS),
        "32xV100+32xP100": ClusterSpec(groups=(
            DeviceGroup("v100", V100_PAPER, 32),
            DeviceGroup("p100", P100_16G, 32))),
    }.items():
        meta = model_graph(cfg, per_gpu_batch * spec.n_devices, seq).workload_meta()
        cands = search(meta, spec, top_k=4, overlap=0.5, max_pp=1)
        nested = [c for c in cands if c.strategy.ep > 1]
        out.append((cname, cands, nested))
    return out


def main(csv=True) -> dict:
    rws = rows()
    speedup = nested_vs_flat_speedup(rws)
    by = {(c, s): (f, t, m) for c, s, f, t, m in rws}
    if csv:
        print("table,config,strategy,feasible,ms_per_step,mem_gib")
        for c, s, f, t, m in rws:
            ms = f"{t * 1e3:.1f}" if f else "inf"
            print(f"fig9,{c},{s},{int(f)},{ms},{m / 2**30:.2f}")
    # story 1: flat DP cannot hold the 32-expert config; nested can
    assert not by[("m6-moe-32e", "flat-dp")][0], \
        "flat DP should OOM on the 32-expert M6 config (16 GB HBM)"
    assert by[("m6-moe-32e", "nested-dp-ep")][0], \
        "nested DP×EP must fit the 32-expert M6 config"
    # story 2: where both fit, nested must win (the CI-gated floor)
    assert speedup > 1.0, \
        f"nested DP×EP must beat flat DP, got {speedup:.3f}×"
    auto = auto_rows()
    hetero_has_nested = False
    for cname, cands, nested in auto:
        assert cands, f"no feasible strategy on {cname}"
        if nested and "P100" in cname:
            hetero_has_nested = True
        if csv:
            best = cands[0]
            print(f"fig9-auto,{cname},{best.strategy.describe()},"
                  f"{best.total * 1e3:.1f}")
    # story 3: the search enumerates + prices nested hybrids on mixed HW
    assert hetero_has_nested, \
        "auto.search must enumerate nested DP×EP on the mixed cluster"
    if csv:
        print(f"# headline: nested replica{{split[experts]}} = "
              f"{speedup:.2f}× flat DP on m6-moe-16e; flat DP OOMs on "
              f"m6-moe-32e while nested fits (the M6 feasibility claim)")
    return {"nested_vs_flat_speedup": speedup,
            "flat_oom_on_32e": not by[("m6-moe-32e", "flat-dp")][0],
            "nested_fits_32e": by[("m6-moe-32e", "nested-dp-ep")][0]}


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run JSONL (EXPERIMENTS.md §Roofline).

Reads ``bench_out/dryrun.jsonl`` (append-only; last record per
(arch, shape, mesh) wins so hillclimb re-runs supersede baselines), prints
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and the roofline fraction per cell.

    PYTHONPATH=src python -m benchmarks.roofline [--jsonl path] [--md]
"""
from __future__ import annotations

import argparse
import json
import os


def load(path: str) -> dict:
    """Last record per (arch, shape, multi_pod) wins — re-runs supersede."""
    cells: dict = {}
    if not os.path.exists(path):
        return cells
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("tag"):
                continue          # tagged = perf-iteration run, not baseline
            cells[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return cells


def fmt_row(r: dict, md: bool = False) -> str:
    sep = " | " if md else "  "
    if r["status"] == "skipped":
        return sep.join([f"{r['arch']:22s}", f"{r['shape']:12s}",
                         r.get("mesh", ""), "skipped: " + r["reason"][:60]])
    if r["status"] != "ok":
        return sep.join([f"{r['arch']:22s}", f"{r['shape']:12s}",
                         r.get("mesh", ""), "FAILED"])
    return sep.join([
        f"{r['arch']:22s}", f"{r['shape']:12s}", f"{r['mesh']:8s}",
        f"{r['t_compute']*1e3:9.1f}", f"{r['t_memory']*1e3:9.1f}",
        f"{r['t_collective']*1e3:9.1f}", f"{r['bottleneck']:10s}",
        f"{r['model_flops_hlo_ratio']:5.2f}", f"{r['roofline_frac']:6.3f}",
        f"{r['mem_temp_gib'] + r['mem_args_gib']:7.2f}",
    ])


HEADER = ("arch                    shape         mesh      comp_ms   "
          " mem_ms   coll_ms  bottleneck  MF/HLO  rf      GiB/dev")


def main(argv=None) -> list:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="bench_out/dryrun.jsonl")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 | 2x16x16")
    args = ap.parse_args(argv)

    cells = load(args.jsonl)
    rows = sorted(cells.values(),
                  key=lambda r: (r.get("mesh", ""), r["arch"], r["shape"]))
    if args.mesh:
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    print(HEADER)
    for r in rows:
        print(fmt_row(r, md=args.md))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"])
        coll = max(ok, key=lambda r: r["t_collective"] /
                   max(r["t_compute"] + r["t_memory"], 1e-12))
        print(f"\n# {len(ok)} ok cells; worst roofline fraction: "
              f"{worst['arch']}/{worst['shape']} ({worst['roofline_frac']:.3f}); "
              f"most collective-bound: {coll['arch']}/{coll['shape']}")
    return rows


if __name__ == "__main__":
    main()

"""Paper Fig. 2 — Bert-Large: Horovod DP vs Whale DP vs Whale pipeline.

Three layers of evidence:

1. **Cost model at the paper's own scale** (V100-16G servers, 8 GPUs each,
   35 Gb/s shared Ethernet): throughput of the three systems at 8→64 GPUs.
   The paper's measured headline is Whale pipeline = 2.32 × HDP at 64 GPUs;
   the meta-driven model must land in that neighbourhood from first
   principles (no fitting): DP's gradient all-reduce crosses Ethernet with
   the full 340M-param volume, while 4-stage pipelining divides the
   all-reduce volume per DP group by the stage count.

2. **Schedule × stage-allocation grid** (:func:`schedule_grid_rows`):
   even vs uneven (hetero-planner) layer splits × GPipe vs 1F1B on a
   mixed V100/P100 cluster — the bubble fraction is identical (the
   closed form (S−1)/(M+S−1); repro.core.schedule), while 1F1B's peak
   activation memory is min(M, S)/M of GPipe's and the uneven split buys
   back the slow cards' latency.

3. **Measured small-scale run** (virtual CPU devices): Whale DP vs Whale
   pipeline×DP on a bert-like reduced config — verifies the executable
   schedule end-to-end (losses match the non-pipelined reference).

Output: CSV rows ``fig2,<system>,<gpus>,<ms_per_step>,<speedup_vs_hdp>``
plus the ``fig2-sched`` grid table.
"""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   StrategySpec, V100_PAPER,
                                   step_cost)
from repro.models.lm import model_graph
from repro.core.schedule import (bubble_fraction_closed_form,
                                 in_flight_micro_batches)


def bert_large_cfg():
    from repro.configs import get_config
    return dataclasses.replace(
        get_config("stablelm-3b"), n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=4096, vocab=30522, norm="ln",
        act="gelu", gated_mlp=False, remat="none", name="bert-large")


def model_rows(per_gpu_batch: int = 24, seq: int = 128):
    """Cost-model throughput for HDP / Whale DP / Whale pipeline, 8→64.

    Assumptions (stated, not fitted): per-GPU batch 24 ≈ the V100-16G
    capacity point for Bert-Large without remat (activations ~9 GB + params/
    optimizer ~5.4 GB); gradient-reduction/backward overlap 0.5 for every
    system (Horovod tensor fusion and XLA latency hiding are comparable);
    pipeline = 4 stages × micro_batch 4 (paper Case 4 uses micro_batch=4).
    """
    cfg = bert_large_cfg()
    rows = []
    for gpus in (8, 16, 32, 64):
        batch = per_gpu_batch * gpus
        meta = model_graph(cfg, batch, seq).workload_meta()
        # Horovod DP: full-volume gradient all-reduce over shared Ethernet
        hdp = step_cost(meta, StrategySpec(dp=gpus, remat=False,
                                           vocab_split=False),
                        V100_PAPER, overlap=0.5)
        # Whale DP: same strategy through the Whale engine (paper: parity)
        wdp = step_cost(meta, StrategySpec(dp=gpus, remat=False,
                                           vocab_split=False),
                        V100_PAPER, overlap=0.55)
        # Whale pipeline: stages divide the per-group all-reduce volume ×4
        pp = 4
        wpipe = step_cost(meta, StrategySpec(dp=gpus // pp, pp=pp,
                                             micro_batches=4, remat=False,
                                             vocab_split=False),
                          V100_PAPER, overlap=0.5)
        rows.append((gpus, hdp.total, wdp.total, wpipe.total))
    return rows


def schedule_grid_rows(per_gpu_batch: int = 24, seq: int = 128):
    """even/uneven stage split × gpipe/1f1b on 8×V100 + 8×P100, 4 stages.

    → rows ``(label, layer_alloc, bubble_frac, mem_gib_peak_stage,
    ms_per_step)``.  Invariants asserted here (and regression-tested in
    tests/test_schedule.py): bubble identical across schedules; 1F1B peak
    stage memory strictly below GPipe's at M > S (its in-flight
    activation cap); the balanced allocation never loses to even on the
    mixed cluster.
    """
    from repro.core.hetero import plan_placement
    from repro.core.schedule import make_schedule
    cfg = bert_large_cfg()
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                               DeviceGroup("p100", P100_16G, 8)))
    gpus, pp, M = 16, 4, 8
    meta = model_graph(cfg, per_gpu_batch * gpus, seq).workload_meta()
    rows = []
    for sched in ("gpipe", "1f1b"):
        for balanced in (False, True):
            strat = StrategySpec(dp=gpus // pp, pp=pp, micro_batches=M,
                                 remat=False, vocab_split=False,
                                 schedule=sched)
            pl = plan_placement(meta, strat, spec, overlap=0.5,
                                balanced=balanced)
            act_peak = max(u.cost.mem_bytes for u in pl.units)
            rows.append((f"{sched}-{'uneven' if balanced else 'even'}",
                         pl.layer_alloc,
                         # bubble measured from the generated tick table —
                         # NOT the closed form, which it is asserted against
                         make_schedule(sched, pp, M).bubble_fraction(),
                         act_peak / 2**30,
                         pl.cost.total * 1e3))
    by = {r[0]: r for r in rows}
    # same bubble (each measured from its own table, and matching the
    # closed form the cost model prices); 1F1B's in-flight advantage
    # shows up as lower peak memory
    assert by["gpipe-even"][2] == by["1f1b-even"][2]
    assert abs(by["gpipe-even"][2]
               - bubble_fraction_closed_form(pp, M)) < 1e-12
    assert by["1f1b-even"][3] < by["gpipe-even"][3]
    assert by["1f1b-uneven"][3] < by["gpipe-uneven"][3]
    assert (in_flight_micro_batches(pp, M, "1f1b")
            < in_flight_micro_batches(pp, M, "gpipe"))
    # the balanced (uneven) split must not lose to even on mixed hardware
    assert by["1f1b-uneven"][4] <= by["1f1b-even"][4] + 1e-9
    return rows


def measured_rows(steps: int = 4):
    """Small-scale executable check: DP vs pipeline×DP on virtual devices."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    import time

    import repro.core.pipeline as pipe
    from repro.configs import get_config
    from repro.core.planner import compile_plan
    from repro.core.sharding import hybrid_rules
    from repro.models.lm import build
    from repro.optim.optimizer import adamw

    n = len(jax.devices())
    if n < 4:
        return []
    cfg = dataclasses.replace(get_config("stablelm-3b", smoke=True),
                              n_layers=4, norm="ln", act="gelu",
                              name="bert-smoke")
    model = build(cfg)
    opt = adamw(lr=1e-3)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (8, 128)), jnp.int32)

    def time_fn(fn, *args):
        out = fn(*args)                      # compile + warm
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(steps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / steps

    rows = []
    # DP
    mesh = jax.make_mesh((n,), ("data",))
    plan = compile_plan(model, mesh)
    with mesh:
        params = plan.init_params(jax.random.key(0))
        ost = opt.init(params)
        step = plan.jit_train_step(opt, {"tokens": tokens}, donate=False)
        dt = time_fn(lambda: step(params, ost, {"tokens": tokens}, 0))
    rows.append(("whale-dp-measured", n, dt))
    # pipeline (2 stages) × DP
    mesh2 = jax.make_mesh((2, n // 2, 1), ("stage", "data", "model"))
    rules = hybrid_rules(mesh2)
    pstep = pipe.make_pipeline_train_step(model, mesh2, rules, opt,
                                          micro_batches=4, donate=False)
    pspecs = pipe.staged_specs(rules, model.axes(), model.param_shapes())
    psh = jax.tree.map(lambda s: jax.NamedSharding(mesh2, s), pspecs,
                       is_leaf=lambda t: isinstance(
                           t, jax.sharding.PartitionSpec))
    with mesh2:
        p2 = jax.jit(model.init, out_shardings=psh)(jax.random.key(0))
        o2 = opt.init(p2)
        dt2 = time_fn(lambda: pstep(p2, o2, tokens, 0))
    rows.append(("whale-pipeline-measured", n, dt2))
    return rows


def print_schedule_grid(rows) -> None:
    print("table,config,layer_alloc,bubble_frac,mem_gib_peak_stage,"
          "ms_per_step")
    for label, alloc, bub, gib, ms in rows:
        print(f"fig2-sched,{label},{'/'.join(str(x) for x in alloc)},"
              f"{bub:.4f},{gib:.2f},{ms:.1f}")
    by = {r[0]: r for r in rows}
    adv = by["gpipe-uneven"][3] / by["1f1b-uneven"][3]
    print(f"# 1F1B peak stage memory = {1 / adv:.2f}× GPipe's on the same "
          f"uneven grid (bubble identical: "
          f"{by['gpipe-uneven'][2]:.4f})")


def main(csv=True) -> list:
    out = []
    rows = model_rows()
    for gpus, hdp, wdp, wpipe in rows:
        out.append(("fig2", "horovod-dp", gpus, hdp * 1e3, 1.0))
        out.append(("fig2", "whale-dp", gpus, wdp * 1e3, hdp / wdp))
        out.append(("fig2", "whale-pipeline", gpus, wpipe * 1e3, hdp / wpipe))
    for name, n, dt in measured_rows():
        out.append(("fig2", name, n, dt * 1e3, float("nan")))
    if csv:
        print("table,system,gpus,ms_per_step,speedup_vs_hdp")
        for r in out:
            print(",".join(str(x) for x in r))
        sp64 = [r for r in out if r[1] == "whale-pipeline" and r[2] == 64]
        print(f"# headline: whale-pipeline @64 GPUs = {sp64[0][4]:.2f}× HDP "
              f"(paper: 2.32×)")
        print_schedule_grid(schedule_grid_rows())
    return out


if __name__ == "__main__":
    main()

"""Profile-calibrated cost model — fit convergence + drift recovery.

Closing the sim-to-measured loop (DESIGN.md §10): every plan in the repo is
priced by analytic ring formulas over a hand-written ``Hardware`` table, so
a mis-set entry silently mis-routes batch splits, layer allocations,
serving partitions and kernel tiles at once.  :mod:`repro.core.calibrate`
re-fits the table from timing observations; this benchmark shows the two
halves of the story on the deterministic simulated clock:

**(a) calibration error shrinks with observed steps.**  A ground-truth
``Hardware`` differs from the prior table by 0.7–1.35× per entry;
observations are synthesized from the analytic formulas on the truth with
5% multiplicative jitter.  ``calibrate.fit`` over growing step prefixes
recovers the true rates — the headline gate is the final max-parameter
error and the predicted-vs-measured step-cost error, both ≤ 10%.

**(b) continuous rebalance recovers a drifting cluster; one-shot stays
degraded.**  On 8×V100 + 8×T4, the V100 group's effective throughput ramps
*slowly* down to 0.35× (thermal degradation): every individual step stays
inside the straggler monitor's outlier band (the EMA tracks the ramp), so
the PR 5 one-shot controller never fires and rides the degradation with
its stale batch shares.  The continuous arm watches predicted-vs-measured
skew, re-fits the drifting group's table from profiler observations, and
re-plans its batch shares with measured rates — paying an explicit
checkpoint-restore + re-jit downtime per rebalance.

Headline metrics (BENCH_PR8.json via benchmarks/bench_ci.py):

- ``calibration_error_final`` ≤ 0.10 (part a, max parameter error);
- ``stepcost_error_final``    ≤ 0.10 (part a, step-cost prediction error);
- ``continuous_vs_oneshot``   ≥ 1.3  (part b, throughput ratio);
- ``drift_fit_error``         ≤ 0.10 (part b, fitted vs true rates at end).

Output: CSV rows ``fig_calibration,a,<n_steps>,...`` and
``fig_calibration,b,<arm>,...``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.calibrate import (Observation, fit, parameter_error,
                                  synthesize_observations)
from repro.core.cost_model import (StrategySpec, T4_16G, V100_PAPER,
                                   hardware_reciprocals, step_cost, step_cost_features)
from repro.core.hetero import price_batch_shares
from repro.models.lm import model_graph
from repro.runtime.elastic import HostTopology, SimHost, search_cluster
from repro.runtime.faults import SimClock
from repro.runtime.profiler import Profiler
from repro.runtime.straggler import HostStragglerAggregator

from benchmarks.fig7_heterogeneous import bert_large_cfg

OVERLAP = 0.5
SEARCH_KW = {"max_pp": 1}

# ---- part (a): fit convergence ------------------------------------------
NOISE = 0.05
PREFIXES = (2, 4, 8, 16, 32, 64, 128)

# ---- part (b): drifting-skew scenario -----------------------------------
# downtime per rebalance: checkpoint restore + re-jit, same accounting as
# fig_elastic so the continuous arm pays for every re-plan
DISK_BW = 1.0e9
RECOMPILE_S = 60.0
N_STEPS = 6000
DRIFT_START, DRIFT_END = 250, 750       # slow ramp: ~0.2%/step — under the
DRIFT_TO = 0.35                         # straggler monitor's outlier band
JITTER = 0.02
SKEW_TRIGGER = 0.15                     # measured > (1+skew)·predicted …
SKEW_PATIENCE = 5                       # … sustained this many steps
FIT_WINDOW = 160                        # profiler observations per fit
MAX_RECALIBRATIONS = 12                 # ~64 s downtime each, <5% of wall


def _truth_table():
    """Ground truth vs the V100 prior: every rate entry mis-set."""
    prior = V100_PAPER
    truth = dataclasses.replace(
        prior, peak_flops=prior.peak_flops * 0.7, hbm_bw=prior.hbm_bw * 1.35,
        link_bw={"fast": prior.link_bw["fast"] * 0.8,
                 "slow": prior.link_bw["slow"] * 1.3})
    return prior, truth


def calibration_curve():
    """Part (a): fit over growing observation prefixes → error rows."""
    prior, truth = _truth_table()
    cfg = bert_large_cfg()
    meta = model_graph(cfg, 192, 128).workload_meta()
    strat = StrategySpec(dp=4, tp=2)
    obs = synthesize_observations(meta, strat, truth, n_steps=max(PREFIXES),
                                  noise=NOISE, seed=3)
    t_true = step_cost(meta, strat, truth, overlap=0.0).total
    assert np.isfinite(t_true)

    def stepcost_err(hw):
        return abs(step_cost(meta, strat, hw, overlap=0.0).total
                   - t_true) / t_true

    rows = []
    for n in PREFIXES:
        fitted = fit([o for o in obs if o.step < n], prior)
        rows.append({"n_steps": n,
                     "param_error": parameter_error(fitted, truth),
                     "stepcost_error": stepcost_err(fitted)})
    return {"prior_param_error": parameter_error(prior, truth),
            "prior_stepcost_error": stepcost_err(prior),
            "curve": rows}


# -------------------------------------------------------------------------
# part (b)
# -------------------------------------------------------------------------


def _topology():
    return HostTopology(hosts=(
        SimHost(0, V100_PAPER, 4), SimHost(1, V100_PAPER, 4),
        SimHost(2, T4_16G, 4), SimHost(3, T4_16G, 4)))


def _drift_mult(step: int) -> float:
    """V100 effective-throughput multiplier at ``step`` (1 → DRIFT_TO)."""
    if step <= DRIFT_START:
        return 1.0
    if step >= DRIFT_END:
        return DRIFT_TO
    frac = (step - DRIFT_START) / (DRIFT_END - DRIFT_START)
    return 1.0 + frac * (DRIFT_TO - 1.0)


def _true_spec(nominal, step: int):
    """The cluster's *actual* rates at ``step``: V100 compute drifted."""
    groups = []
    for g in nominal.groups:
        if g.hw.name == V100_PAPER.name:
            hw = dataclasses.replace(
                g.hw, mxu_eff=g.hw.mxu_eff * _drift_mult(step))
            groups.append(dataclasses.replace(g, hw=hw))
        else:
            groups.append(g)
    return dataclasses.replace(nominal, groups=tuple(groups))


def _plan(meta, spec):
    """Search ``spec`` → (strategy, batch shares, predicted step time)."""
    cand = search_cluster(meta, spec, overlap=OVERLAP, search_kw=SEARCH_KW)
    shares = (cand.placement.batch_shares if cand.placement
              else (meta.batch,))
    return cand.strategy, shares, float(cand.total)


def _jitter(seed: int, step: int, host: int) -> float:
    rng = np.random.default_rng((seed * 1_000_003 + step) * 1_000_003 + host)
    return max(1.0 + JITTER * float(rng.standard_normal()), 0.1)


def _measure(meta, strat, shares, nominal, topo, step: int, seed: int):
    """One synchronous step on the true (drifted) cluster.

    Returns per-host wall times and the per-group
    (compute, link-fast, link-slow, features) decomposition used by the
    continuous arm's profiler — the simulated stand-in for per-collective
    timers + HLO byte counts on a real fleet.
    """
    true_spec = _true_spec(nominal, step)
    units, extra = price_batch_shares(meta, strat, true_spec, shares,
                                      overlap=OVERLAP)
    members = topo.group_hosts()
    host_times, decomp = {}, {}
    for u in units:
        g = u.group
        t_g = u.cost.compute + u.cost.comm + extra
        feats = step_cost_features(u.meta, u.strategy, g.hw, overlap=OVERLAP)
        recips = hardware_reciprocals(g.hw)
        decomp[g.name] = (feats, recips)
        for h in members.get(g.name, ()):
            host_times[h] = t_g * _jitter(seed, step, h)
    return host_times, decomp


def _record(profiler: Profiler, decomp: dict, meta, step: int, seed: int):
    """Per-group decomposed observations (jittered truth components)."""
    kb = float(meta.act_bytes_per_layer)
    for i, (gname, (feats, recips)) in enumerate(sorted(decomp.items())):
        j = _jitter(seed + 7, step, i)
        profiler.record_compute(gname, feats["eff_flops"]
                                * recips["eff_flops"] * j,
                                feats["eff_flops"], step=step)
        for p in ("link_fast", "link_slow"):
            if feats[p] > 0.0:
                # features are already ring-effective bytes, so record the
                # Observation directly rather than via record_collective
                # (which would re-apply the ring factor)
                profiler.record(Observation(
                    "collective", gname,
                    feats[p] * recips[p] * _jitter(seed + 11, step, i),
                    {p: feats[p]}, step))
        profiler.record_kernel(gname, kb,
                               kb * recips["hbm_bw"]
                               * _jitter(seed + 13, step, i), step=step)


def simulate_oneshot(meta, topo, seed: int = 0) -> dict:
    """PR 5 behaviour: straggler aggregator + one-shot eviction only."""
    nominal = topo.cluster_spec()
    strat, shares, _ = _plan(meta, nominal)
    agg = HostStragglerAggregator(n_hosts=len(topo.hosts), threshold=2.0,
                                  patience=3, warmup=5)
    agg.reset(topo.host_ids)
    clock = SimClock()
    evictions = []
    for step in range(N_STEPS):
        times, _ = _measure(meta, strat, shares, nominal, topo, step, seed)
        clock.advance(times)
        flagged = agg.observe(times)
        for h in flagged:
            if len(topo.hosts) <= 1 or len(evictions) >= 2:
                continue
            agg.evict(h)
            topo = topo.without({h})
            nominal = topo.cluster_spec()
            strat, shares, _ = _plan(meta, nominal)
            agg.reset(topo.host_ids)
            clock.charge(3 * meta.param_bytes / DISK_BW + RECOMPILE_S)
            evictions.append(step)
    return {"throughput": N_STEPS * meta.batch / clock.t,
            "wall_s": clock.t, "evictions": evictions}


def simulate_continuous(meta, topo, seed: int = 0) -> dict:
    """Drift-triggered recalibration: re-fit rates, re-plan shares."""
    nominal = topo.cluster_spec()
    believed = nominal
    strat, shares, predicted = _plan(meta, believed)
    profiler = Profiler()
    clock = SimClock()
    recals, hot = [], 0
    recent: list = []
    for step in range(N_STEPS):
        times, decomp = _measure(meta, strat, shares, nominal, topo, step,
                                 seed)
        clock.advance(times)
        _record(profiler, decomp, meta, step, seed)
        recent.append(max(times.values()))
        del recent[:-SKEW_PATIENCE]
        skew = (sum(recent) / len(recent)) / predicted
        hot = hot + 1 if skew > 1.0 + SKEW_TRIGGER else 0
        if hot >= SKEW_PATIENCE and len(recals) < MAX_RECALIBRATIONS:
            believed, fits = profiler.fit_spec(nominal, last_n=FIT_WINDOW)
            strat, shares, predicted = _plan(meta, believed)
            clock.charge(3 * meta.param_bytes / DISK_BW + RECOMPILE_S)
            recals.append({"step": step, "skew": skew,
                           "shares": tuple(shares)})
            hot = 0
            recent.clear()
    # fitted-vs-true rates of the drifted group at the end of the run
    fitted_end, _ = profiler.fit_spec(nominal, last_n=FIT_WINDOW)
    true_end = _true_spec(nominal, N_STEPS)
    drift_err = max(parameter_error(gf.hw, gt.hw)
                    for gf, gt in zip(fitted_end.groups, true_end.groups))
    return {"throughput": N_STEPS * meta.batch / clock.t,
            "wall_s": clock.t, "recalibrations": recals,
            "drift_fit_error": drift_err, "final_shares": tuple(shares)}


def drift_scenario(seed: int = 0) -> dict:
    cfg = bert_large_cfg()
    topo = _topology()
    # large per-device batch → compute-dominated steps, so the stale batch
    # shares actually hurt (at small batches the share-independent in-group
    # DP all-reduce dominates and mis-splitting is almost free)
    meta = model_graph(cfg, 256 * sum(h.n_devices for h in topo.hosts), 128).workload_meta()
    one = simulate_oneshot(meta, _topology(), seed)
    cont = simulate_continuous(meta, _topology(), seed)
    return {"oneshot": one, "continuous": cont,
            "continuous_vs_oneshot": cont["throughput"] / one["throughput"]}


def main(csv: bool = True, strict: bool = True) -> dict:
    """``strict=False`` (bench_ci) skips the hard asserts so the gate can
    record regressed metrics in the JSON artifact instead of raising."""
    a = calibration_curve()
    b = drift_scenario()
    if csv:
        print("table,part,key,param_error,stepcost_error")
        print(f"fig_calibration,a,prior,{a['prior_param_error']:.4f},"
              f"{a['prior_stepcost_error']:.4f}")
        for r in a["curve"]:
            print(f"fig_calibration,a,n={r['n_steps']},"
                  f"{r['param_error']:.4f},{r['stepcost_error']:.4f}")
        print("table,part,arm,samples_per_s,rebalances,drift_fit_error")
        print(f"fig_calibration,b,oneshot,{b['oneshot']['throughput']:.2f},"
              f"{len(b['oneshot']['evictions'])},")
        print(f"fig_calibration,b,continuous,"
              f"{b['continuous']['throughput']:.2f},"
              f"{len(b['continuous']['recalibrations'])},"
              f"{b['continuous']['drift_fit_error']:.4f}")
    final = a["curve"][-1]
    metrics = {
        "calibration_error_initial": a["prior_param_error"],
        "calibration_error_final": final["param_error"],
        "stepcost_error_prior": a["prior_stepcost_error"],
        "stepcost_error_final": final["stepcost_error"],
        "continuous_vs_oneshot": b["continuous_vs_oneshot"],
        "drift_fit_error": b["continuous"]["drift_fit_error"],
        "oneshot_evictions": len(b["oneshot"]["evictions"]),
        "continuous_rebalances": len(b["continuous"]["recalibrations"]),
        "curve": a["curve"],
        "drift": b,
    }
    if strict:
        assert final["param_error"] <= 0.10, \
            f"calibration error {final['param_error']:.3f} > 10%"
        assert final["stepcost_error"] <= 0.10, \
            f"step-cost error {final['stepcost_error']:.3f} > 10%"
        assert final["param_error"] < a["prior_param_error"] / 2, \
            "calibration barely improved on the prior"
        assert b["continuous_vs_oneshot"] >= 1.3, \
            f"continuous only {b['continuous_vs_oneshot']:.2f}× one-shot"
        assert metrics["continuous_rebalances"] >= 1, \
            "continuous arm never rebalanced"
        assert metrics["drift_fit_error"] <= 0.10, \
            f"drifted-group fit error {metrics['drift_fit_error']:.3f} > 10%"
    if csv:
        print(f"# headline: calibration error "
              f"{a['prior_param_error']:.2f} → {final['param_error']:.3f} "
              f"({max(PREFIXES)} steps); continuous rebalance "
              f"{b['continuous_vs_oneshot']:.2f}× one-shot on the "
              f"drifting-skew scenario "
              f"({metrics['continuous_rebalances']} recalibrations vs "
              f"{metrics['oneshot_evictions']} evictions)")
    return metrics


if __name__ == "__main__":
    main()

"""Spot-fleet membership churn — drain-and-grow vs restart-from-checkpoint.

Whale's resource adaptability (§5) is bidirectional, and spot capacity is
where both directions meet: the scheduler reclaims hosts mid-job (with a
short warning) and re-admits capacity later.  Two recovery disciplines:

- **restart**: the job is fleet-rigid — it needs all N hosts.  On the
  reclaim warning it commits a checkpoint (credited — the generous
  baseline), then idles the whole outage window, restores + re-jits when
  the fleet is whole again, and redoes nothing (it never trained during
  the outage).
- **drain-and-grow**: the membership controller (DESIGN.md §12) drains
  within the warning deadline, sheds the reclaimed hosts, re-plans on the
  survivors and *keeps training* through the outage at the smaller
  fleet's pace; when the capacity re-joins, the same
  ``apply_membership_change`` path grows the topology back
  (``HostTopology.with_host`` — the re-admitted hosts reclaim their
  vacated device ranges) and the run resumes at the full-fleet pace.

Both arms play on the deterministic simulated clock
(:mod:`repro.runtime.faults`) with step times from the analytic cost
model and the reclaim/re-admit signals from the injector's scenario
playback (:meth:`FaultInjector.membership`) — the same machinery the live
controller consumes, minus the jax execution, so it is CI-gateable.

Headline metrics (recorded in BENCH_PR10.json by benchmarks/bench_ci.py):

- ``drain_vs_restart``: end-to-end throughput ratio (floor 1.3);
- ``grow_recovery``: predicted full-fleet step time / achieved post-grow
  mean — after re-admission the run lands back on the cost model's
  full-fleet prediction (∈ [0.9, 1.1]);
- ``post_grow_vs_initial``: the re-grown plan's predicted step cost vs
  the never-preempted plan's — the round trip must end within 5% of
  where it started.

Scenarios cover a homogeneous pool (2 of 8 V100 hosts reclaimed) and a
mixed pool where the *T4 spot* hosts are reclaimed, so the survivors are
homogeneous and re-admission re-enters the heterogeneous placement.

Output: CSV rows ``fig_spot,<scenario>,<arm>,...``.
"""
from __future__ import annotations

import dataclasses
import statistics

from repro.core.cost_model import T4_16G, V100_PAPER
from repro.models.lm import model_graph
from repro.runtime.elastic import HostTopology, SimHost, search_cluster
from repro.runtime.faults import FaultInjector, SimClock, SpotPreemption

from benchmarks.fig7_heterogeneous import bert_large_cfg

# downtime paid at each re-plan: restore params+optimizer from the
# checkpoint store and re-jit — charged on the simulated clock so the
# drain arm pays for BOTH its rebalances (shed and grow)
DISK_BW = 1.0e9               # checkpoint-store read bandwidth, B/s
RECOMPILE_S = 60.0            # re-jit on the re-planned mesh
N_STEPS = 2000
WARN_AT = 200                 # the reclaim warning lands at this step
DEADLINE_STEPS = 2            # …and the hosts vanish this many steps later
OUTAGE_STEPS = 1200           # survivor steps until the capacity re-joins


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    topology: HostTopology
    spot_hosts: tuple          # host ids the scheduler reclaims
    per_device_batch: int = 24
    seq: int = 128


SCENARIOS = (
    # homogeneous pool: 2 of 8 V100 hosts are reclaimed and later re-join
    Scenario("8hostx4xV100", HostTopology.uniform(8, 4, V100_PAPER),
             spot_hosts=(6, 7)),
    # mixed pool: the T4 *spot* hosts are reclaimed — survivors are pure
    # V100, and re-admission re-enters the heterogeneous balanced
    # placement on the grow path
    Scenario("6x4xV100+2x4xT4",
             HostTopology(hosts=tuple(
                 [SimHost(h, V100_PAPER, 4) for h in range(6)]
                 + [SimHost(6, T4_16G, 4), SimHost(7, T4_16G, 4)])),
             spot_hosts=(6, 7)),
)

# live re-plans stay in the checkpoint's non-pipelined parameter layout
# (same constraint the membership controller applies)
SEARCH_KW = {"max_pp": 1}


def _plan_step_time(meta, spec) -> float:
    return float(search_cluster(meta, spec, overlap=0.5,
                                search_kw=SEARCH_KW).total)


def _downtime(meta) -> float:
    return 3 * meta.param_bytes / DISK_BW + RECOMPILE_S


def simulate_drain(sc: Scenario, *, n_steps: int = N_STEPS) -> dict:
    """Drain-and-grow arm: shed on the warning, train through the outage
    on the survivors, grow back when the capacity re-joins."""
    cfg = bert_large_cfg()
    topo = sc.topology
    meta = model_graph(cfg, sc.per_device_batch * topo.n_devices,
                       sc.seq).workload_meta()
    injector = FaultInjector(
        scenarios=tuple(SpotPreemption(host=h, warn_step=WARN_AT,
                                       deadline_steps=DEADLINE_STEPS)
                        for h in sc.spot_hosts),
        n_hosts=len(topo.hosts), seed=7)
    lost = {h.host: dataclasses.replace(h, offset=-1) for h in topo.hosts
            if h.host in sc.spot_hosts}
    t_full = _plan_step_time(meta, topo.cluster_spec())
    t_step = t_full
    clock = SimClock()
    events = []
    warn_wall = rejoin_wall = None
    rejoin_step = None
    post_grow = []
    for step in range(n_steps):
        # the injector's one-shot membership playback, grounded against
        # the live topology exactly like the controller's InjectorSource
        shed = [s.host for kind, s in injector.membership(step)
                if kind == "preempt_warn" and s.host in topo.host_ids]
        if shed:
            warn_wall = clock.t
            topo = topo.without(set(shed))
            t_step = _plan_step_time(meta, topo.cluster_spec())
            clock.charge(_downtime(meta))
            rejoin_step = step + OUTAGE_STEPS
            events.append({"kind": "evict", "step": step, "hosts": shed,
                           "predicted_step_s": t_step})
        if rejoin_step is not None and step == rejoin_step:
            rejoin_wall = clock.t
            for h in sorted(lost):
                # offset -1: with_host's first-fit placement reclaims the
                # device ranges the eviction vacated
                topo = topo.with_host(lost[h])
            t_step = _plan_step_time(meta, topo.cluster_spec())
            clock.charge(_downtime(meta))
            events.append({"kind": "join", "step": step,
                           "hosts": sorted(lost),
                           "predicted_step_s": t_step})
            post_grow = []
        times = injector.host_times(step, base=t_step, hosts=topo.host_ids)
        clock.advance(times)
        if events and events[-1]["kind"] == "join":
            post_grow.append(max(times.values()))
    return {
        "throughput": n_steps / clock.t,
        "wall_s": clock.t,
        "events": events,
        "t_full": t_full,
        "t_regrown": t_step,
        "outage_wall_s": (rejoin_wall - warn_wall
                          if rejoin_wall is not None else None),
        "post_grow_mean": (statistics.fmean(post_grow)
                           if post_grow else None),
        "topology": topo,
    }


def simulate_restart(sc: Scenario, *, outage_wall_s: float,
                     n_steps: int = N_STEPS) -> dict:
    """Fleet-rigid arm: checkpoint on the warning (credited), idle the
    outage, restore + re-jit, finish on the whole fleet."""
    cfg = bert_large_cfg()
    topo = sc.topology
    meta = model_graph(cfg, sc.per_device_batch * topo.n_devices,
                       sc.seq).workload_meta()
    injector = FaultInjector(scenarios=(), n_hosts=len(topo.hosts), seed=7)
    t_full = _plan_step_time(meta, topo.cluster_spec())
    clock = SimClock()
    for step in range(WARN_AT):
        clock.advance(injector.host_times(step, base=t_full,
                                          hosts=topo.host_ids))
    # warning checkpoint is free (generous baseline); the job then idles
    # the same wall window the drain arm trained through, and pays the
    # restore + re-jit the drain arm also paid
    clock.charge(outage_wall_s)
    clock.charge(_downtime(meta))
    for step in range(WARN_AT, n_steps):
        clock.advance(injector.host_times(step, base=t_full,
                                          hosts=topo.host_ids))
    return {"throughput": n_steps / clock.t, "wall_s": clock.t}


def rows(strict: bool = True) -> list:
    out = []
    for sc in SCENARIOS:
        drain = simulate_drain(sc)
        evicts = [e for e in drain["events"] if e["kind"] == "evict"]
        joins = [e for e in drain["events"] if e["kind"] == "join"]
        if strict:
            assert evicts and sorted(evicts[0]["hosts"]) == \
                sorted(sc.spot_hosts), f"{sc.name}: wrong hosts shed"
            assert evicts[0]["step"] < WARN_AT + DEADLINE_STEPS, \
                f"{sc.name}: drain missed the reclaim deadline"
            assert joins, f"{sc.name}: capacity never re-admitted"
            assert drain["topology"].host_ids == sc.topology.host_ids, \
                f"{sc.name}: round trip did not restore the fleet"
        restart = simulate_restart(sc,
                                   outage_wall_s=drain["outage_wall_s"])
        # no join (grow broke) → recovery 0.0: the gate's floor fails
        # loudly with the metric recorded instead of a traceback
        recovery = (drain["t_full"] / drain["post_grow_mean"]
                    if drain["post_grow_mean"] else 0.0)
        out.append({
            "scenario": sc.name,
            "restart_throughput": restart["throughput"],
            "drain_throughput": drain["throughput"],
            "drain_vs_restart": (drain["throughput"]
                                 / restart["throughput"]),
            "grow_recovery": recovery,
            "post_grow_vs_initial": drain["t_regrown"] / drain["t_full"],
            "shed_step": evicts[0]["step"] if evicts else -1,
            "rejoin_step": joins[0]["step"] if joins else -1,
            "predicted_ms": drain["t_full"] * 1e3,
            "achieved_ms": (drain["post_grow_mean"] or 0.0) * 1e3,
        })
    return out


def main(csv: bool = True, strict: bool = True) -> dict:
    """``strict=False`` (bench_ci) skips the hard asserts so the gate can
    record the regressed metrics in the JSON artifact and report them
    through its own floor/ceiling machinery instead of a raw traceback."""
    rs = rows(strict=strict)
    if csv:
        print("table,scenario,arm,steps_per_s,shed_step,rejoin_step,"
              "predicted_ms,achieved_ms,recovery")
        for r in rs:
            print(f"fig_spot,{r['scenario']},restart,"
                  f"{r['restart_throughput']:.2f},,,,,")
            print(f"fig_spot,{r['scenario']},drain-grow,"
                  f"{r['drain_throughput']:.2f},{r['shed_step']},"
                  f"{r['rejoin_step']},{r['predicted_ms']:.1f},"
                  f"{r['achieved_ms']:.1f},{r['grow_recovery']:.3f}")
    speedup = min(r["drain_vs_restart"] for r in rs)
    recovery = min(r["grow_recovery"] for r in rs)
    recovery_max = max(r["grow_recovery"] for r in rs)
    regrown = max(r["post_grow_vs_initial"] for r in rs)
    if strict:
        # draining through the outage must beat idling it out on every
        # scenario; post-grow throughput must land on the full-fleet
        # cost-model prediction; and the re-grown plan must price within
        # 5% of the never-preempted plan (the round trip is lossless)
        assert speedup >= 1.3, \
            f"drain-and-grow only {speedup:.3f}× restart (< 1.3)"
        for r in rs:
            assert 0.9 <= r["grow_recovery"] <= 1.1, \
                f"{r['scenario']}: post-grow throughput " \
                f"{r['achieved_ms']:.1f}ms off the predicted " \
                f"{r['predicted_ms']:.1f}ms"
            assert r["post_grow_vs_initial"] <= 1.05, \
                f"{r['scenario']}: re-grown plan prices " \
                f"{r['post_grow_vs_initial']:.3f}× the original"
    if csv:
        print(f"# headline: drain-and-grow ≥{speedup:.2f}× "
              f"restart-from-checkpoint; post-grow within "
              f"{abs(1-recovery)*100:.1f}% of the full-fleet prediction")
    return {
        "drain_vs_restart_speedup": speedup,
        "grow_recovery": recovery,
        "grow_recovery_max": recovery_max,
        "post_grow_vs_initial": regrown,
        "per_scenario": {r["scenario"]: r for r in rs},
    }


if __name__ == "__main__":
    main()

"""Benchmark suite entry: one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--skip-measured]

Sections:
  fig2      Bert-Large HDP vs Whale DP vs Whale pipeline (paper Fig. 2)
            + the schedule grid: even/uneven stages × GPipe/1F1B with
            bubble-fraction and peak-stage-memory columns
  fig5      100k-class DP vs DP+split hybrid             (paper Fig. 5)
  fig7      hardware-aware vs naive split on mixed GPUs  (paper §5)
  fig9      M6 recipe: nested replica{split[experts]} vs flat DP (paper §4)
  fig10     M6 multimodal: segment-aware auto-search vs hand-even
            pipeline split on mixed V100+T4               (paper §5.3)
  elastic   self-healing straggler eviction vs naive        (paper §5)
  spot      spot-fleet drain-and-grow vs restart-from-checkpoint
            (DESIGN.md §12)
  serve     paged + disaggregated serving vs dense colocated (DESIGN.md §9)
  calibration  profile-calibrated cost model + drift-triggered
            rebalance vs one-shot                        (DESIGN.md §10)
  kernels   Pallas kernel numerics vs oracle + VMEM budget
  roofline  per-(arch × shape × mesh) table from the dry-run JSONL

The CI regression gate over the analytic sections is benchmarks/bench_ci.py
(writes BENCH_PR10.json, fails below the recorded floors).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="cost-model/static sections only (fast)")
    args = ap.parse_args()

    t0 = time.time()
    print("=" * 72)
    print("== fig2: Bert-Large pipeline (paper Fig. 2) ==")
    import benchmarks.fig2_bert_pipeline as fig2
    if args.skip_measured:
        rows = fig2.model_rows()
        print("table,system,gpus,ms_per_step,speedup_vs_hdp")
        for gpus, hdp, wdp, wpipe in rows:
            print(f"fig2,horovod-dp,{gpus},{hdp*1e3:.1f},1.0")
            print(f"fig2,whale-pipeline,{gpus},{wpipe*1e3:.1f},"
                  f"{hdp/wpipe:.2f}")
        print(f"# headline: {rows[-1][1]/rows[-1][3]:.2f}× @64 "
              f"(paper: 2.32×)")
        print("-- schedule grid: even/uneven × gpipe/1f1b --")
        fig2.print_schedule_grid(fig2.schedule_grid_rows())
    else:
        fig2.main()

    print("=" * 72)
    print("== fig5: 100k-class hybrid (paper Fig. 5) ==")
    import benchmarks.fig5_classification as fig5
    fig5.main()

    print("=" * 72)
    print("== fig7: heterogeneous hardware-aware balancing (paper §5) ==")
    import benchmarks.fig7_heterogeneous as fig7
    fig7.main()

    print("=" * 72)
    print("== fig9: nested DP×EP MoE — the M6 recipe (paper §4) ==")
    import benchmarks.fig9_m6_moe as fig9
    fig9.main()

    print("=" * 72)
    print("== fig10: segment-aware auto-search on M6 multimodal (§5.3) ==")
    import benchmarks.fig10_multimodal as fig10
    fig10.main()

    print("=" * 72)
    print("== elastic: self-healing eviction vs naive straggler (§5) ==")
    import benchmarks.fig_elastic as fig_elastic
    fig_elastic.main()

    print("=" * 72)
    print("== spot: drain-and-grow vs restart-from-checkpoint (§12) ==")
    import benchmarks.fig_spot as fig_spot
    fig_spot.main()

    print("=" * 72)
    print("== serve: paged + disaggregated vs dense colocated (§9) ==")
    import benchmarks.fig_serve as fig_serve
    fig_serve.main()

    print("=" * 72)
    print("== calibration: fitted cost model + drift rebalance (§10) ==")
    import benchmarks.fig_calibration as fig_cal
    fig_cal.main()

    print("=" * 72)
    print("== kernels: Pallas vs oracle ==")
    import benchmarks.kernel_bench as kb
    kb.main()

    print("=" * 72)
    print("== roofline (from dry-run artifacts) ==")
    import benchmarks.roofline as rl
    rl.main([])

    print("=" * 72)
    print(f"benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

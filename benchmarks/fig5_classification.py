"""Paper Fig. 5 — 100,000-class classification: Whale DP vs DP + op split.

The paper's setting (§3.2): ResNet-50 features (~90M params) + a 100k-way
FC+softmax head (~782M params).  Under pure DP every GPU all-reduces the
782M-param head's gradients over 35 Gb/s Ethernet *and* burns GPU memory on
the replicated head + the (B, 100k) logits, capping the per-GPU batch.  The
hybrid (Case 2) replicates the features, splits the head over the GPUs —
head gradients never cross devices, the loss stays sharded (Fig 4), and the
freed memory allows a much larger mini-batch ("We could tune the total
mini-batch size to get more performance gains" — §3.2).  Measured headline:
14.8× at 64 GPUs.

This harness reproduces the effect with the meta-driven cost model, using
memory feasibility to pick each strategy's max per-GPU batch (powers of two,
as one would in practice), then compares samples/sec.  A small measured
CPU-device run of the actual Case-2 program (examples/classification_split)
covers the executable path.

Output CSV: ``fig5,<system>,<gpus>,<batch_per_gpu>,<samples_per_s>,<speedup>``.
"""
from __future__ import annotations

from repro.core.cost_model import (ModelGraph, SegmentMeta, StrategySpec,
                                   V100_PAPER, WorkloadMeta, step_cost,
                                   throughput)

RESNET_FLOPS = 4.1e9            # fwd FLOPs per 224×224 image
FEAT_PARAMS = 90e6
HEAD_PARAMS = 782e6             # 2048 → ~382k??  paper: 782M ≈ 2048 × 100k ×4
N_CLASSES = 100_000
FEAT_DIM = 2048                 # resnet50 pool dim (782M/100k ≈ 7.8k? paper's
                                # head counts fc+softmax aux — we take theirs)


ACT_BYTES_PER_IMG_LAYER = 3e6   # ≈150 MB fp32 activations/image over ~50
                                # layers — the standard ResNet-50 footprint


def classification_graph(batch: int) -> ModelGraph:
    """The paper's workload as a single-segment ModelGraph: the ResNet
    feature tower is the (pipelineable) segment; the 100k-way head is
    priced like an LM head — extra flops + non-layer params + logits."""
    return ModelGraph(
        name="resnet50-100k",
        segments=(SegmentMeta(
            name="resnet50", n_layers=50,
            fwd_flops=RESNET_FLOPS * batch,
            param_bytes=FEAT_PARAMS * 4,
            act_bytes_per_layer=batch * ACT_BYTES_PER_IMG_LAYER),),
        batch=batch,
        extra_fwd_flops=2 * batch * FEAT_DIM * N_CLASSES,
        extra_param_bytes=HEAD_PARAMS * 4,
        logits_bytes=batch * N_CLASSES * 4,
        head_param_bytes=HEAD_PARAMS * 4,
        opt_state_factor=1.0,          # SGD + momentum (classification)
        # only the head splits: fc+softmax over the class dim
        tp_shardable_fraction=HEAD_PARAMS / (FEAT_PARAMS + HEAD_PARAMS),
    )


def classification_meta(batch: int) -> WorkloadMeta:
    return classification_graph(batch).workload_meta()


def max_feasible_batch(gpus: int, strat_of, cap: int = 128) -> int:
    best = 0
    b = 1
    while b <= cap:
        meta = classification_meta(b * gpus)
        c = step_cost(meta, strat_of(gpus), V100_PAPER, overlap=0.5)
        if c.feasible:
            best = b
        b *= 2
    return best


# ---------------------------------------------------------------------------
# the hybrid is a PER-SUBGRAPH strategy (Whale's whole point): the feature
# extractor is replica'd over all GPUs while the head is split over all
# GPUs.  A uniform (dp, tp) spec cannot express that, so its cost is
# assembled from the cost model's collective primitives per subgraph.
# ---------------------------------------------------------------------------

from repro.core.cost_model import all_gather_time, all_reduce_time  # noqa: E402


def hybrid_step_cost(per_gpu_batch: int, gpus: int, hw=V100_PAPER,
                     overlap: float = 0.5):
    """Case 2: replica(features) over all GPUs + split(head) over all GPUs."""
    B = per_gpu_batch * gpus
    eth = hw.bw_for_axis("data")
    eff = hw.peak_flops * hw.mxu_eff
    # feature subgraph: DP compute + 90M-param gradient all-reduce
    t_feat = per_gpu_batch * RESNET_FLOPS * 3 / eff
    t_feat_ar = all_reduce_time(FEAT_PARAMS * 4, gpus, eth) * (1 - overlap)
    # head subgraph: features all-gathered to every shard (fwd) + the
    # transposed grad scatter (bwd ≈ same bytes); head matmul split /gpus;
    # loss reductions are O(B) scalars (Fig 4) — negligible
    feats_bytes = B * FEAT_DIM * 4
    t_head_ag = 2 * all_gather_time(feats_bytes, gpus, eth)
    t_head = 3 * 2 * B * FEAT_DIM * N_CLASSES / gpus / eff
    t = t_feat + t_feat_ar + t_head_ag + t_head
    # memory: replicated features + sharded head + local activations/logits
    mem = (FEAT_PARAMS * 4 * 3 + HEAD_PARAMS * 4 * 3 / gpus
           + per_gpu_batch * ACT_BYTES_PER_IMG_LAYER * 50
           + B * N_CLASSES * 4 / gpus)
    return t, mem <= hw.hbm_bytes


def max_feasible_batch_hybrid(gpus: int, cap: int = 128) -> int:
    best = 0
    b = 1
    while b <= cap:
        if hybrid_step_cost(b, gpus)[1]:
            best = b
        b *= 2
    return best


def model_rows():
    rows = []
    dp_strat = lambda g: StrategySpec(dp=g, remat=False, vocab_split=False)
    for gpus in (8, 16, 32, 64):
        b_dp = max_feasible_batch(gpus, dp_strat)
        tp_dp = throughput(classification_meta(b_dp * gpus), dp_strat(gpus),
                           V100_PAPER, overlap=0.5)
        b_hy = max_feasible_batch_hybrid(gpus)
        t_hy, _ = hybrid_step_cost(b_hy, gpus)
        tp_hy = b_hy * gpus / t_hy
        rows.append((gpus, b_dp, tp_dp, b_hy, tp_hy))
    return rows


def main(csv=True) -> list:
    out = []
    for gpus, b_dp, tp_dp, b_hy, tp_hy in model_rows():
        out.append(("fig5", "whale-dp", gpus, b_dp, tp_dp, 1.0))
        out.append(("fig5", "whale-dp+split", gpus, b_hy, tp_hy,
                    tp_hy / max(tp_dp, 1e-9)))
    if csv:
        print("table,system,gpus,batch_per_gpu,samples_per_s,speedup_vs_dp")
        for r in out:
            print(",".join(f"{x:.1f}" if isinstance(x, float) else str(x)
                           for x in r))
        last = out[-1]
        print(f"# headline: dp+split @64 GPUs = {last[5]:.1f}× DP "
              f"(paper: 14.8×)")
    return out


if __name__ == "__main__":
    main()

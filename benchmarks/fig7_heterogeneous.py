"""Paper §5 / Fig. 7 — hardware-aware balancing on heterogeneous GPUs.

Whale's headline heterogeneity claim: on a cluster mixing GPU generations,
the hardware-aware strategy (micro-batch shares ∝ each group's effective
FLOP/s, pipeline stages sized so per-stage latency equalizes) clearly beats
the naive even split, which makes every synchronous step wait for the
slowest card.  The paper reports up to ~1.4× from balancing alone on mixed
V100/P100 pools.

This benchmark reproduces the claim from the analytic cost model
(meta-driven — nothing executes): a Bert-Large-class workload on clusters
mixing V100 with T4- and P100-class pods, comparing

- ``naive``:  even batch shares / even layer split (hardware-oblivious)
- ``aware``:  :func:`repro.core.hetero.plan_placement` balanced placement

for both balancing mechanisms (intra-stage DP batch split and inter-stage
pipeline layer allocation), plus the end-to-end auto-search over the mixed
cluster.  Sanity anchor: a homogeneous cluster must show speedup exactly
1.0 (the balanced placement reduces to the even split).

Output: CSV rows ``fig7,<mode>,<cluster>,<naive_ms>,<aware_ms>,<speedup>``.
"""
from __future__ import annotations

import dataclasses

from repro.core.auto import search
from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   StrategySpec, T4_16G, V100_PAPER)
from repro.core.hetero import plan_placement
from repro.models.lm import model_graph


def bert_large_cfg():
    from repro.configs import get_config
    return dataclasses.replace(
        get_config("stablelm-3b"), n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=4096, vocab=30522, norm="ln",
        act="gelu", gated_mlp=False, remat="none", name="bert-large")


CLUSTERS = {
    "8xV100+8xT4": ClusterSpec(groups=(
        DeviceGroup("v100", V100_PAPER, 8),
        DeviceGroup("t4", T4_16G, 8))),
    "8xV100+8xP100": ClusterSpec(groups=(
        DeviceGroup("v100", V100_PAPER, 8),
        DeviceGroup("p100", P100_16G, 8))),
    "12xV100+4xT4": ClusterSpec(groups=(
        DeviceGroup("v100", V100_PAPER, 12),
        DeviceGroup("t4", T4_16G, 4))),
    "16xV100(homog)": ClusterSpec.homogeneous(V100_PAPER, 16),
}


def compare(meta, strat, spec, overlap=0.5):
    """(naive_step_s, aware_step_s) for one strategy on one cluster."""
    naive = plan_placement(meta, strat, spec, overlap=overlap,
                           balanced=False)
    aware = plan_placement(meta, strat, spec, overlap=overlap)
    return naive, aware


def rows(per_gpu_batch: int = 24, seq: int = 128):
    cfg = bert_large_cfg()
    out = []
    for cname, spec in CLUSTERS.items():
        meta = model_graph(cfg, per_gpu_batch * spec.n_devices, seq).workload_meta()
        # mechanism 1: intra-stage DP batch balancing
        dp = StrategySpec(dp=spec.n_devices, remat=False, vocab_split=False)
        naive, aware = compare(meta, dp, spec)
        out.append(("dp-batch-split", cname, naive.step_time,
                    aware.step_time, aware))
        # mechanism 2: inter-stage pipeline layer balancing (4 stages)
        pp = StrategySpec(dp=spec.n_devices // 4, pp=4, micro_batches=4,
                          remat=False, vocab_split=False)
        naive, aware = compare(meta, pp, spec)
        out.append(("pipeline-layers", cname, naive.step_time,
                    aware.step_time, aware))
    return out


def auto_rows(per_gpu_batch: int = 24, seq: int = 128):
    """End-to-end: the auto-search picks a balanced strategy for the mix."""
    cfg = bert_large_cfg()
    out = []
    for cname, spec in CLUSTERS.items():
        meta = model_graph(cfg, per_gpu_batch * spec.n_devices, seq).workload_meta()
        cands = search(meta, spec, top_k=1, overlap=0.5)
        if cands:
            out.append((cname, cands[0].strategy.describe(),
                        cands[0].total, cands[0].placement))
    return out


def main(csv=True) -> list:
    out = []
    for mode, cname, t_naive, t_aware, aware in rows():
        out.append(("fig7", mode, cname, t_naive * 1e3, t_aware * 1e3,
                    t_naive / t_aware))
    if csv:
        print("table,mode,cluster,naive_ms,aware_ms,speedup")
        for r in out:
            print(f"{r[0]},{r[1]},{r[2]},{r[3]:.1f},{r[4]:.1f},{r[5]:.3f}")
        hetero = [r for r in out if "homog" not in r[2]]
        homog = [r for r in out if "homog" in r[2]]
        best = max(r[5] for r in hetero)
        print(f"# headline: hardware-aware up to {best:.2f}× over naive even "
              f"split on mixed clusters (paper §5: balanced > even)")
        # never-worse everywhere (the even split is in the balancer's search
        # space); strictly better on the headline mixed V100/T4 cluster.
        # Comm-bound memory-capped corners (12xV100+4xT4 pure DP on shared
        # Ethernet) legitimately tie: the all-reduce dominates and HBM caps
        # pin the shares at the even point.
        assert all(r[5] >= 1.0 - 1e-9 for r in hetero), \
            "hardware-aware must never lose to the naive split"
        headline = [r for r in out if r[2] == "8xV100+8xT4"]
        assert all(r[5] > 1.0 for r in headline), \
            "hardware-aware must beat the naive split on the mixed V100/T4 cluster"
        assert all(abs(r[5] - 1.0) < 1e-9 for r in homog), \
            "homogeneous cluster must reduce exactly to the even split"
        print("table,cluster,auto_strategy,step_ms,placement")
        for cname, desc, t, pl in auto_rows():
            print(f"fig7-auto,{cname},{desc},{t*1e3:.1f},{pl.describe() if pl else ''}")
    return out


if __name__ == "__main__":
    main()

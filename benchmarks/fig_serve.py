"""Serving tier: paged + disaggregated vs dense colocated (DESIGN.md §9).

Whale's thesis — put each phase of the workload on the hardware whose
roofline matches it — applied to *inference*: prefill is FLOPs-bound,
decode is HBM-bound, so on a mixed cluster the router sends prompts to
the compute-rich groups and decode to the bandwidth-rich ones
(:mod:`repro.serving.router`), and the decode pool runs the paged KV
cache so a step reads only the tokens actually cached instead of every
slot's ``max_len`` reservation.

Both arms play the *same* deterministic open-loop Pareto trace through
the analytic discrete-event simulator (:mod:`repro.serving.sim`) with
step times from the serving cost model — no jax execution, CI-gateable:

- **colocated dense**: every group runs prefill+decode, dense
  ``max_len``-per-slot cache, prefill blocks the group head-of-line.
- **disagg paged**: routed prefill pool → KV handoff over the slow link
  → paged decode pool with page-budget admission.

Headline gate (recorded in BENCH_PR7.json by benchmarks/bench_ci.py):
on the 8×V100 + 8×T4 flagship the disaggregated+paged arm must hold
``tokens/s ≥ 1.3×`` the colocated dense arm **with p99 TTFT no worse**.
The offered rate is set to ``UTILISATION ×`` the router's own predicted
sustainable rate, so the gate tracks the cost model and the simulator
together — a regression in either breaks it.

Output: CSV rows ``fig_serve,<scenario>,<arm>,...``.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core.cost_model import (ClusterSpec, DeviceGroup, T4_16G,
                                   V100_PAPER, lm_serving_meta)
from repro.serving.router import route
from repro.serving.sim import ServeScenario, compare
from repro.serving.traffic import TrafficCfg

UTILISATION = 0.8          # offered rate as a fraction of the routed capacity
N_REQUESTS = 400
PAGE_SIZE = 64


@dataclasses.dataclass(frozen=True)
class _Spec:
    name: str
    groups: tuple
    batch_slots: int
    max_len: int
    prompt_lens: tuple
    gen_lens: tuple
    gate: bool               # scenario participates in the ≥1.3× floor


SPECS = (
    # flagship: the paper's mixed pool — T4s are compute-rich per HBM byte
    # (prefill), V100s have 3× the memory bandwidth (decode)
    _Spec("8xV100+8xT4",
          (DeviceGroup("8xv100", V100_PAPER, 8),
           DeviceGroup("8xt4", T4_16G, 8)),
          batch_slots=64, max_len=4096,
          prompt_lens=(16, 32, 64, 128), gen_lens=(32, 64, 128), gate=True),
    # long-prompt mix: prefill-heavy traffic, same cluster — the dense
    # reservation pathology shrinks (prompts fill their slots), so this
    # only checks the tier holds parity on traffic it can't improve
    _Spec("8xV100+8xT4-longprompt",
          (DeviceGroup("8xv100", V100_PAPER, 8),
           DeviceGroup("8xt4", T4_16G, 8)),
          batch_slots=64, max_len=4096,
          prompt_lens=(256, 512, 1024), gen_lens=(16, 32), gate=False),
)


def scenarios() -> list:
    """Build each scenario's offered rate from its own routed capacity."""
    cfg = get_config("tinyllama-1.1b")
    meta = lm_serving_meta(cfg)
    out = []
    for sp in SPECS:
        spec = ClusterSpec(groups=sp.groups)
        mean_prompt = int(sum(sp.prompt_lens) / len(sp.prompt_lens))
        mean_gen = int(sum(sp.gen_lens) / len(sp.gen_lens))
        plan = route(meta, spec, mean_prompt=mean_prompt, mean_gen=mean_gen,
                     page_size=PAGE_SIZE, batch_slots=sp.batch_slots)
        tc = TrafficCfg(rate=UTILISATION * plan.request_rate,
                        n_requests=N_REQUESTS,
                        prompt_lens=sp.prompt_lens, gen_lens=sp.gen_lens)
        out.append((sp, ServeScenario(
            name=sp.name, spec=spec, traffic=tc,
            batch_slots=sp.batch_slots, page_size=PAGE_SIZE,
            max_len=sp.max_len)))
    return out


def rows() -> list:
    cfg = get_config("tinyllama-1.1b")
    meta = lm_serving_meta(cfg)
    out = []
    for sp, sc in scenarios():
        r = compare(meta, sc)
        r["gate"] = sp.gate
        out.append(r)
    return out


def main(csv: bool = True, strict: bool = True) -> dict:
    """``strict=False`` (bench_ci) skips the hard asserts so the gate can
    record regressed metrics in the JSON artifact and report them through
    its own floor machinery instead of a raw traceback."""
    rs = rows()
    if csv:
        print("table,scenario,arm,tokens_per_s,ttft_p50_ms,ttft_p99_ms,"
              "tpot_ms,completed")
        for r in rs:
            for arm in ("colocated", "disagg"):
                s = r[arm]
                print(f"fig_serve,{r['scenario']},{arm},"
                      f"{s['tokens_per_s']:.0f},"
                      f"{s['ttft_p50_s'] * 1e3:.1f},"
                      f"{s['ttft_p99_s'] * 1e3:.1f},"
                      f"{s['tpot_mean_s'] * 1e3:.2f},{s['completed']}")
            print(f"# {r['scenario']}: {r['plan']} — "
                  f"{r['tokens_per_s_ratio']:.2f}× tokens/s, "
                  f"p99 TTFT ratio {r['ttft_p99_ratio']:.2f}")
    gated = [r for r in rs if r["gate"]]
    speedup = min(r["tokens_per_s_ratio"] for r in gated)
    ttft_ratio = max(r["ttft_p99_ratio"] for r in gated)
    speedup_all = min(r["tokens_per_s_ratio"] for r in rs)
    if strict:
        for r in rs:
            assert r["colocated"]["completed"] == N_REQUESTS, \
                f"{r['scenario']}: colocated arm dropped requests"
            assert r["disagg"]["completed"] == N_REQUESTS, \
                f"{r['scenario']}: disagg arm dropped requests"
        assert speedup >= 1.3, \
            f"paged+disagg only {speedup:.2f}× dense colocated (need ≥1.3×)"
        assert ttft_ratio <= 1.0, \
            f"p99 TTFT regressed: {ttft_ratio:.2f}× the colocated arm"
        # prefill-heavy traffic fills its dense slots, so paging has
        # nothing to reclaim there — require parity (no collapse), not a win
        assert speedup_all >= 0.95, \
            f"non-flagship scenario collapsed vs colocated "\
            f"({speedup_all:.2f}×, need ≥0.95×)"
    if csv:
        print(f"# headline: paged+disagg ≥{speedup:.2f}× dense colocated "
              f"tokens/s on the flagship, p99 TTFT {ttft_ratio:.2f}× "
              f"(≤1.0 required)")
    return {
        "serve_tokens_per_s_ratio": speedup,
        "serve_ttft_p99_ratio": ttft_ratio,
        "serve_tokens_per_s_ratio_all": speedup_all,
        "per_scenario": {r["scenario"]: {
            "tokens_per_s_ratio": r["tokens_per_s_ratio"],
            "ttft_p99_ratio": r["ttft_p99_ratio"],
            "plan": r["plan"],
        } for r in rs},
    }


if __name__ == "__main__":
    main()

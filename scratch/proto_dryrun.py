import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs import shapes as sh
from repro.core.sharding import hybrid_rules, use_rules
from repro.launch.mesh import make_production_mesh
from repro.models.lm import build

arch = sys.argv[1] if len(sys.argv) > 1 else "tinyllama-1.1b"
shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
multi = len(sys.argv) > 3 and sys.argv[3] == "multi"

t0 = time.time()
mesh = make_production_mesh(multi_pod=multi)
cfg = get_config(arch)
model = build(cfg)
rules = hybrid_rules(mesh)
pshapes = model.param_shapes()
paxes = model.axes()
pspecs = rules.param_specs_tree(paxes, pshapes)
print("setup", time.time() - t0)

def report(compiled):
    ma = compiled.memory_analysis()
    print("argument bytes/dev:", ma.argument_size_in_bytes / 2**30, "GiB")
    print("temp bytes/dev:", ma.temp_size_in_bytes / 2**30, "GiB")
    print("output bytes/dev:", ma.output_size_in_bytes / 2**30, "GiB")
    print("flops:", compiled.cost_analysis().get("flops", None))
    import re
    txt = compiled.as_text()
    colls = {}
    for mm in re.finditer(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt):
        colls[mm.group(1)] = colls.get(mm.group(1), 0) + 1
    print("collective op counts:", colls)
    print("HLO len:", len(txt))


cell = sh.SHAPES[shape]
ns = lambda tree: jax.tree.map(lambda s: jax.NamedSharding(mesh, s), tree)
if cell.step == "prefill":
    specs = sh.batch_specs(model, cell)
    bspecs = {k: rules.spec_for(("batch",) + (None,) * (len(v.shape) - 1), v.shape)
              for k, v in specs.items()}

    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch, gen_budget=0)

    t0 = time.time()
    with use_rules(rules):
        lowered = jax.jit(prefill_step, in_shardings=(ns(pspecs), ns(bspecs))).lower(pshapes, specs)
    print("lower", time.time() - t0)
    t0 = time.time()
    compiled = lowered.compile()
    print("compile", time.time() - t0)
    report(compiled)
elif cell.step == "decode":
    specs = sh.decode_specs(model, cell)
    st_axes = model.state_axes()
    sspecs = {
        "tokens": rules.spec_for(("batch",), specs["tokens"].shape),
        "state": jax.tree.map(
            lambda names, sds: rules.spec_for(names, sds.shape),
            st_axes, specs["state"],
            is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t)),
    }

    def serve_step(params, tokens, state):
        with use_rules(rules):
            return model.serve_step(params, tokens, state)

    t0 = time.time()
    with use_rules(rules):
        lowered = jax.jit(serve_step, in_shardings=(ns(pspecs), ns(sspecs["tokens"]), ns(sspecs["state"]))).lower(
            pshapes, specs["tokens"], specs["state"])
    print("lower", time.time() - t0)
    t0 = time.time()
    compiled = lowered.compile()
    print("compile", time.time() - t0)
    report(compiled)
elif cell.step == "train":
    specs = sh.batch_specs(model, cell)
    bspecs = {k: rules.spec_for(("batch",) + (None,) * (len(v.shape) - 1), v.shape)
              for k, v in specs.items()}

    MICRO = int(os.environ.get("MICRO", "8"))

    def train_step(params, batch):
        with use_rules(rules):
            def micro_step(grads, mb):
                (loss, metrics), g = jax.value_and_grad(model.loss_fn, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, grads, g), loss

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbatch = jax.tree.map(
                lambda x: jnp.moveaxis(x.reshape((MICRO, x.shape[0] // MICRO) + x.shape[1:]), 0, 0),
                batch)
            grads, losses = jax.lax.scan(micro_step, gz, mbatch)
            new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
        return new_params, losses.mean()

    fn = jax.jit(train_step, in_shardings=(jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspecs),
                                           jax.tree.map(lambda s: jax.NamedSharding(mesh, s), bspecs)))
    t0 = time.time()
    with use_rules(rules):
        lowered = fn.lower(pshapes, specs)
    print("lower", time.time() - t0)
    t0 = time.time()
    compiled = lowered.compile()
    print("compile", time.time() - t0)
    ma = compiled.memory_analysis()
    print("argument bytes/dev:", ma.argument_size_in_bytes / 2**30, "GiB")
    print("temp bytes/dev:", ma.temp_size_in_bytes / 2**30, "GiB")
    print("output bytes/dev:", ma.output_size_in_bytes / 2**30, "GiB")
    ca = compiled.cost_analysis()
    print("flops:", ca.get("flops", None))
    txt = compiled.as_text()
    import re
    colls = {}
    for m in re.finditer(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt):
        colls[m.group(1)] = colls.get(m.group(1), 0) + 1
    print("collective op counts:", colls)
    print("HLO len:", len(txt))

"""Engine check on 8 virtual CPU devices: planner train step, gpipe step, auto search."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

import repro as wh
from repro.configs import get_config
import repro.core.pipeline as pipe
from repro.core.planner import compile_plan
from repro.models.lm import build
from repro.optim.optimizer import adamw

cfg = get_config("tinyllama-1.1b", smoke=True)
model = build(cfg)
opt = adamw(lr=1e-3)

# ---- 1. GSPMD hybrid plan: dp=4 × tp=2 ----
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = compile_plan(model, mesh)
params = plan.init_params(jax.random.key(0))
opt_state = jax.jit(opt.init, out_shardings=wh.core.planner._ns(mesh, plan.opt_specs(opt)) if False else None)(params) if False else opt.init(params)
batch = {"tokens": jnp.asarray(np.random.randint(0, cfg.vocab, (8, 64)), jnp.int32)}
with mesh:
    step = plan.jit_train_step(opt, batch, micro_batches=2, donate=False)
    p2, o2, metrics = step(params, opt_state, batch, 0)
print("hybrid train:", {k: float(v) for k, v in metrics.items() if v.ndim == 0})
assert np.isfinite(metrics["loss"])

# losses decrease over a few steps
with mesh:
    p, o = params, opt_state
    for i in range(5):
        p, o, m = step(p, o, batch, i)
    print("loss step0 -> step5:", float(metrics["loss"]), "->", float(m["loss"]))
    assert m["loss"] < metrics["loss"]

# ---- 2. serve step ----
with mesh:
    serve = plan.jit_serve_step(batch=8, cache_len=32, donate=False)
    state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.decode_state_shapes(8, 32))
    logits, st2 = serve(params, jnp.zeros((8,), jnp.int32), state)
print("serve ok:", logits.shape)

# ---- 3. pipeline: 2 stages × dp=2 × tp=2 ----
mesh3 = jax.make_mesh((2, 2, 2), ("stage", "data", "model"))
rules = wh.hybrid_rules(mesh3)
plan3 = compile_plan(model, mesh3)
with mesh3:
    pstep = pipe.make_pipeline_train_step(model, mesh3, rules, opt,
                                          micro_batches=4, donate=False)
    # params sharded for pipeline
    pspecs = pipe.staged_specs(rules, model.axes(), model.param_shapes())
    psh = jax.tree.map(lambda s: jax.NamedSharding(mesh3, s), pspecs,
                       is_leaf=lambda t: isinstance(t, jax.sharding.PartitionSpec))
    params3 = jax.jit(model.init, out_shardings=psh)(jax.random.key(0))
    ost3 = opt.init(params3)
    tokens = batch["tokens"]
    p3, o3, loss3 = pstep(params3, ost3, tokens, 0)
print("gpipe loss:", float(loss3))
assert np.isfinite(float(loss3))

# pipeline loss == non-pipeline loss on same params (both from key 0)
with mesh:
    l_ref, _ = plan.jit_loss(batch)(params, batch)
# ref loss includes z_loss etc; compare
lfn, _ = pipe.make_pipeline_loss(model, mesh3, rules, micro_batches=4)
with mesh3:
    l_pipe = jax.jit(lfn)(params3, tokens)
print("ref loss:", float(l_ref), "pipe loss:", float(l_pipe))
np.testing.assert_allclose(float(l_ref), float(l_pipe), rtol=2e-2)

# ---- 4. auto-parallel search ----
meta = wh.model_graph(get_config("tinyllama-1.1b"), 256, 4096).workload_meta()
cands = wh.search(meta, 256, top_k=5)
for c in cands:
    print(f"  {c.strategy.describe():40s} t={c.total*1e3:8.1f} ms "
          f"mem={c.cost.mem_bytes/2**30:.1f} GiB")
print("ENGINE OK")

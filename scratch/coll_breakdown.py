"""Hypothesis grounding: which collectives dominate a train cell's bytes."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import collections
import re

import jax
import jax.numpy as jnp

from repro.configs import get_config, shapes as sh
from repro.core.planner import compile_plan
from repro.core.cost_model import StrategySpec
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis as ha
from repro.models.lm import build
from repro.optim.optimizer import adamw, adafactor

arch = sys.argv[1] if len(sys.argv) > 1 else "tinyllama-1.1b"
micro = int(sys.argv[2]) if len(sys.argv) > 2 else 8
optn = sys.argv[3] if len(sys.argv) > 3 else "adamw"

mesh = make_production_mesh()
model = build(get_config(arch))
strat = StrategySpec(dp=16, tp=16, micro_batches=micro, zero=3)
plan = compile_plan(model, mesh, strategy=strat)
cell = sh.SHAPES["train_4k"]
bspecs = sh.batch_specs(model, cell)
opt = adafactor(lr=1e-4) if optn == "adafactor" else adamw(moment_dtype="bfloat16")
fn = plan.jit_train_step(opt, bspecs, micro_batches=micro)
osh = jax.eval_shape(opt.init, plan.param_shapes)
with mesh:
    compiled = fn.lower(plan.param_shapes, osh, bspecs,
                        jax.ShapeDtypeStruct((), jnp.int32)).compile()
hlo = compiled.as_text()
comps = ha.parse_computations(hlo)

items = collections.Counter()
def visit(name, mult, stack):
    if name not in comps or name in stack:
        return
    stack = stack | {name}
    for line in comps[name]:
        m = ha._COLL_RE.search(line)
        if m:
            b = ha._shape_bytes(m.group(1))
            kind = m.group(2)
            shape = m.group(1)[:48]
            md = re.search(r'op_name="([^"]*)"', line)
            tag = (md.group(1).split("/")[-1][:40] if md else "?")
            items[(kind, shape, tag)] += mult * b
        mw = ha._WHILE_RE.search(line)
        if mw:
            visit(mw.group(2), mult * ha.trip_count(comps.get(mw.group(1), [])),
                  stack)
entry = [l for l in hlo.splitlines() if l.startswith("ENTRY")][0]
visit(ha._HEADER_RE.match(entry).group(1), 1, frozenset())
total = sum(items.values())
print(f"{arch}: total (unweighted result bytes×trips) {total/2**30:.1f} GiB")
for (kind, shape, tag), b in items.most_common(14):
    print(f"  {b/2**30:8.2f} GiB  {kind:18s} {shape:50s} {tag}")

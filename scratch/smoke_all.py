"""Quick substrate check: every smoke config does one fwd loss + one decode step."""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.configs import shapes as sh
from repro.models.lm import build, param_count

key = jax.random.key(0)
for name in ARCH_NAMES:
    t0 = time.time()
    cfg = get_config(name, smoke=True)
    model = build(cfg)
    params = model.init(key)
    n = param_count(params)
    cell = sh.ShapeCell("t", "train", 64, 2)
    batch = sh.make_synthetic_batch(model, cell, key)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    # decode one step
    state = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        model.decode_state_shapes(2, 32))
    logits, state2 = jax.jit(model.serve_step)(params, jnp.zeros((2,), jnp.int32), state)
    assert jnp.all(jnp.isfinite(logits)), name
    # axes treedef matches params treedef
    axes = model.axes()
    jax.tree.map(lambda p, a: None, params, axes,
                 is_leaf=lambda t: isinstance(t, tuple) and all(
                     isinstance(e, (str, type(None))) for e in t))
    print(f"{name:24s} params={n:9d} loss={float(loss):8.4f} "
          f"({time.time()-t0:.1f}s)")

# heterogeneous planner smoke: the fig7 benchmark's analytic comparison
# (hardware-aware vs naive even split) with its built-in assertions
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import benchmarks.fig7_heterogeneous as fig7
fig7.main()

# pipeline schedule smoke: tick tables validate against the closed forms
# (full property coverage in tests/test_schedule.py) + the fig2 grid's
# built-in assertions (same bubble, 1F1B memory advantage, uneven >= even)
from repro.core.schedule import (bubble_fraction_closed_form, make_schedule)
for S, M in ((2, 4), (4, 8), (3, 5)):
    for name in ("gpipe", "1f1b"):
        sc = make_schedule(name, S, M)
        assert abs(sc.bubble_fraction()
                   - bubble_fraction_closed_form(S, M)) < 1e-12
import benchmarks.fig2_bert_pipeline as fig2
fig2.print_schedule_grid(fig2.schedule_grid_rows())

# nested-hybrid smoke: the fig9 M6 comparison (flat DP OOMs, nested DP×EP
# fits and wins) with its built-in assertions, plus the graph optimizer's
# bridge insertion on a traced replica{split[experts]} nest
import benchmarks.fig9_m6_moe as fig9
fig9.main()

# multimodal smoke: the fig10 M6 comparison (segment-aware auto-search
# beats the hand-even pipeline split; jamba-52B feasible only via auto)
# with its built-in assertions
import benchmarks.fig10_multimodal as fig10
fig10.main()

# self-healing smoke: the fig_elastic eviction loop (straggler detected,
# evicted, rebalanced plan recovers to the cost-model prediction) with its
# built-in assertions
import benchmarks.fig_elastic as fig_elastic
fig_elastic.main()
# spot-fleet smoke: the fig_spot drain-and-grow vs restart comparison
# (hosts shed within the reclaim deadline, re-admitted later, post-grow
# back on the full-fleet prediction) with its built-in assertions
import benchmarks.fig_spot as fig_spot
fig_spot.main()
# serving smoke: the fig_serve paged+disaggregated comparison with its
# built-in gates (≥1.3× tokens/s, p99 TTFT no worse), plus one real
# paged-vs-dense lockstep decode step proving bit-exactness end to end
import benchmarks.fig_serve as fig_serve
fig_serve.main()
# calibration smoke: the fig_calibration fit + drift comparison with its
# built-in gates (fit error ≤10%, continuous rebalance ≥1.3× one-shot)
import benchmarks.fig_calibration as fig_cal
fig_cal.main()

import numpy as np
from repro.core.planner import compile_plan
from repro.serving.server import Request, Server

_cfg = get_config("tinyllama-1.1b", smoke=True)
_model = build(_cfg)
_mesh = jax.make_mesh((len(jax.devices()),), ("data",))
_plan = compile_plan(_model, _mesh)
with _mesh:
    _params = _plan.init_params(jax.random.key(0))
_srvs = {c: Server(_model, _plan, batch_slots=2, max_len=16, cache=c,
                   page_size=4, record_logits=True)
         for c in ("dense", "paged")}
for _c, _srv in _srvs.items():
    _srv.admit(_params, Request(0, np.arange(5, dtype=np.int32), max_new=4),
               slot=0)
    _srv.step(_params)
assert _srvs["dense"].slots[0].out_tokens \
    == _srvs["paged"].slots[0].out_tokens
assert np.array_equal(_srvs["dense"].last_logits[0],
                      _srvs["paged"].last_logits[0])
print("serving: paged decode bit-exact vs dense")

import repro as wh
with wh.cluster(mesh_shape=(1, 1), axis_names=("data", "model")) as _cl:
    with wh.replica():
        _h = wh.sub("attn", lambda p, x: x @ p["w"])(
            {"w": jnp.ones((8, 8))}, jnp.ones((4, 8)))
        with wh.split(experts=True):
            _h = wh.sub("moe", lambda p, x: x @ p["w"])(
                {"w": jnp.ones((8, 8))}, _h)
_low = wh.lower(_cl)
assert _low.bridges("all_to_all"), _low.describe()
assert _low.max_nesting_depth == 2
print("graph_opt:", _low.describe())
print("ALL OK")

"""Profile-calibrated cost model (DESIGN.md §10).

Layers under test:
  (a) the linear feature decomposition — ``predict_step_time`` over
      ``step_cost_features`` must equal ``step_cost``'s analytic
      compute+comm+bubble *exactly*, for every strategy shape (this
      identity is what makes calibration a linear least-squares problem);
  (b) the round-trip property — ``fit`` over observations synthesized
      from a ground-truth table recovers its rates (noise-free to ridge
      precision, 5%-jittered to well inside 10%), and the fitted
      ``CalibratedHardware`` is a drop-in ``Hardware`` everywhere;
  (c) the profiler plumbing — ring-effective byte accounting, sliding
      windows, per-group fits over a ``ClusterSpec``;
  (d) the drift loop — ``DriftHost`` ramps, and the end-to-end
      controller detects sustained predicted-vs-measured skew, re-fits,
      and resumes (subprocess, simulated clock).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.calibrate import (CalibratedHardware, Observation, fit,
                                  parameter_error, prediction_error,
                                  refit_spec, synthesize_observations)
from repro.core.cost_model import (CALIBRATION_PARAMS, ClusterSpec,
                                   DeviceGroup, Hardware, StrategySpec,
                                   T4_16G, TPU_V5E, V100_PAPER,
                                   hardware_reciprocals, predict_step_time, step_cost,
                                   step_cost_features)
from repro.core.hetero import plan_placement, price_batch_shares
from repro.models.lm import model_graph
from repro.runtime.faults import DriftHost, FaultInjector
from repro.runtime.profiler import Profiler, ring_effective_bytes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 540):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def _meta(batch=256, seq=512, arch="tinyllama-1.1b"):
    from repro.configs import get_config
    return model_graph(get_config(arch), batch, seq).workload_meta()


# ---------------------------------------------------------------------------
# (a) the linear identity: features · reciprocals == analytic step cost
# ---------------------------------------------------------------------------

STRATS = [
    StrategySpec(dp=8),
    StrategySpec(dp=4, tp=2),
    StrategySpec(dp=2, tp=2, pp=2, micro_batches=4),
    StrategySpec(dp=1, tp=4, pp=2, micro_batches=8, schedule="1f1b"),
    StrategySpec(dp=8, zero=3),
    StrategySpec(dp=4, tp=2, vocab_split=False),
]


@pytest.mark.parametrize("hw", [V100_PAPER, T4_16G, TPU_V5E],
                         ids=lambda h: h.name)
@pytest.mark.parametrize("strat", STRATS, ids=lambda s: s.describe())
@pytest.mark.parametrize("overlap", [0.0, 0.5])
def test_features_reproduce_step_cost(hw, strat, overlap):
    """``predict_step_time(step_cost_features(...))`` equals the analytic
    compute + comm + bubble to float precision.  (``total`` also folds in
    memory *feasibility* — infinite when the plan OOMs — which is
    orthogonal to the timing decomposition, so the identity is checked
    against the three timed terms.)"""
    meta = _meta()
    cb = step_cost(meta, strat, hw, overlap=overlap)
    feats = step_cost_features(meta, strat, hw, overlap=overlap)
    want = cb.compute + cb.comm + cb.bubble
    got = predict_step_time(feats, hw)
    assert got == pytest.approx(want, rel=1e-9), (strat.describe(), cb)


def test_features_reproduce_step_cost_moe():
    from repro.configs import get_config
    meta = model_graph(get_config("deepseek-moe-16b"), 64, 512).workload_meta()
    for strat in (StrategySpec(dp=8, ep=4), StrategySpec(dp=4, tp=2, ep=2),
                  StrategySpec(dp=8, ep=8, zero=3)):
        cb = step_cost(meta, strat, V100_PAPER, overlap=0.5)
        feats = step_cost_features(meta, strat, V100_PAPER, overlap=0.5)
        want = cb.compute + cb.comm + cb.bubble
        assert predict_step_time(feats, V100_PAPER) == pytest.approx(
            want, rel=1e-9), strat.describe()


def test_features_cover_only_calibration_params():
    feats = step_cost_features(_meta(), StrategySpec(dp=4, tp=2),
                               V100_PAPER)
    assert set(feats) == set(CALIBRATION_PARAMS)
    assert all(v >= 0.0 for v in feats.values())
    assert feats["eff_flops"] > 0.0


# ---------------------------------------------------------------------------
# (b) round trip: fit recovers a ground-truth table
# ---------------------------------------------------------------------------

TRUTH = dataclasses.replace(
    V100_PAPER, peak_flops=V100_PAPER.peak_flops * 0.7,
    hbm_bw=V100_PAPER.hbm_bw * 1.35,
    link_bw={"fast": V100_PAPER.link_bw["fast"] * 0.8,
             "slow": V100_PAPER.link_bw["slow"] * 1.3})


def test_fit_recovers_truth_noise_free():
    obs = synthesize_observations(_meta(), StrategySpec(dp=4, tp=2), TRUTH,
                                  n_steps=16)
    fitted = fit(obs, V100_PAPER)
    assert parameter_error(fitted, TRUTH) < 1e-3   # ridge bias only
    assert prediction_error(obs, fitted) < 1e-3
    assert all(fitted.confidence[p] > 0.8 for p in CALIBRATION_PARAMS), \
        fitted.confidence


def test_fit_recovers_truth_under_noise():
    obs = synthesize_observations(_meta(), StrategySpec(dp=4, tp=2), TRUTH,
                                  n_steps=200, noise=0.05, seed=7)
    fitted = fit(obs, V100_PAPER)
    err, prior_err = (parameter_error(fitted, TRUTH),
                      parameter_error(V100_PAPER, TRUTH))
    assert err < 0.10, err                          # the acceptance gate
    assert err < prior_err / 4, (err, prior_err)    # and a real improvement


@settings(max_examples=15, deadline=None)
@given(scales=st.tuples(*([st.floats(0.4, 2.5)] * 4)))
def test_fit_round_trip_property(scales):
    """Any physically-plausible perturbation of every rate entry is
    recovered from noise-free decomposed observations."""
    sf, sh, sl_f, sl_s = scales
    truth = dataclasses.replace(
        V100_PAPER, peak_flops=V100_PAPER.peak_flops * sf,
        hbm_bw=V100_PAPER.hbm_bw * sh,
        link_bw={"fast": V100_PAPER.link_bw["fast"] * sl_f,
                 "slow": V100_PAPER.link_bw["slow"] * sl_s})
    obs = synthesize_observations(_meta(batch=64), StrategySpec(dp=4, tp=2),
                                  truth, n_steps=8)
    assert parameter_error(fit(obs, V100_PAPER), truth) < 1e-2


def test_compute_only_observations_keep_links_at_prior():
    """Unobserved parameters are not hallucinated: they stay exactly at
    the prior with zero confidence."""
    obs = [o for o in synthesize_observations(
        _meta(), StrategySpec(dp=4, tp=2), TRUTH, n_steps=16)
        if o.kind == "compute"]
    fitted = fit(obs, V100_PAPER)
    r_fit, r_prior = (hardware_reciprocals(fitted),
                      hardware_reciprocals(V100_PAPER))
    for p in ("link_fast", "link_slow", "hbm_bw"):
        assert r_fit[p] == pytest.approx(r_prior[p])
        assert fitted.confidence[p] == 0.0
    assert parameter_error(fitted, TRUTH, params=("eff_flops",)) < 1e-3
    assert fitted.confidence["eff_flops"] > 0.8


def test_whole_step_observations_still_predict_well():
    """Whole-step times are collinear (one row shape), so per-parameter
    recovery is not identifiable — but the ridge-to-prior fit must still
    *predict* step times accurately."""
    obs = synthesize_observations(_meta(), StrategySpec(dp=4, tp=2), TRUTH,
                                  n_steps=32, decomposed=False)
    fitted = fit(obs, V100_PAPER)
    assert prediction_error(obs, fitted) < 0.05
    # and the prior is much worse on the same observations
    assert prediction_error(obs, V100_PAPER) > 3 * prediction_error(
        obs, fitted)


def test_fit_without_observations_returns_prior():
    fitted = fit([], V100_PAPER)
    assert parameter_error(fitted, V100_PAPER) == 0.0
    assert fitted.n_observations == 0
    assert all(v == 0.0 for v in fitted.confidence.values())
    assert fitted.base_name == V100_PAPER.name


def test_confidence_discounts_small_samples():
    few = fit(synthesize_observations(_meta(), StrategySpec(dp=4, tp=2),
                                      TRUTH, n_steps=2, noise=0.05, seed=1),
              V100_PAPER)
    many = fit(synthesize_observations(_meta(), StrategySpec(dp=4, tp=2),
                                       TRUTH, n_steps=64, noise=0.05,
                                       seed=1),
               V100_PAPER)
    assert few.confidence["eff_flops"] < many.confidence["eff_flops"]


def test_calibrated_hardware_is_drop_in():
    """A fitted table flows through every ``Hardware`` consumer: cost
    model, hetero balancer, strategy search, kernel autotuner."""
    from repro.core.auto import search
    from repro.kernels.autotune import autotune
    obs = synthesize_observations(_meta(), StrategySpec(dp=4, tp=2), TRUTH,
                                  n_steps=16)
    fitted = fit(obs, V100_PAPER)
    assert isinstance(fitted, Hardware)
    meta = _meta()
    cb = step_cost(meta, StrategySpec(dp=4, tp=2), fitted)
    want = step_cost(meta, StrategySpec(dp=4, tp=2), TRUTH)
    assert cb.total == pytest.approx(want.total, rel=1e-3)
    spec = ClusterSpec(groups=(DeviceGroup("fit", fitted, 8),
                               DeviceGroup("t4", T4_16G, 8)))
    pl = plan_placement(meta, StrategySpec(dp=8, tp=2), spec, overlap=0.5)
    assert sum(pl.batch_shares) == meta.batch
    assert search(meta, spec, top_k=1, overlap=0.5, max_pp=1)
    tiles = autotune(fitted, head_dim=128, group=4, d_model=2048,
                     vocab=32000)
    assert tiles == autotune(V100_PAPER, head_dim=128, group=4,
                             d_model=2048, vocab=32000), \
        "vmem/hbm capacity unchanged → same tile geometry"


def test_refit_spec_is_partial_and_name_keyed():
    spec = ClusterSpec(groups=(DeviceGroup("a", V100_PAPER, 8),
                               DeviceGroup("b", T4_16G, 8)))
    fitted = fit(synthesize_observations(
        _meta(), StrategySpec(dp=4, tp=2), TRUTH, n_steps=8), V100_PAPER)
    out = refit_spec(spec, {"a": fitted})
    assert out.groups[0].hw is fitted
    assert out.groups[1].hw is T4_16G          # no observations → prior
    assert [g.name for g in out.groups] == ["a", "b"]


def test_fit_chains_base_name_through_refits():
    obs = synthesize_observations(_meta(), StrategySpec(dp=4, tp=2), TRUTH,
                                  n_steps=8)
    first = fit(obs, V100_PAPER)
    second = fit(obs, first)                   # recalibrate the calibrated
    assert isinstance(second, CalibratedHardware)
    assert first.base_name == V100_PAPER.name
    assert second.base_name == V100_PAPER.name


# ---------------------------------------------------------------------------
# (c) profiler: byte accounting, windows, spec-level fits
# ---------------------------------------------------------------------------

def test_ring_effective_bytes():
    """Effective volumes match the cost model's own ring formulas at unit
    bandwidth — the invariant that makes fitted bandwidth == table entry."""
    from repro.core.cost_model import (all_gather_time, all_reduce_time,
                                       all_to_all_time, p2p_time)
    b, n = 1024.0, 4
    assert ring_effective_bytes("all-reduce", b, n) == pytest.approx(
        all_reduce_time(b, n, 1.0))
    assert ring_effective_bytes("all-gather", b, n) == pytest.approx(
        all_gather_time(b, n, 1.0))
    assert ring_effective_bytes("reduce-scatter", b, n) == pytest.approx(
        all_gather_time(b, n, 1.0))
    assert ring_effective_bytes("all-to-all", b, n) == pytest.approx(
        all_to_all_time(b, n, 1.0))
    assert ring_effective_bytes("p2p", b, n) == pytest.approx(
        p2p_time(b, 1.0))
    assert ring_effective_bytes("all-reduce", b, 1) == 0.0
    with pytest.raises(ValueError):
        ring_effective_bytes("gossip", b, n)


def test_profiler_window_drops_oldest():
    prof = Profiler(max_per_group=8)
    for s in range(20):
        prof.record_compute("g", wall_s=1.0, flops=1e12, step=s)
    assert prof.n_obs("g") == 8
    assert [o.step for o in prof.window("g")] == list(range(12, 20))
    assert [o.step for o in prof.window("g", last_n=3)] == [17, 18, 19]
    prof.clear("g")
    assert prof.n_obs() == 0


def test_profiler_ignores_degenerate_observations():
    prof = Profiler()
    prof.record_compute("g", wall_s=0.0, flops=1e12)
    prof.record_compute("g", wall_s=1.0, flops=0.0)
    prof.record_kernel("g", hbm_bytes=0.0, wall_s=1.0)
    prof.record_collective("g", "all-reduce", 1024.0, 1, 1.0)  # n=1: no-op
    assert prof.n_obs() == 0


def test_profiler_fit_spec_per_group():
    """Two groups with different true rates fit independently; a group
    without observations keeps its prior."""
    spec = ClusterSpec(groups=(DeviceGroup("v", V100_PAPER, 8),
                               DeviceGroup("t", T4_16G, 8),
                               DeviceGroup("idle", TPU_V5E, 8)))
    prof = Profiler()
    for o in synthesize_observations(_meta(), StrategySpec(dp=4, tp=2),
                                     TRUTH, n_steps=16, group="v"):
        prof.record(o)
    for o in synthesize_observations(_meta(), StrategySpec(dp=4, tp=2),
                                     T4_16G, n_steps=16, group="t"):
        prof.record(o)
    out, fits = prof.fit_spec(spec)
    assert set(fits) == {"v", "t"}
    assert parameter_error(out.groups[0].hw, TRUTH) < 1e-3
    assert parameter_error(out.groups[1].hw, T4_16G) < 1e-3
    assert out.groups[2].hw is TPU_V5E
    assert prof.error("v", out.groups[0].hw) < 1e-3
    rep = prof.report(out)
    assert "v" in rep and "idle" in rep and "eff_flops" in rep


# ---------------------------------------------------------------------------
# (d) drift: the ramp scenario and the pricing kernel it re-plans with
# ---------------------------------------------------------------------------

def test_drift_host_ramp():
    d = DriftHost(host=1, start_step=10, end_step=30, factor=3.0)
    assert d.factor_at(0) == 1.0 and d.factor_at(10) == 1.0
    assert d.factor_at(20) == pytest.approx(2.0)
    assert d.factor_at(30) == 3.0 and d.factor_at(100) == 3.0


def test_injector_applies_drift_ramp():
    inj = FaultInjector(scenarios=(DriftHost(host=0, start_step=0,
                                             end_step=10, factor=2.0),),
                        n_hosts=2, jitter=0.0, seed=0, nominal=1.0)
    t5 = inj.host_times(5)
    assert t5[0] == pytest.approx(1.5) and t5[1] == pytest.approx(1.0)
    assert inj.host_times(10)[0] == pytest.approx(2.0)


def test_price_batch_shares_matches_plan_placement():
    """The exposed pricing kernel is byte-identical to what the balancer
    prices internally — re-pricing stale shares on a re-fitted spec uses
    the same arithmetic as planning fresh ones."""
    meta = _meta()
    strat = StrategySpec(dp=8, tp=2)
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                               DeviceGroup("t4", T4_16G, 8)))
    pl = plan_placement(meta, strat, spec, overlap=0.5)
    units, extra = price_batch_shares(meta, strat, spec, pl.batch_shares,
                                      overlap=0.5)
    got = [u.cost for u in units]
    want = [u.cost for u in pl.units if u.kind == "group"]
    assert got == want
    assert extra >= 0.0


@pytest.mark.slow
def test_drift_controller_recalibrates_end_to_end(tmp_path):
    """A slow 1→3× ramp on one host (under the straggler monitor's
    outlier band) trips the predicted-vs-measured skew watch; the
    controller re-fits the table from profiler observations, re-plans,
    resumes, and finishes — with the fitted rate reflecting the slowdown
    and no host evicted."""
    run_py(f"""
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.data.pipeline import DataCfg, TokenPipeline
        from repro.launch.train import (CalibrationConfig, ElasticConfig,
                                        TrainController)
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.runtime.elastic import HostTopology
        from repro.runtime.faults import DriftHost, FaultInjector

        N = 60
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)
        data = TokenPipeline(DataCfg(global_batch=8, seq_len=64,
                                     vocab=cfg.vocab, seed=0))
        inj = FaultInjector(scenarios=(
            DriftHost(host=1, start_step=5, end_step=200, factor=3.0),),
            n_hosts=2, seed=0, nominal=0.05)
        ctl = TrainController(
            model, cfg, adamw(lr=1e-3), data,
            CheckpointManager({str(tmp_path)!r} + "/drift", keep=3),
            elastic=ElasticConfig(
                topology=HostTopology.uniform(2, 2, TPU_V5E),
                patience=3, warmup=3,
                calibration=CalibrationConfig(skew=0.25, patience=3,
                                              min_steps=8)),
            batch=8, seq=64, save_every=10, injector=inj, log_every=100)
        out = ctl.run(N, seed=0)
        assert out["phase"] == "DONE" and out["final_step"] == N
        kinds = [e["kind"] for e in out["events"]]
        assert "drift" in kinds and "recalibrate" in kinds, kinds
        assert "evict" not in kinds, kinds      # the ramp must NOT evict
        assert out["topology"].host_ids == (0, 1)
        drift = next(e for e in out["events"] if e["kind"] == "drift")
        assert drift["skew"] > 1.25
        (gname, fitted), = drift["hardware"].items()
        prior_eff = TPU_V5E.peak_flops * TPU_V5E.mxu_eff
        assert fitted["n_obs"] > 0
        assert fitted["eff_flops"] < prior_eff, (fitted, prior_eff)
        print("OK drift→recalibrate:", drift["skew"], fitted)
    """)

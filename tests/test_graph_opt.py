"""Graph optimizations (Whale §4): nested scopes, bridges, grad placement,
and the nested replica{split[experts]} strategy threading (cost model,
auto-search, planner)."""
import dataclasses

import jax.numpy as jnp
import pytest

import repro as wh
from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   StrategySpec, V100_PAPER,
                                   step_cost)
from repro.core.graph_opt import (StrategyNestingError, bridge_cost,
                                  insert_bridges, place_grad_aggregation,
                                  plan_bridge, validate_nesting)
from repro.core.ir import StrategyAnnotation, Subgraph, TaskGraph, TensorMeta
from repro.models.lm import model_graph


def _net(p, x):
    return x @ p["w"]


def _p(n=8, m=8):
    return {"w": jnp.ones((n, m))}


# ---------------------------------------------------------------------------
# nested-scope semantics: stacking records, illegal nests raise loud
# ---------------------------------------------------------------------------

def test_nested_scopes_stack_annotations_with_depth():
    with wh.cluster(mesh_shape=(1, 1), axis_names=("data", "model")) as cl:
        with wh.replica():
            with wh.split(dim=-1):
                wh.sub("fc", _net)(_p(), jnp.ones((4, 8)))
    sg = cl.taskgraph.by_name("fc")
    assert sg.strategy_kinds() == ("replica", "split")
    assert [a.depth for a in sg.strategy] == [0, 1]
    assert sg.nesting_depth == 2
    assert sg.parallel_kinds() == ("replica", "split")


def test_expert_split_option_recorded():
    with wh.cluster(mesh_shape=(1, 1), axis_names=("data", "model")) as cl:
        with wh.replica():
            with wh.split(experts=True):
                wh.sub("moe", _net)(_p(), jnp.ones((4, 8)))
    sg = cl.taskgraph.by_name("moe")
    assert sg.split_options()["experts"] is True
    assert sg.vdevice is not None and sg.vdevice.name == "hybrid"


def test_split_outside_cluster_raises():
    with pytest.raises(StrategyNestingError, match="outside any wh.cluster"):
        with wh.split():
            pass


def test_replica_inside_split_raises():
    with wh.cluster(mesh_shape=(1,), axis_names=("data",)):
        with pytest.raises(StrategyNestingError, match="innermost"):
            with wh.split():
                with wh.replica():
                    pass


def test_self_nesting_raises():
    with wh.cluster(mesh_shape=(1,), axis_names=("data",)):
        with pytest.raises(StrategyNestingError, match="once per nest"):
            with wh.replica():
                with wh.replica():
                    pass


def test_stage_without_pipeline_raises():
    with wh.cluster(mesh_shape=(1,), axis_names=("data",)):
        with pytest.raises(StrategyNestingError, match="enclosing 'pipeline'"):
            with wh.stage():
                pass


def test_three_level_nest_is_legal():
    # pipeline{stage{replica{split}}} — the paper's deepest shipped nest
    validate_nesting(("pipeline", "stage", "replica", "split"))
    # Case 4's replica{pipeline{stage}} stays legal too
    validate_nesting(("replica", "pipeline", "stage"))
    with pytest.raises(StrategyNestingError):
        validate_nesting(("pipeline", "split", "replica"))


# ---------------------------------------------------------------------------
# bridge insertion on small TaskGraphs
# ---------------------------------------------------------------------------

def _sg(name, kinds, *, experts=False, stage=None, out_shape=(4, 8)):
    anns = []
    for k in kinds:
        opts = {}
        if k == "split":
            opts = {"dim": -1, "experts": experts}
        if k == "stage":
            opts = {"index": stage}
        anns.append(StrategyAnnotation(k, opts))
    return Subgraph(name=name, fn=None, strategy=anns,
                    outputs=[TensorMeta(out_shape, jnp.float32)],
                    params=[TensorMeta((8, 8), jnp.float32)])


def test_bridge_replica_to_split_is_all_gather():
    b = plan_bridge(_sg("a", ("replica",)), _sg("b", ("replica", "split")))
    assert (b.kind, b.bwd_kind, b.axis) == ("all_gather", "reduce_scatter",
                                            "model")
    assert b.bytes == 4 * 8 * 4


def test_bridge_split_to_replica_is_reduce_scatter():
    b = plan_bridge(_sg("a", ("replica", "split")), _sg("b", ("replica",)))
    assert (b.kind, b.bwd_kind) == ("reduce_scatter", "all_gather")


def test_bridge_expert_split_is_all_to_all_both_ways():
    rep = _sg("attn", ("replica",))
    moe = _sg("moe", ("replica", "split"), experts=True)
    disp = plan_bridge(rep, moe)
    comb = plan_bridge(moe, rep)
    assert disp.kind == comb.kind == "all_to_all"
    assert disp.bwd_kind == "all_to_all"       # self-transpose
    assert "dispatch" in disp.reason and "combine" in comb.reason


def test_bridge_stage_boundary_is_p2p():
    b = plan_bridge(_sg("s0", ("pipeline", "stage"), stage=0),
                    _sg("s1", ("pipeline", "stage"), stage=1))
    assert (b.kind, b.axis) == ("p2p", "stage")


def test_bridge_pipeline_entry_and_exit_are_p2p():
    """Work outside the pipeline scope still pays the boundary transfer."""
    outside = _sg("loss", ("replica",))
    staged = _sg("s0", ("pipeline", "stage"), stage=0)
    exit_b = plan_bridge(staged, outside)
    entry_b = plan_bridge(outside, staged)
    assert exit_b.kind == entry_b.kind == "p2p"
    assert exit_b.bytes > 0


def test_bridge_same_layout_is_identity_and_free():
    b = plan_bridge(_sg("a", ("replica",)), _sg("b", ("replica",)))
    assert b.kind == "identity"
    assert bridge_cost(b, V100_PAPER, 8) == 0.0


def test_insert_bridges_walks_consecutive_pairs_idempotently():
    tg = TaskGraph()
    for sg in (_sg("attn", ("replica",)),
               _sg("moe", ("replica", "split"), experts=True),
               _sg("out", ("replica",))):
        tg.add(sg)
    edges = insert_bridges(tg)
    assert [(e.src, e.dst, e.bridge.kind) for e in edges] == [
        ("attn", "moe", "all_to_all"), ("moe", "out", "all_to_all")]
    insert_bridges(tg)                      # re-lowering must not duplicate
    assert len(tg.edges) == 2
    assert tg.edges_into("moe")[0].src == "attn"


def test_bridge_cost_uses_ring_formulas():
    b = plan_bridge(_sg("a", ("replica",)), _sg("b", ("replica", "split")))
    t = bridge_cost(b, V100_PAPER, 8)
    assert t == pytest.approx((8 - 1) / 8 * b.bytes
                              / V100_PAPER.bw_for_axis("model"))


# ---------------------------------------------------------------------------
# gradient-aggregation placement
# ---------------------------------------------------------------------------

def test_grad_aggregation_placement():
    tg = TaskGraph()
    tg.add(_sg("attn", ("replica",)))
    tg.add(_sg("moe", ("replica", "split"), experts=True))
    tg.add(_sg("head", ("split",)))
    aggs = {a.subgraph: a for a in place_grad_aggregation(tg, ep=4)}
    assert aggs["attn"].collective == "all_reduce"
    assert aggs["attn"].axes == ("data",)
    # expert shards own disjoint experts: data-axis reduction at 1/ep volume
    assert aggs["moe"].bytes == pytest.approx(aggs["attn"].bytes / 4)
    # no replica ancestor → nothing to aggregate
    assert aggs["head"].collective == "none"


# ---------------------------------------------------------------------------
# end-to-end lowering: scopes → LoweredGraph → ExecutionPlan strategy
# ---------------------------------------------------------------------------

def _trace_m6_nest():
    cl = wh.cluster(mesh_shape=(1, 1), axis_names=("data", "model"))
    with cl:
        with wh.replica():
            h = wh.sub("attn", _net)(_p(), jnp.ones((4, 8)))
            with wh.split(experts=True):
                h = wh.sub("moe", _net)(_p(), h)
            wh.sub("out", _net)(_p(), h)
    return cl


def test_lower_produces_bridged_nested_graph():
    low = wh.lower(_trace_m6_nest())
    assert low.max_nesting_depth == 2
    kinds = [e.bridge.kind for e in low.edges]
    assert kinds == ["all_to_all", "all_to_all"]
    assert len(low.grad_aggs) == 3
    assert "all_to_all" in low.describe()


def test_strategy_from_taskgraph_detects_expert_nest():
    cl = _trace_m6_nest()
    strat = wh.strategy_from_taskgraph(cl)
    # mesh model axis is 1 here, so degrees collapse — but the expert nest
    # must not masquerade as tensor parallelism
    assert strat.tp == 1 and not strat.vocab_split


# ---------------------------------------------------------------------------
# nested StrategySpec + cost model
# ---------------------------------------------------------------------------

def test_ep_spec_validation_and_devices():
    s = StrategySpec(dp=8, ep=8)
    assert s.devices == 64 and s.model_parallel == 8
    assert "split[experts]×8" in s.describe()
    with pytest.raises(ValueError, match="must be equal"):
        StrategySpec(tp=4, ep=8)
    # ep == tp is the combined expert+tensor point
    assert StrategySpec(dp=2, tp=8, ep=8).devices == 16


def _moe_meta(n_experts=16, batch=1024):
    from repro.configs import get_config
    cfg = dataclasses.replace(
        get_config("deepseek-moe-16b"), n_layers=16, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
        n_experts=n_experts, top_k=2, d_ff_expert=1024, n_shared=0,
        moe_every=2, vocab=30522, name="moe-test")
    return model_graph(cfg, batch, 512).workload_meta()


def test_ep1_pricing_identical_to_flat():
    """ep == 1 must not change a single term (regression guard)."""
    meta = _moe_meta()
    for strat in (StrategySpec(dp=16), StrategySpec(dp=4, tp=4),
                  StrategySpec(dp=4, pp=4, micro_batches=4)):
        c0 = step_cost(meta, strat, V100_PAPER, overlap=0.5)
        c1 = step_cost(meta, dataclasses.replace(strat, ep=1), V100_PAPER,
                       overlap=0.5)
        assert c0.total == c1.total and c0.mem_bytes == c1.mem_bytes


def test_nested_ep_beats_flat_dp_on_moe():
    """The fig9 headline at test scale: expert grads reduce at 1/ep volume
    over slow Ethernet, experts shard ep-ways in HBM."""
    meta = _moe_meta()
    flat = step_cost(meta, StrategySpec(dp=64, remat=False,
                                        vocab_split=False),
                     V100_PAPER, overlap=0.5)
    nested = step_cost(meta, StrategySpec(dp=8, ep=8, remat=False,
                                          vocab_split=False),
                       V100_PAPER, overlap=0.5)
    assert nested.feasible
    assert nested.mem_bytes < flat.mem_bytes
    assert nested.total < flat.total
    assert "ep_all_to_all" in nested.detail


def test_zero3_allgather_respects_ep_sharding():
    """ZeRO-3 under nested ep gathers 1/ep of the expert weights (they
    are already ep-sharded), matching the memory model."""
    meta = _moe_meta()
    z_flat = step_cost(meta, StrategySpec(dp=64, zero=3), V100_PAPER)
    z_nest = step_cost(meta, StrategySpec(dp=8, ep=8, zero=3), V100_PAPER)
    assert (z_nest.detail["fsdp_allgather"]
            < z_flat.detail["fsdp_allgather"])
    # ep == 1 stays byte-identical to the historical formula
    c = step_cost(meta, StrategySpec(dp=16, tp=4, zero=3), V100_PAPER)
    from repro.core.cost_model import all_gather_time
    assert c.detail["fsdp_allgather"] == pytest.approx(
        2 * all_gather_time(meta.param_bytes / 4, 16,
                            V100_PAPER.bw_for_axis("data")))


def test_lower_populates_replication_degrees():
    low = wh.lower(_trace_m6_nest())
    assert set(low.replication) == {"attn", "moe", "out"}
    # 1×1 mesh: every replica degree collapses to 1 but is recorded
    assert all(v == 1 for v in low.replication.values())


def test_nested_ep_pays_all_to_all():
    meta = _moe_meta()
    c = step_cost(meta, StrategySpec(dp=8, ep=8), V100_PAPER)
    assert c.detail["ep_all_to_all"] > 0
    # dense model: no moe terms, ep pricing inert
    from repro.configs import get_config
    dense = model_graph(get_config("tinyllama-1.1b"), 1024, 512).workload_meta()
    assert dense.n_moe_layers == 0 and dense.expert_param_bytes == 0


# ---------------------------------------------------------------------------
# auto-search enumerates + prices the nested hybrid (incl. hetero cluster)
# ---------------------------------------------------------------------------

def test_search_enumerates_nested_hybrids():
    from repro.core.auto import enumerate_strategies
    meta = _moe_meta()
    strats = enumerate_strategies(meta, 64)
    assert any(s.ep > 1 for s in strats), "nested points missing"
    assert all(s.devices == 64 for s in strats)
    # ep only divides the expert count
    assert all(meta.n_experts % s.ep == 0 for s in strats if s.ep > 1)


def test_search_prices_nested_hybrid_on_hetero_cluster():
    """Acceptance: auto.search enumerates and prices nested DP×EP on a
    heterogeneous ClusterSpec, carrying a balanced placement."""
    from repro.core.auto import search
    meta = _moe_meta(batch=2048)
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 32),
                               DeviceGroup("p100", P100_16G, 32)))
    cands = search(meta, spec, top_k=8, overlap=0.5, max_pp=1)
    nested = [c for c in cands if c.strategy.ep > 1]
    assert nested, "no nested candidate priced on the mixed cluster"
    pl = nested[0].placement
    assert pl is not None and sum(pl.batch_shares) == meta.batch
    # throughput-proportional: the V100 group gets the larger share
    assert pl.batch_shares[0] >= pl.batch_shares[1]


def test_hybrid_rules_expert_axis():
    """A mesh carrying an `expert` axis shards the `experts` logical dim
    over it (ahead of the model axis), leaving batch on the data axes."""
    from repro.core.sharding import hybrid_rules

    class _FakeMesh:
        def __init__(self, shape):
            self.shape = shape
            self.axis_names = tuple(shape)

    rules = hybrid_rules(_FakeMesh({"data": 2, "expert": 4, "model": 2}))
    spec = rules.spec_for(("batch", "experts", None), (8, 8, 16))
    assert spec[0] == "data" and spec[1] in ("expert", ("expert", "model"))
    # without the axis, experts falls back to the model axis
    rules2 = hybrid_rules(_FakeMesh({"data": 2, "model": 4}))
    spec2 = rules2.spec_for(("batch", "experts", None), (8, 8, 16))
    assert spec2[1] == "model"


def test_mesh_for_strategy_sizes_model_axis_by_ep():
    import jax
    if len(jax.devices()) != 1:
        pytest.skip("virtual-device count varies")
    from repro.core.planner import mesh_for_strategy
    # single CPU device: dp=1, ep=1 builds; just assert axis arithmetic
    m = mesh_for_strategy(StrategySpec(dp=1, ep=1))
    assert m.shape["model"] == 1

"""Optimizers, gradient compression, data pipeline, checkpointing, runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataCfg, TokenPipeline
from repro.optim.grad_compress import dequantize_int8, quantize_int8
from repro.optim.optimizer import (Schedule, adafactor, adamw,
                                   clip_by_global_norm, global_norm)
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.straggler import HostStragglerAggregator, StragglerMonitor


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _toy_params():
    return {"a": jnp.ones((4, 8)), "b": {"c": jnp.full((3,), 2.0)}}


def test_adamw_reduces_quadratic():
    opt = adamw(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for i in range(200):
        g = {"w": 2 * params["w"]}
        params, state = opt.apply(g, state, params, i)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adafactor_state_is_factored_and_matches_axes():
    opt = adafactor(lr=0.01)
    params = {"w": jnp.ones((8, 16)), "v1": jnp.ones((5,)),
              "g1": jnp.ones((4, 1, 16))}      # size-1 dim (jamba wB case)
    state = opt.init(params)
    assert set(state["v"]["w"]) == {"vr", "vc"}
    assert set(state["v"]["v1"]) == {"v"}
    assert set(state["v"]["g1"]) == {"vr", "vc"}
    axes = opt.state_axes({"w": ("embed", "mlp"), "v1": ("embed",),
                           "g1": ("a", "b", "c")})
    # structures agree (the jamba multi-pod regression)
    assert jax.tree.structure(
        jax.tree.map(lambda x: 0, state,
                     is_leaf=lambda t: isinstance(t, jnp.ndarray))) == \
        jax.tree.structure(jax.tree.map(lambda t: 0, axes,
                                        is_leaf=lambda t: isinstance(t, tuple)))
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2 = opt.apply(g, state, params, 0)
    assert jax.tree.structure(p2) == jax.tree.structure(params)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), 6.0, rtol=1e-6)


def test_schedule_warmup_and_decay():
    s = Schedule(base_lr=1.0, warmup=10, decay_steps=100, min_ratio=0.1)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

def test_quantize_int8_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 3,
                    jnp.float32)
    q, s, err = quantize_int8(x)
    xd = dequantize_int8(q, s)
    assert float(jnp.abs(xd - x).max()) <= float(s) + 1e-6
    np.testing.assert_allclose(np.asarray(xd + err), np.asarray(x),
                               atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_error_feedback_mean_converges(seed):
    """Property: with error feedback, the time-average of the compressed
    signal converges to the true mean (bias is carried, not lost)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 0.01)
    err = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    n = 40
    for _ in range(n):
        q, s, err = quantize_int8(x, err)
        total = total + dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(x),
                               atol=float(jnp.abs(x).max()) / 100 + 1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataCfg(global_batch=8, seq_len=16, vocab=100, seed=3)
    p1 = TokenPipeline(cfg, host_id=0, n_hosts=1)
    batches = [p1.next_batch()["tokens"] for _ in range(5)]
    # resume from step 3
    p2 = TokenPipeline(cfg, host_id=0, n_hosts=1)
    for _ in range(3):
        p2.next_batch()
    st3 = p2.state_dict()
    p3 = TokenPipeline(cfg, host_id=0, n_hosts=1)
    p3.load_state_dict(st3)
    np.testing.assert_array_equal(p3.next_batch()["tokens"], batches[3])
    np.testing.assert_array_equal(p3.next_batch()["tokens"], batches[4])


def test_pipeline_host_shards_disjoint():
    cfg = DataCfg(global_batch=8, seq_len=16, vocab=1000, seed=1)
    a = TokenPipeline(cfg, host_id=0, n_hosts=2).next_batch()["tokens"]
    b = TokenPipeline(cfg, host_id=1, n_hosts=2).next_batch()["tokens"]
    assert a.shape == b.shape == (4, 16)
    assert not np.array_equal(a, b)


def test_pipeline_tokens_in_vocab():
    cfg = DataCfg(global_batch=4, seq_len=64, vocab=97, seed=0)
    t = TokenPipeline(cfg).next_batch()["tokens"]
    assert t.min() >= 0 and t.max() < 97


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree),
                 extra={"step": step})
    assert mgr.all_steps() == [20, 30]          # keep=2
    restored, extra = mgr.restore(30, tree)
    np.testing.assert_allclose(restored["w"], tree["w"] * 30)
    assert extra["step"] == 30


def test_ckpt_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones(3)}
    mgr.save(1, tree)
    # simulate a crash mid-write: directory exists, no COMMITTED marker
    os.makedirs(tmp_path / "step_00000002")
    assert mgr.latest_step() == 1


def test_ckpt_async_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.full((5,), 7.0)}
    mgr.save_async(5, tree)
    mgr.wait()
    out = mgr.restore_latest(tree)
    assert out is not None
    step, restored, _ = out
    assert step == 5
    np.testing.assert_allclose(restored["w"], tree["w"])


def test_ckpt_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.ones((3, 3))})


# ---------------------------------------------------------------------------
# runtime: fault tolerance + straggler
# ---------------------------------------------------------------------------

def test_ft_loop_retries_then_succeeds(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    fails = {"n": 2}

    def step_fn(i, state):
        if i == 3 and fails["n"]:
            fails["n"] -= 1
            raise RuntimeError("transient")
        return {"v": state["v"] + 1}

    loop = FaultTolerantLoop(mgr, save_every=100, max_retries=3,
                             async_save=False)
    final, state = loop.run(state={"v": jnp.zeros(())}, step_fn=step_fn,
                            n_steps=5)
    assert final == 5 and float(state["v"]) == 5.0


def test_ft_loop_persistent_failure_saves_and_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    def bad(i, state):
        raise RuntimeError("dead host")

    loop = FaultTolerantLoop(mgr, save_every=100, max_retries=1,
                             async_save=False)
    with pytest.raises(RuntimeError):
        loop.run(state={"v": jnp.zeros(())}, step_fn=bad, n_steps=3)
    assert mgr.latest_step() == 0               # final save happened


def test_ft_loop_checkpoints_every_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10)
    loop = FaultTolerantLoop(mgr, save_every=2, async_save=False)
    loop.run(state={"v": jnp.zeros(())},
             step_fn=lambda i, s: {"v": s["v"] + 1}, n_steps=5)
    assert 2 in mgr.all_steps() and 4 in mgr.all_steps()
    assert 5 in mgr.all_steps()                 # final flush


def test_straggler_monitor_flags_sustained_outlier():
    m = StragglerMonitor(patience=3, warmup=3)
    flagged = False
    for _ in range(10):
        flagged = m.observe(0.10 + np.random.default_rng(0).normal() * 1e-3)
    assert not flagged
    for _ in range(3):
        flagged = m.observe(0.50)
    assert flagged


def test_straggler_aggregator_identifies_host():
    agg = HostStragglerAggregator(n_hosts=4, patience=2)
    reported = []
    for step in range(12):
        times = {h: 0.1 for h in range(4)}
        if step >= 6:
            times[2] = 0.4                      # host 2 goes slow
        reported.extend(agg.observe(times))
    assert reported == [2]                      # one-shot: exactly once

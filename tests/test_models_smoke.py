"""Per-arch smoke: reduced config, one train step + one decode step on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs import shapes as sh
from repro.models.lm import build, chunked_xent, param_count


def _is_axes(t):
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in t)


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch, key):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(key)
    assert param_count(params) > 0
    cell = sh.ShapeCell("t", "train", 64, 2)
    batch = sh.make_synthetic_batch(model, cell, key)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss {loss}"
    assert float(metrics["tokens"]) > 0
    # grads exist and are finite for every leaf
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.all(jnp.isfinite(leaf))), (arch, path)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch, key):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(key)
    state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.decode_state_shapes(2, 16))
    logits, state2 = jax.jit(model.serve_step)(
        params, jnp.zeros((2,), jnp.int32), state)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # state treedef preserved
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_axes_tree_matches_params(arch, key):
    """Every param leaf has a logical-axes annotation of the right rank."""
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    shapes = model.param_shapes()
    axes = model.axes()
    leaves_s, td_s = jax.tree.flatten(shapes)
    leaves_a = td_s.flatten_up_to(
        jax.tree.map(lambda t: t, axes, is_leaf=_is_axes))
    for s, a in zip(leaves_s, leaves_a):
        assert _is_axes(a)
        assert len(a) == len(s.shape), (arch, a, s.shape)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "seamless-m4t-medium"])
def test_prefill_then_decode_consistent(arch, key):
    """greedy(prefill → decode) == greedy(full forward) for the next token."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    model = build(cfg)
    params = model.init(key)
    cell = sh.ShapeCell("t", "train", 32, 2)
    batch = sh.make_synthetic_batch(model, cell, key)
    logits, state = model.prefill(params, batch, gen_budget=8)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)
    logits2, state = model.serve_step(params, tok, state)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    c = get_config("deepseek-moe-16b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (28, 2048, 16, 102400)
    assert (c.n_experts, c.top_k, c.n_shared) == (64, 6, 2)
    c = get_config("grok-1-314b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (64, 6144, 48, 8)
    assert (c.d_ff_expert, c.vocab, c.n_experts, c.top_k) == (32768, 131072, 8, 2)
    c = get_config("stablelm-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (32, 2560, 32, 6912, 50304)
    c = get_config("gemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (18, 2048, 8, 1)
    assert (c.d_ff, c.vocab, c.hd) == (16384, 256000, 256)
    c = get_config("tinyllama-1.1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (22, 2048, 32, 4, 5632, 32000)
    c = get_config("qwen3-1.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 2048, 16, 8, 6144, 151936)
    assert c.qk_norm
    c = get_config("qwen2-vl-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 1536, 12, 2, 8960, 151936)
    assert c.mrope_sections is not None
    c = get_config("mamba2-1.3b")
    assert (c.n_layers, c.d_model, c.vocab, c.ssd_state) == \
        (48, 2048, 50280, 128)
    c = get_config("seamless-m4t-medium")
    assert (c.d_model, c.n_heads, c.d_ff, c.vocab) == (1024, 16, 4096, 256206)
    assert (c.n_enc_layers, c.n_dec_layers) == (12, 12)
    c = get_config("jamba-v0.1-52b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 8, 14336, 65536)
    assert (c.n_experts, c.top_k, c.attn_period) == (16, 2, 8)


def test_int8_kv_cache_matches_bf16_decode(key):
    """int8 KV serving: greedy tokens identical, logits within 2%."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b", smoke=True),
                              dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build(cfg), build(cfg8)
    params = m.init(key)
    s = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype),
                     m.decode_state_shapes(2, 16))
    s8 = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype),
                      m8.decode_state_shapes(2, 16))
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(s8))
    toks = jnp.zeros((2,), jnp.int32)
    for _ in range(4):
        lo, s = m.serve_step(params, toks, s)
        lo8, s8 = m8.serve_step(params, toks, s8)
        rel = float(jnp.abs(lo - lo8).max() / jnp.abs(lo).max())
        assert rel < 0.02, rel
        assert bool((jnp.argmax(lo, -1) == jnp.argmax(lo8, -1)).all())
        toks = jnp.argmax(lo[:, :cfg.vocab], -1).astype(jnp.int32)


def test_chunked_xent_equals_full_softmax():
    """The Fig-4 loss path == naive full-logits cross entropy."""
    key = jax.random.key(7)
    B, T, E, V, Vp = 2, 48, 32, 100, 128
    h = jax.random.normal(key, (B, T, E))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, Vp)) * 0.2
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, T), 0, V)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, T)) > 0.3
            ).astype(jnp.float32)
    nll, zl, n = chunked_xent(h, w, labels, mask, vocab=V, chunk=16,
                              z_loss_coef=0.0)
    logits = (h @ w).astype(jnp.float32)
    logits = jnp.where(jnp.arange(Vp)[None, None] < V, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    correct = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = ((lse - correct) * mask).sum()
    np.testing.assert_allclose(float(nll), float(ref), rtol=1e-5)
    np.testing.assert_allclose(float(n), float(mask.sum()), rtol=1e-6)


def test_chunked_xent_ragged_tail():
    """T not divisible by chunk: padded tokens must not contribute."""
    key = jax.random.key(8)
    B, T, E, Vp = 1, 50, 16, 64
    h = jax.random.normal(key, (B, T, E))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, Vp)) * 0.2
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, T), 0, Vp)
    mask = jnp.ones((B, T), jnp.float32)
    nll16, _, n16 = chunked_xent(h, w, labels, mask, vocab=Vp, chunk=16)
    nll50, _, n50 = chunked_xent(h, w, labels, mask, vocab=Vp, chunk=50)
    np.testing.assert_allclose(float(nll16), float(nll50), rtol=1e-5)
    assert float(n16) == float(n50) == 50.0

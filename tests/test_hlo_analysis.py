"""HLO accounting: trip-count recovery + collective/traffic accumulation."""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (collective_bytes, hbm_traffic_bytes,
                                       parse_computations, trip_count)

SYNTH = textwrap.dedent("""\
    HloModule synth

    %cond (p: (s32[], f32[8,128])) -> pred[] {
      %p = (s32[], f32[8,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %constant.1 = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %constant.1), direction=LT
    }

    %body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p = (s32[], f32[8,128]) parameter(0)
      %x = f32[8,128] get-tuple-element(%p), index=1
      %ar = f32[8,128] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
    }

    ENTRY %main (a: f32[8,128]) -> f32[8,128] {
      %a = f32[8,128] parameter(0)
      %ag = f32[32,128] all-gather(%a), replica_groups=[4,8]<=[32], dimensions={0}
      %w = (s32[], f32[8,128]) while(%tuple.0), condition=%cond, body=%body
      ROOT %out = f32[8,128] get-tuple-element(%w), index=1
    }
""")


def test_parse_computations_finds_all():
    comps = parse_computations(SYNTH)
    assert {"cond", "body", "main"} <= set(comps)


def test_trip_count_from_condition():
    comps = parse_computations(SYNTH)
    assert trip_count(comps["cond"]) == 12


def test_collective_bytes_multiplies_loop_trips():
    out = collective_bytes(SYNTH, 32)
    # all-reduce inside a 12-trip loop: 12 × 2·(3/4)·(8·128·4)
    ar = 12 * 2 * (3 / 4) * 8 * 128 * 4
    assert out["all-reduce"] == pytest.approx(ar)
    # all-gather at entry: group size 8 from iota format
    ag = (7 / 8) * 32 * 128 * 4
    assert out["all-gather"] == pytest.approx(ag)
    assert out["total"] == pytest.approx(ar + ag)


def test_real_compiled_module_roundtrip():
    """End-to-end on a real compiled jit fn with a scan."""
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()

    hlo = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    traffic = hbm_traffic_bytes(hlo)
    # ≥ 5 iterations × (read + write) of the 16 KiB matmul result
    assert traffic >= 5 * 2 * 64 * 64 * 4
    colls = collective_bytes(hlo, 1)
    assert colls["total"] == 0.0

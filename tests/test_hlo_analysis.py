"""HLO accounting: trip-count recovery + collective/traffic accumulation."""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (collective_bytes, hbm_traffic_bytes,
                                       parse_computations, trip_count)

SYNTH = textwrap.dedent("""\
    HloModule synth

    %cond (p: (s32[], f32[8,128])) -> pred[] {
      %p = (s32[], f32[8,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %constant.1 = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %constant.1), direction=LT
    }

    %body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p = (s32[], f32[8,128]) parameter(0)
      %x = f32[8,128] get-tuple-element(%p), index=1
      %ar = f32[8,128] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
    }

    ENTRY %main (a: f32[8,128]) -> f32[8,128] {
      %a = f32[8,128] parameter(0)
      %ag = f32[32,128] all-gather(%a), replica_groups=[4,8]<=[32], dimensions={0}
      %w = (s32[], f32[8,128]) while(%tuple.0), condition=%cond, body=%body
      ROOT %out = f32[8,128] get-tuple-element(%w), index=1
    }
""")


def test_parse_computations_finds_all():
    comps = parse_computations(SYNTH)
    assert {"cond", "body", "main"} <= set(comps)


def test_trip_count_from_condition():
    comps = parse_computations(SYNTH)
    assert trip_count(comps["cond"]) == 12


def test_collective_bytes_multiplies_loop_trips():
    out = collective_bytes(SYNTH, 32)
    # all-reduce inside a 12-trip loop: 12 × 2·(3/4)·(8·128·4)
    ar = 12 * 2 * (3 / 4) * 8 * 128 * 4
    assert out["all-reduce"] == pytest.approx(ar)
    # all-gather at entry: group size 8 from iota format
    ag = (7 / 8) * 32 * 128 * 4
    assert out["all-gather"] == pytest.approx(ag)
    assert out["total"] == pytest.approx(ar + ag)


def test_real_compiled_module_roundtrip():
    """End-to-end on a real compiled jit fn with a scan."""
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()

    hlo = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    traffic = hbm_traffic_bytes(hlo)
    # ≥ 5 iterations × (read + write) of the 16 KiB matmul result
    assert traffic >= 5 * 2 * 64 * 64 * 4
    colls = collective_bytes(hlo, 1)
    assert colls["total"] == 0.0


# ---------------------------------------------------------------------------
# dtype sizing: every width explicit, unknowns refuse to guess
# ---------------------------------------------------------------------------

def test_dtype_bytes_covers_model_emitted_dtypes():
    from repro.launch.hlo_analysis import dtype_bytes
    widths = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
              "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
              "pred": 1, "s4": 1, "u4": 1, "c64": 8, "c128": 16,
              "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e5m2": 1, "f8e5m2fnuz": 1}
    for dt, want in widths.items():
        assert dtype_bytes(dt) == want, dt


def test_dtype_bytes_zero_sized_tokens():
    from repro.launch.hlo_analysis import dtype_bytes
    assert dtype_bytes("token") == 0
    assert dtype_bytes("opaque") == 0


def test_dtype_bytes_raises_on_unknown():
    """The pre-fix accountant defaulted unknown dtypes to 4 bytes — a
    silent 2–8× skew on any bf16/f8 buffer it mis-parsed.  Unknowns must
    fail loudly instead."""
    from repro.launch.hlo_analysis import dtype_bytes
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        dtype_bytes("f128")
    with pytest.raises(ValueError, match="_DTYPE_BYTES"):
        dtype_bytes("bfloat16")     # the jnp spelling, not the HLO one


def test_shape_bytes_on_bf16_collective():
    """A bf16 all-reduce is half the f32 volume — the case the 4-byte
    default silently doubled."""
    hlo_f32 = SYNTH
    hlo_bf16 = SYNTH.replace("f32[", "bf16[")
    f32 = collective_bytes(hlo_f32, 32)["total"]
    b16 = collective_bytes(hlo_bf16, 32)["total"]
    assert b16 == pytest.approx(f32 / 2)


def test_shape_bytes_token_operands_cost_nothing():
    hlo = textwrap.dedent("""\
        HloModule tok

        ENTRY %main (a: f32[8]) -> f32[8] {
          %a = f32[8] parameter(0)
          %t = token[] after-all()
          %ar = f32[8] all-reduce(%a), replica_groups={{0,1}}
          ROOT %out = f32[8] copy(%ar)
        }
    """)
    out = collective_bytes(hlo, 2)
    assert out["all-reduce"] == pytest.approx(2 * (1 / 2) * 8 * 4)

"""Serving tier (DESIGN.md §9): paged KV cache, router, traffic, sim.

The load-bearing test is the lockstep equivalence: a dense Server and a
paged Server driven over the same ragged two-wave workload must emit
bit-identical tokens AND bit-identical logits at every step — the paged
cache is a memory-layout change, not a numerics change.  The second wave
re-admits into recycled slots whose pages hold stale KV from the first
wave, which is exactly the case that corrupts silently if page zeroing /
overwrite-at-admission is wrong.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   T4_16G, V100_PAPER, lm_serving_meta,
                                   prefill_time, decode_step_time,
                                   serving_page_budget)
from repro.kernels.autotune import DEFAULT_TILES, autotune
from repro.core.planner import compile_plan
from repro.serving.metrics import RequestTiming, ServeMetrics, percentile
from repro.serving.paged_cache import (BlockTable, PageAllocator,
                                       PagedCacheConfig)
from repro.serving.router import route
from repro.serving.server import Request, Server, prompt_bucket
from repro.serving.sim import ServeScenario, compare
from repro.serving.traffic import TrafficCfg, make_trace


# ---------------------------------------------------------------------------
# paged_cache: allocator + block table (pure host-side, no jax)
# ---------------------------------------------------------------------------

def _pcfg(n_pages=9, page_size=4, max_pages=4):
    return PagedCacheConfig(n_pages, page_size, max_pages)


def test_paged_cache_config_geometry():
    cfg = _pcfg()
    assert cfg.max_len == 16
    assert cfg.usable_pages == 8
    assert cfg.pages_for(1) == 1
    assert cfg.pages_for(4) == 1
    assert cfg.pages_for(5) == 2
    with pytest.raises(ValueError):
        PagedCacheConfig(1, 4, 4)        # needs a trash page + one real


def test_allocator_all_or_nothing():
    alloc = PageAllocator(_pcfg())
    pages = alloc.alloc(0, 3)
    assert len(pages) == 3 and 0 not in pages       # never the trash page
    assert alloc.free_pages == 5
    with pytest.raises(MemoryError):
        alloc.alloc(1, 6)                # only 5 left: nothing granted
    assert alloc.free_pages == 5
    assert alloc.owned(1) == []


def test_allocator_free_recycles_and_guards_double_free():
    alloc = PageAllocator(_pcfg())
    first = alloc.alloc(0, 2)
    alloc.free_slot(0)
    assert alloc.free_pages == 8
    again = alloc.alloc(1, 2)
    assert set(again) == set(first)       # LIFO reuse of the freed pages
    alloc._owned[2] = [again[0]]          # simulate corrupt ownership
    alloc.free_slot(1)
    with pytest.raises(RuntimeError):
        alloc.free_slot(2)                # its page is already free


def test_block_table_assign_append_needs():
    cfg = _pcfg()
    bt = BlockTable(slots=2, cfg=cfg)
    bt.assign(0, [3, 5], pos=7)
    assert list(bt.table[0]) == [3, 5, 0, 0]
    assert not bt.needs_page(0)           # pos 7 lands in page 1 (=5)
    bt.pos[0] = 8
    assert bt.needs_page(0)               # page 2 unallocated
    bt.append_page(0, 7)
    assert not bt.needs_page(0)
    bt.clear(0)
    assert not bt.table[0].any() and bt.pos[0] == 0
    with pytest.raises(ValueError):
        bt.assign(1, [1, 2, 3, 4, 5], pos=0)


# ---------------------------------------------------------------------------
# metrics + traffic
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.random(101).tolist()
    for p in (0, 25, 50, 90, 99, 100):
        assert percentile(xs, p) == pytest.approx(np.percentile(xs, p))
    with pytest.raises(ValueError):
        percentile([], 50)


def test_request_timing_slos():
    tm = RequestTiming(rid=0, arrival=1.0, admitted=2.0, first_token=3.0,
                       finished=7.0, n_tokens=5)
    assert tm.ttft == 2.0
    assert tm.tpot == 1.0
    assert tm.e2e == 6.0
    m = ServeMetrics()
    with pytest.raises(ValueError):
        m.add(RequestTiming(rid=1, arrival=0.0))


def test_traffic_trace_deterministic_and_calibrated():
    cfg = TrafficCfg(rate=50.0, n_requests=20000)
    a, b = make_trace(cfg, seed=3), make_trace(cfg, seed=3)
    assert a == b
    assert make_trace(cfg, seed=4) != a
    ts = [x.t for x in a]
    assert ts == sorted(ts)
    # Pareto gaps with x_m=(α−1)/(α·rate) have mean 1/rate
    assert ts[-1] / len(ts) == pytest.approx(1 / 50.0, rel=0.1)
    assert {x.prompt_len for x in a} <= set(cfg.prompt_lens)
    assert {x.gen_len for x in a} <= set(cfg.gen_lens)


# ---------------------------------------------------------------------------
# router: prefill→compute-rich, decode→bandwidth-rich
# ---------------------------------------------------------------------------

def _mixed_spec():
    return ClusterSpec(groups=(DeviceGroup("8xv100", V100_PAPER, 8),
                               DeviceGroup("8xt4", T4_16G, 8)))


def test_router_splits_by_roofline():
    meta = lm_serving_meta(get_config("tinyllama-1.1b"))
    plan = route(meta, _mixed_spec(), mean_prompt=64, mean_gen=64,
                 page_size=64, batch_slots=16)
    # T4s are compute-rich per HBM byte → prefill; V100s have 3× the
    # memory bandwidth → decode
    assert {g.name for g in plan.prefill.groups} == {"8xt4"}
    assert {g.name for g in plan.decode.groups} == {"8xv100"}
    assert plan.request_rate > 0
    assert plan.page_budget > 0
    assert plan.concurrency > 0


def test_router_rejects_single_group():
    meta = lm_serving_meta(get_config("tinyllama-1.1b"))
    with pytest.raises(ValueError):
        route(meta, ClusterSpec.homogeneous(V100_PAPER, 8),
              mean_prompt=64, mean_gen=64, page_size=64, batch_slots=16)


def test_serving_rooflines_monotone():
    meta = lm_serving_meta(get_config("tinyllama-1.1b"))
    g = DeviceGroup("v100", V100_PAPER, 8)
    assert prefill_time(meta, g, 256) > prefill_time(meta, g, 64)
    assert decode_step_time(meta, g, 8, 8 * 2048) \
        > decode_step_time(meta, g, 8, 8 * 128)
    assert serving_page_budget(meta, g, 64) \
        > serving_page_budget(meta, g, 64, reserve=0.5)


# ---------------------------------------------------------------------------
# analytic simulator
# ---------------------------------------------------------------------------

def test_sim_conserves_requests_and_flagship_wins():
    meta = lm_serving_meta(get_config("tinyllama-1.1b"))
    plan = route(meta, _mixed_spec(), mean_prompt=60, mean_gen=74,
                 page_size=64, batch_slots=64)
    sc = ServeScenario(
        name="t", spec=_mixed_spec(),
        traffic=TrafficCfg(rate=0.8 * plan.request_rate, n_requests=400,
                           gen_lens=(32, 64, 128)),
        batch_slots=64, page_size=64, max_len=4096)
    r = compare(meta, sc)
    assert r["colocated"]["completed"] == 400
    assert r["disagg"]["completed"] == 400
    assert r["tokens_per_s_ratio"] > 1.0
    assert r["ttft_p99_ratio"] <= 1.0


# ---------------------------------------------------------------------------
# autotuner: per-hardware page size
# ---------------------------------------------------------------------------

def test_autotuned_page_size():
    assert DEFAULT_TILES.page_size == 64
    kw = dict(head_dim=128, group=4, d_model=2048)
    v100 = autotune(V100_PAPER, **kw).page_size
    p100 = autotune(P100_16G, **kw).page_size
    assert 8 <= p100 <= v100 <= 256       # monotone in VMEM budget
    t = dataclasses.replace(V100_PAPER, vmem_bytes=2 * V100_PAPER.vmem_bytes)
    assert autotune(t, **kw).page_size >= v100


# ---------------------------------------------------------------------------
# jax-level: prompt bucketing + paged ↔ dense lockstep equivalence
# ---------------------------------------------------------------------------

MAX_LEN = 32
PAGE = 8
SLOTS = 3


@pytest.fixture(scope="module")
def served():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    from repro.models.lm import build
    model = build(cfg)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    plan = compile_plan(model, mesh)
    with mesh:
        params = plan.init_params(jax.random.key(0))
    return model, plan, params


def test_prompt_bucket_pow2():
    assert prompt_bucket(1, 64) == 8
    assert prompt_bucket(8, 64) == 8
    assert prompt_bucket(9, 64) == 16
    assert prompt_bucket(33, 64) == 64
    assert prompt_bucket(64, 64) == 64
    with pytest.raises(ValueError):
        prompt_bucket(65, 64)


@pytest.mark.slow
def test_prefill_jit_cache_bounded(served):
    """S1 regression: admitting every prompt length 3..20 must compile
    O(log max_len) prefill programs (buckets {8, 16, 32}), not one per
    distinct length."""
    model, plan, params = served
    server = Server(model, plan, batch_slots=2, max_len=MAX_LEN)
    for i, s in enumerate(range(3, 21)):
        prompt = np.arange(s, dtype=np.int32) % model.cfg.vocab
        # max_new=1 → finishes at admission, the slot never fills
        server.admit(params, Request(i, prompt, max_new=1), slot=0)
    assert server.prefill_cache_size <= 3
    assert set(server._prefill_fns) <= {8, 16, 32}


def _drive_lockstep(model, plan, params, requests_spec):
    """Run dense and paged servers over the same workload in lockstep,
    asserting bit-identical tokens and logits at every step."""
    servers = {
        "dense": Server(model, plan, batch_slots=SLOTS, max_len=MAX_LEN,
                        cache="dense", record_logits=True),
        "paged": Server(model, plan, batch_slots=SLOTS, max_len=MAX_LEN,
                        cache="paged", page_size=PAGE, record_logits=True),
    }
    pendings = {arm: [Request(i, p.copy(), max_new=g)
                      for i, (p, g) in enumerate(requests_spec)]
                for arm in servers}
    dones = {arm: [] for arm in servers}
    for _ in range(10_000):
        if not any(pendings[a] or servers[a].active for a in servers):
            break
        active_sets = {}
        for arm, srv in servers.items():
            pending = pendings[arm]
            while (pending and (slot := srv.free_slot()) is not None
                   and srv.can_admit(pending[0])):
                req = pending.pop(0)
                srv.admit(params, req, slot)
                if req.done:
                    dones[arm].append(req)
            active_sets[arm] = tuple(b for b, r in enumerate(srv.slots)
                                     if r is not None)
        assert active_sets["dense"] == active_sets["paged"]
        for arm, srv in servers.items():
            dones[arm].extend(srv.step(params))
            pendings[arm][:0] = srv.take_requeued()
        for b in active_sets["dense"]:
            assert np.array_equal(servers["dense"].last_logits[b],
                                  servers["paged"].last_logits[b]), \
                f"slot {b}: paged logits diverged from dense"
    else:
        raise AssertionError("lockstep drive did not converge")
    return servers, dones


@pytest.mark.slow
def test_paged_equals_dense_lockstep_two_waves(served):
    """S3: ragged prompts, more requests than slots — the second wave
    re-admits into recycled slots whose pages hold stale first-wave KV.
    Tokens and per-step logits must be bit-identical (fp32)."""
    model, plan, params = served
    rng = np.random.default_rng(7)
    spec = [(rng.integers(0, model.cfg.vocab, s, dtype=np.int32), g)
            for s, g in [(3, 6), (7, 9), (12, 5),      # wave 1 (ragged)
                         (5, 8), (9, 4), (16, 7)]]     # wave 2 (recycled)
    servers, dones = _drive_lockstep(model, plan, params, spec)
    assert len(dones["dense"]) == len(dones["paged"]) == len(spec)
    by_rid = {arm: {r.rid: r for r in dones[arm]} for arm in dones}
    for rid in by_rid["dense"]:
        assert by_rid["dense"][rid].out_tokens \
            == by_rid["paged"][rid].out_tokens, f"request {rid} diverged"
        assert np.array_equal(by_rid["dense"][rid].first_logits,
                              by_rid["paged"][rid].first_logits)
    # the trash page stayed exactly zero (live-mask on the scatter)
    for name in servers["paged"].pools:
        for kv in ("k", "v"):
            page0 = np.asarray(servers["paged"].pools[name][kv][:, 0])
            assert not page0.any()


@pytest.mark.slow
def test_paged_preemption_still_exact(served):
    """Pool too small for every slot's full sequence: decode-time page
    appends preempt the youngest slot, it restarts, and the final tokens
    still match the dense arm exactly (dense never preempts — only the
    schedule differs, so compare converged out_tokens per request)."""
    model, plan, params = served
    rng = np.random.default_rng(11)
    spec = [(rng.integers(0, model.cfg.vocab, 6, dtype=np.int32), 14)
            for _ in range(3)]
    dense = Server(model, plan, batch_slots=SLOTS, max_len=MAX_LEN,
                   cache="dense")
    # 7 usable pages of 8 rows; 3 slots × ceil(20/8)=3 pages don't fit
    paged = Server(model, plan, batch_slots=SLOTS, max_len=MAX_LEN,
                   cache="paged", page_size=PAGE, n_pages=8)
    results = {}
    for arm, srv in (("dense", dense), ("paged", paged)):
        pending = [Request(i, p.copy(), max_new=g)
                   for i, (p, g) in enumerate(spec)]
        done = []
        for _ in range(10_000):
            if not (pending or srv.active):
                break
            while (pending and (slot := srv.free_slot()) is not None
                   and srv.can_admit(pending[0])):
                req = pending.pop(0)
                srv.admit(params, req, slot)
                if req.done:
                    done.append(req)
            done.extend(srv.step(params))
            pending[:0] = srv.take_requeued()
        else:
            raise AssertionError("drive did not converge")
        results[arm] = {r.rid: r for r in done}
    assert sum(r.preemptions for r in results["paged"].values()) > 0, \
        "tight pool never preempted — the scenario lost its point"
    for rid, r in results["dense"].items():
        assert r.out_tokens == results["paged"][rid].out_tokens, \
            f"request {rid}: tokens diverged after preemption/restart"


@pytest.mark.slow
def test_pallas_paged_decode_matches_ref(served):
    """The Pallas gather-decode kernel (interpret mode on CPU) against a
    straight jnp reference over the same block table."""
    from repro.kernels.flash_attention import paged_decode
    B, H, K, D, ps, mp, P = 2, 4, 2, 16, 4, 3, 7
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k_pool = jax.random.normal(ks[1], (P, ps, K, D), jnp.float32)
    v_pool = jax.random.normal(ks[2], (P, ps, K, D), jnp.float32)
    table = jnp.array([[2, 5, 0], [1, 3, 6]], jnp.int32)
    pos = jnp.array([6, 9], jnp.int32)

    out = paged_decode(q, k_pool, v_pool, table, pos, interpret=True)

    # reference: gather pages logically, mask, softmax
    G = H // K
    kg = k_pool[table].reshape(B, mp * ps, K, D)
    vg = v_pool[table].reshape(B, mp * ps, K, D)
    qr = q.reshape(B, K, G, D) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, kg)
    mask = jnp.arange(mp * ps)[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgs,bskd->bkgd", p, vg).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_server_rejects_bad_geometry(served):
    model, plan, _ = served
    with pytest.raises(ValueError):
        Server(model, plan, batch_slots=2, max_len=30, cache="paged",
               page_size=8)              # max_len not a page multiple
    with pytest.raises(ValueError):
        Server(model, plan, batch_slots=2, max_len=32, cache="nope")

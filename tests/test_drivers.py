"""End-to-end driver tests: train.py (with resume) and serve.py as CLIs."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(args, devices: int = 2, timeout: int = 540):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_train_driver_then_resume(tmp_path):
    common = ["repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
              "--batch", "4", "--seq", "64", "--save-every", "4",
              "--ckpt-dir", str(tmp_path), "--log-every", "4"]
    out1 = run_cli(common + ["--steps", "6"])
    assert "[done] step 6" in out1
    out2 = run_cli(common + ["--steps", "10"])
    assert "[resume] from step 6" in out2
    assert "[done] step 10" in out2


@pytest.mark.slow
def test_serve_driver(tmp_path):
    out = run_cli(["repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--smoke", "--requests", "4", "--batch-slots", "2",
                   "--gen", "4", "--prompt-len", "8", "--max-len", "16"])
    # regression: finished requests used to be freed from their slot in the
    # same pass that marked them done, so the driver's `done` list stayed
    # empty; the driver now exits non-zero unless every request completes
    assert "[serve/dense] 4 requests completed" in out


@pytest.mark.slow
def test_serve_driver_paged_preemption(tmp_path):
    """Paged CLI with a pool too small for all slots: preemption +
    requeue must still complete every request."""
    out = run_cli(["repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--smoke", "--requests", "6", "--batch-slots", "3",
                   "--gen", "24", "--prompt-len", "16", "--max-len", "64",
                   "--cache", "paged", "--page-size", "16", "--pages", "7"])
    assert "[serve/paged] 6 requests completed" in out


@pytest.mark.slow
def test_serve_driver_traffic_replay(tmp_path):
    """Open-loop traffic mode: every arrival completes with TTFT/TPOT
    accounting on the paged cache."""
    out = run_cli(["repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--smoke", "--traffic", "--cache", "paged",
                   "--requests", "10", "--batch-slots", "4", "--rate", "8",
                   "--gen", "8", "--prompt-len", "16", "--max-len", "64",
                   "--page-size", "16"])
    assert "traffic: 10 requests" in out
    assert "ttft p50/p99" in out


@pytest.mark.slow
def test_train_driver_self_healing_cli(tmp_path):
    """The --hosts CLI path: injected straggler → evict → rebalance."""
    out = run_cli(["repro.launch.train", "--arch", "tinyllama-1.1b",
                   "--smoke", "--steps", "12", "--batch", "8",
                   "--seq", "64", "--hosts", "2", "--inject-slow", "1:4:5",
                   "--straggler-warmup", "2", "--patience", "2",
                   "--save-every", "4", "--log-every", "4",
                   "--ckpt-dir", str(tmp_path),
                   "--overrides", "n_layers=2"], devices=4)
    assert "[evict] hosts [1]" in out
    assert "[rebalance] resumed" in out
    assert "phase DONE, 1 eviction(s)" in out


@pytest.mark.slow
def test_train_driver_multimodal_vlm(tmp_path):
    """--model alias + the vlm path: MultimodalPipeline feeds patch_embeds
    through the standard (non-pipelined) engine."""
    out = run_cli(["repro.launch.train", "--model", "qwen2-vl-2b",
                   "--smoke", "--steps", "3", "--batch", "2", "--seq", "64",
                   "--log-every", "1", "--ckpt-dir", str(tmp_path)])
    assert "[done] step 3" in out


def test_train_driver_vlm_rejects_pp(tmp_path):
    """The executable pipeline engine cannot stage the vision frontend:
    --pp on a vlm arch must fail loudly, and --auto must never route
    there (regression: auto used to pick pp=2 and crash in M-RoPE)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--model",
         "qwen2-vl-2b", "--smoke", "--pp", "2", "--steps", "1",
         "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=300, env=env, cwd=ROOT)
    assert p.returncode != 0
    assert "does not apply to vlm" in p.stderr


@pytest.mark.slow
def test_train_driver_auto_vlm_stays_unpipelined(tmp_path):
    out = run_cli(["repro.launch.train", "--model", "qwen2-vl-2b",
                   "--smoke", "--auto", "--steps", "2", "--batch", "4",
                   "--seq", "32", "--ckpt-dir", str(tmp_path)])
    assert "[auto] chose:" in out
    assert "pipeline" not in out.split("[auto] chose:")[1].splitlines()[0]
    assert "[done] step 2" in out

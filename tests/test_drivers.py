"""End-to-end driver tests: train.py (with resume) and serve.py as CLIs."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(args, devices: int = 2, timeout: int = 540):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_train_driver_then_resume(tmp_path):
    common = ["repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
              "--batch", "4", "--seq", "64", "--save-every", "4",
              "--ckpt-dir", str(tmp_path), "--log-every", "4"]
    out1 = run_cli(common + ["--steps", "6"])
    assert "[done] step 6" in out1
    out2 = run_cli(common + ["--steps", "10"])
    assert "[resume] from step 6" in out2
    assert "[done] step 10" in out2


@pytest.mark.slow
def test_serve_driver(tmp_path):
    out = run_cli(["repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--smoke", "--requests", "4", "--batch-slots", "2",
                   "--gen", "4", "--prompt-len", "8", "--max-len", "16"])
    # regression: finished requests used to be freed from their slot in the
    # same pass that marked them done, so the driver's `done` list stayed
    # empty; the driver now exits non-zero unless every request completes
    assert "[serve] 4 requests completed" in out


@pytest.mark.slow
def test_train_driver_self_healing_cli(tmp_path):
    """The --hosts CLI path: injected straggler → evict → rebalance."""
    out = run_cli(["repro.launch.train", "--arch", "tinyllama-1.1b",
                   "--smoke", "--steps", "12", "--batch", "8",
                   "--seq", "64", "--hosts", "2", "--inject-slow", "1:4:5",
                   "--straggler-warmup", "2", "--patience", "2",
                   "--save-every", "4", "--log-every", "4",
                   "--ckpt-dir", str(tmp_path),
                   "--overrides", "n_layers=2"], devices=4)
    assert "[evict] hosts [1]" in out
    assert "[rebalance] resumed" in out
    assert "phase DONE, 1 eviction(s)" in out

"""Event-driven cluster-membership runtime (DESIGN.md §12).

The machine layer (transition table, event folding/deferral, the merge
algebra of MembershipChange), the grow-side topology/cluster APIs
(with_host first-fit, grow_devices, grow_cluster), the injector's
one-shot membership playback and its topology grounding, and the
abort-without-commit loop discipline all run in-process.  The end-to-end
spot scenarios (drain within deadline → shed → re-admit → regrow;
deadline missed → fall back to the last committed checkpoint
exactly-once) run in subprocesses with virtual CPU devices.
"""
import itertools
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.cost_model import (ClusterSpec, DeviceGroup, T4_16G,
                                   TPU_V5E, V100_PAPER)
from repro.core.hetero import grow_cluster, shrink_cluster
from repro.data.pipeline import DataCfg, TokenPipeline
from repro.runtime.controller import (DONE, DRAINING, FAILED, PREEMPTED,
                                      REBALANCING, RESUMING, RUNNING,
                                      TERMINAL, _TRANSITIONS, ClusterEvent,
                                      DriftSustained, HostJoin, HostLost,
                                      IllegalTransition, InjectorSource,
                                      MembershipChange,
                                      MembershipStateMachine,
                                      PreemptionWarning, StragglerSustained,
                                      change_for)
from repro.runtime.elastic import (HostTopology, SimHost, grow_devices,
                                   shrink_devices)
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.faults import FaultInjector, JoinHost, SpotPreemption
from repro.runtime.straggler import HostStragglerAggregator

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_STATES = (RUNNING, DRAINING, REBALANCING, RESUMING, DONE, PREEMPTED,
              FAILED)


def _events(step=3):
    """One instance of every concrete event type."""
    return (StragglerSustained(step=step, host=1, dt=0.4),
            DriftSustained(step=step, skew=1.5),
            PreemptionWarning(step=step, host=1, deadline_step=step + 2),
            HostLost(step=step, host=1),
            HostJoin(step=step, host=SimHost(7, TPU_V5E, 2)))


def run_py(code: str, devices: int = 4, timeout: int = 540):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# state machine: the transition table is exhaustive and enforced
# ---------------------------------------------------------------------------

def test_transition_table_covers_every_state_pair():
    """to() permits exactly the table's edges — every other (from, to)
    pair raises IllegalTransition.  Exhaustive over all 7×7 pairs."""
    assert set(_TRANSITIONS) == set(ALL_STATES)
    for src, dst in itertools.product(ALL_STATES, ALL_STATES):
        m = MembershipStateMachine(state=src)
        if dst in _TRANSITIONS[src]:
            m.to(dst)
            assert m.state == dst
        else:
            with pytest.raises(IllegalTransition):
                m.to(dst)
            assert m.state == src          # a refused transition is a no-op


def test_terminal_states_have_no_exits():
    for t in TERMINAL:
        assert _TRANSITIONS[t] == frozenset()


def test_on_event_from_every_state_for_every_event_type():
    """RUNNING starts a drain, DRAINING folds in place, REBALANCING and
    RESUMING defer, terminal states raise — for all five event types."""
    for ev in _events():
        m = MembershipStateMachine()                       # RUNNING
        assert m.on_event(ev) is True
        assert m.state == DRAINING
        assert m.pending == change_for(ev)

        assert m.on_event(ev) is True                      # DRAINING: merge
        assert m.state == DRAINING
        assert m.pending == change_for(ev).merged(change_for(ev))
        assert m.deferred == ()

        for busy in (REBALANCING, RESUMING):
            b = MembershipStateMachine(state=busy)
            assert b.on_event(ev) is False                 # deferred, not
            assert b.pending.is_noop                       # folded
            assert b.deferred == (ev,)
            assert b.state == busy

        for t in TERMINAL:
            dead = MembershipStateMachine(state=t)
            with pytest.raises(IllegalTransition, match=t):
                dead.on_event(ev)


def test_take_and_take_deferred_clear():
    m = MembershipStateMachine()
    ev = StragglerSustained(step=2, host=0)
    m.on_event(ev)
    assert m.take() == change_for(ev)
    assert m.pending.is_noop                               # cleared
    m2 = MembershipStateMachine(state=REBALANCING)
    m2.on_event(ev)
    assert m2.take_deferred() == (ev,)
    assert m2.take_deferred() == ()                        # cleared


# ---------------------------------------------------------------------------
# change_for + the MembershipChange merge algebra
# ---------------------------------------------------------------------------

def test_change_for_every_event_type():
    s, d, w, l, j = _events(step=5)
    assert change_for(s) == MembershipChange(
        evict=(1,), reasons=("StragglerSustained",))
    assert change_for(d) == MembershipChange(
        recalibrate=1.5, reasons=("DriftSustained",))
    assert change_for(w) == MembershipChange(
        evict=(1,), deadline_step=7, reasons=("PreemptionWarning",))
    assert change_for(l) == MembershipChange(
        evict=(1,), abort=True, reasons=("HostLost",))
    assert change_for(j).admit == (j.host,)
    with pytest.raises(TypeError, match="not a ClusterEvent"):
        change_for(ClusterEvent(step=0))
    with pytest.raises(TypeError):
        change_for("straggler on host 1")


def test_membership_change_merge_semantics():
    a = MembershipChange(evict=(1, 2), deadline_step=9,
                         admit=(SimHost(5, TPU_V5E, 2),),
                         recalibrate=1.2, reasons=("A",))
    b = MembershipChange(evict=(2, 3), deadline_step=7, abort=True,
                         admit=(SimHost(5, TPU_V5E, 4),
                                SimHost(6, TPU_V5E, 2)),
                         recalibrate=1.5, reasons=("B",))
    m = a.merged(b)
    assert m.evict == (1, 2, 3)             # dedupe-union, order preserved
    # admit dedupes by host id — first sighting wins (5 keeps 2 devices)
    assert [(h.host, h.n_devices) for h in m.admit] == [(5, 2), (6, 2)]
    assert m.recalibrate == 1.5             # max skew
    assert m.abort is True                  # sticky OR
    assert m.deadline_step == 7             # earliest deadline binds
    assert m.reasons == ("A", "B")
    # abort and deadline survive a merge with an empty change, both ways
    assert MembershipChange().merged(m).abort is True
    assert m.merged(MembershipChange()).deadline_step == 7
    assert MembershipChange().is_noop
    assert MembershipChange(abort=True).is_noop  # abort alone reshapes nothing
    assert not MembershipChange(evict=(1,)).is_noop


# ---------------------------------------------------------------------------
# grow-side topology: with_host first-fit + grow_devices
# ---------------------------------------------------------------------------

class _FakeDev:
    def __init__(self, i):
        self.id = i
        self.process_index = 0


def test_with_host_first_fit_reclaims_evicted_range():
    """A re-admitted host lands in the gap the eviction vacated — the
    flat device list never grows just because membership churned."""
    topo = HostTopology.uniform(3, 2, TPU_V5E)             # [0,2) [2,4) [4,6)
    surv = topo.without({1})                               # gap at [2,4)
    back = surv.with_host(SimHost(9, TPU_V5E, 2))
    assert {(h.host, h.offset) for h in back.hosts} == {
        (0, 0), (9, 2), (2, 4)}
    assert back.n_devices == 6
    devs = [_FakeDev(i) for i in range(6)]
    assert [d.id for d in back.devices(devs)] == [0, 1, 2, 3, 4, 5]
    # too big for the gap → appended past the tail
    wide = surv.with_host(SimHost(9, TPU_V5E, 3))
    assert {(h.host, h.offset) for h in wide.hosts} == {
        (0, 0), (2, 4), (9, 6)}


def test_with_host_loud_errors():
    topo = HostTopology.uniform(2, 2, TPU_V5E)
    with pytest.raises(ValueError, match="already a member"):
        topo.with_host(SimHost(1, TPU_V5E, 2))
    with pytest.raises(ValueError, match="at least one device"):
        topo.with_host(SimHost(5, TPU_V5E, 0))
    with pytest.raises(ValueError, match="overlapping"):
        topo.with_host(SimHost(5, TPU_V5E, 2, offset=1))
    # an explicit non-overlapping offset is honoured verbatim
    parked = topo.with_host(SimHost(5, TPU_V5E, 2, offset=10))
    assert {(h.host, h.offset) for h in parked.hosts} == {
        (0, 0), (1, 2), (5, 10)}


def test_grow_devices_round_trips_shrink():
    """Shed a mid-fleet host, re-admit it: the device list is restored
    (grow is the inverse of shrink, down to physical device identity)."""
    topo = HostTopology.uniform(3, 2, TPU_V5E)
    devs = [_FakeDev(i) for i in range(6)]
    before = [d.id for d in topo.devices(devs)]
    surv = topo.without({1})
    assert [d.id for d in shrink_devices(devs, {1}, topology=topo)] \
        == [d.id for d in surv.devices(devs)] == [0, 1, 4, 5]
    regrown_devs, regrown = grow_devices(
        devs, [SimHost(1, TPU_V5E, 2)], topology=surv)
    assert [d.id for d in regrown_devs] == before
    assert regrown.host_ids == (0, 1, 2)
    assert regrown.cluster_spec() == topo.cluster_spec()


# ---------------------------------------------------------------------------
# grow_cluster: group-keyed admission, inverse of shrink_cluster
# ---------------------------------------------------------------------------

def test_grow_cluster_adds_and_appends():
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 4),
                               DeviceGroup("t4", T4_16G, 4)))
    out = grow_cluster(spec, {"v100": 4})
    assert [(g.name, g.n_devices) for g in out.groups] == [("v100", 8),
                                                           ("t4", 4)]
    out = grow_cluster(spec, {}, new_groups=(
        DeviceGroup("tpu", TPU_V5E, 8),))
    assert [(g.name, g.n_devices) for g in out.groups] == [
        ("v100", 4), ("t4", 4), ("tpu", 8)]


def test_grow_cluster_loud_errors():
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 4),))
    with pytest.raises(ValueError, match="unknown device group"):
        grow_cluster(spec, {"t4": 2})
    with pytest.raises(ValueError, match="at least one device"):
        grow_cluster(spec, {"v100": 0})
    with pytest.raises(ValueError, match="collides"):
        grow_cluster(spec, {}, new_groups=(
            DeviceGroup("v100", V100_PAPER, 2),))
    with pytest.raises(ValueError, match="n_devices=0"):
        grow_cluster(spec, {}, new_groups=(DeviceGroup("t4", T4_16G, 0),))


def test_grow_cluster_inverts_shrink_cluster():
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                               DeviceGroup("t4", T4_16G, 4)))
    assert grow_cluster(shrink_cluster(spec, {"v100": 4}),
                        {"v100": 4}) == spec
    # a group shrunk to nothing comes back via new_groups
    gone = shrink_cluster(spec, {"t4": 4})
    assert grow_cluster(gone, {}, new_groups=(
        DeviceGroup("t4", T4_16G, 4),)) == spec


# ---------------------------------------------------------------------------
# shrink_devices: host-keyed unification (the deprecated callable form)
# ---------------------------------------------------------------------------

def test_shrink_devices_host_of_deprecated_but_agrees():
    """Mixed V100/T4 fleet: the deprecated ``host_of=`` callable form
    warns, and selects the identical survivors as the host-keyed
    ``topology=`` form and ``HostTopology.without``."""
    topo = HostTopology(hosts=(SimHost(0, V100_PAPER, 2),
                               SimHost(1, T4_16G, 4),
                               SimHost(2, V100_PAPER, 2)))
    devs = [_FakeDev(i) for i in range(topo.n_devices)]
    want = [d.id for d in shrink_devices(devs, {1}, topology=topo)]
    with pytest.warns(DeprecationWarning, match="host_of"):
        legacy = shrink_devices(devs, {1}, host_of=topo.host_of)
    assert [d.id for d in legacy] == want == [0, 1, 6, 7]
    assert [d.id for d in topo.without({1}).devices(devs)] == want


# ---------------------------------------------------------------------------
# data stream: growing the host count keeps the global stream invariant
# ---------------------------------------------------------------------------

def test_pipeline_reshard_up_keeps_global_stream():
    """Growing 1 → 2 hosts mid-stream: the concatenation of the new
    shards continues the exact global stream (the shrink-direction twin
    of test_pipeline_reshard_continues_stream)."""
    cfg = DataCfg(global_batch=8, seq_len=16, vocab=997, seed=5)
    full = TokenPipeline(cfg, host_id=0, n_hosts=1)
    ref = [full.next_batch()["tokens"] for _ in range(6)]
    p = TokenPipeline(cfg, host_id=0, n_hosts=1)
    for _ in range(3):
        p.next_batch()
    shards = [p.reshard(host_id=h, n_hosts=2) for h in range(2)]
    for step in range(3, 6):
        got = np.concatenate([s.next_batch()["tokens"] for s in shards])
        np.testing.assert_array_equal(got, ref[step])


# ---------------------------------------------------------------------------
# injector membership playback + InjectorSource topology grounding
# ---------------------------------------------------------------------------

def test_injector_membership_one_shot_and_late_delivery():
    inj = FaultInjector(scenarios=(
        SpotPreemption(host=1, warn_step=5, deadline_steps=2),
        JoinHost(host=2, step=3, n_devices=2)), n_hosts=2)
    assert inj.membership(2) == []
    # step 3 and 5 fell inside a (hypothetical) rebalance window: the
    # signals still deliver at the next polled step, each exactly once
    got = inj.membership(6)
    assert [(k, type(s).__name__) for k, s in got] == [
        ("preempt_warn", "SpotPreemption"), ("join", "JoinHost")]
    assert [k for k, _ in inj.membership(7)] == ["host_lost"]
    assert inj.membership(8) == [] and inj.membership(100) == []


def test_injector_zero_deadline_warn_and_lost_same_step():
    inj = FaultInjector(scenarios=(
        SpotPreemption(host=0, warn_step=4, deadline_steps=0),))
    assert [k for k, _ in inj.membership(4)] == ["preempt_warn",
                                                 "host_lost"]


def test_injector_source_grounds_events_against_live_topology():
    topo = HostTopology.uniform(2, 2, TPU_V5E)             # hosts 0, 1
    inj = FaultInjector(scenarios=(
        SpotPreemption(host=7, warn_step=1, deadline_steps=1),  # not ours
        SpotPreemption(host=1, warn_step=2, deadline_steps=2),
        JoinHost(host=0, step=2, n_devices=2),             # already present
        JoinHost(host=3, step=2, n_devices=2, hw=None)))   # hw defaulted
    src = InjectorSource(inj, default_hw=T4_16G)
    # the foreign host's warn/lost are consumed but emit nothing
    assert src.poll(1, {}, topo) == []
    evs = src.poll(2, {}, topo)
    kinds = {type(e).__name__ for e in evs}
    assert kinds == {"PreemptionWarning", "HostJoin"}
    warn = next(e for e in evs if isinstance(e, PreemptionWarning))
    assert warn.host == 1 and warn.deadline_step == 4
    join = next(e for e in evs if isinstance(e, HostJoin))
    assert (join.host.host, join.host.hw, join.host.n_devices) \
        == (3, T4_16G, 2)
    # after the shed, the host-lost for an already-absent host is dropped
    shed = topo.without({1})
    assert src.poll(4, {}, shed) == []


# ---------------------------------------------------------------------------
# aggregator: admission is the one way back in
# ---------------------------------------------------------------------------

def test_aggregator_admit_reverses_eviction():
    agg = HostStragglerAggregator(n_hosts=2, threshold=2.0, patience=1,
                                  warmup=2)
    agg.evict(1)
    assert agg.observe({0: 1.0, 1: 50.0}) == []            # ignored
    agg.admit(1)
    assert 1 in agg.monitors and agg.evicted == set()
    agg.reset([0, 1])                                      # no resurrection
    assert set(agg.monitors) == {0, 1}                     # needed: admitted
    for t in ({0: 1.0, 1: 1.0},) * 2:
        assert agg.observe(t) == []
    # a re-admitted host is watched like any other — it can re-flag
    assert agg.observe({0: 1.0, 1: 50.0}) == [1]


# ---------------------------------------------------------------------------
# abort: the drain-failed path commits NOTHING
# ---------------------------------------------------------------------------

def test_loop_request_abort_commits_nothing_past_last_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    loop = FaultTolerantLoop(mgr, save_every=4, async_save=False)

    def on_step(i, st, dt):
        if i == 5:
            loop.request_abort()

    step, state = loop.run(state={"x": np.zeros(())},
                           step_fn=lambda i, st: {"x": st["x"] + 1},
                           n_steps=100, on_step=on_step,
                           extra_fn=lambda st, s: {"pos": s})
    assert step == 6 and loop.aborted
    # the periodic save at 4 is the last commit — no final save at 6
    assert mgr.latest_step() == 4
    _, tree, extra = mgr.restore_latest({"x": np.zeros(())})
    assert float(tree["x"]) == 4.0 and extra["pos"] == 4
    # a normal run re-arms the flag
    step, _ = loop.run(state=state, step_fn=lambda i, st: st, n_steps=8,
                       start_step=step)
    assert step == 8 and not loop.aborted


# ---------------------------------------------------------------------------
# controller guards: the one apply path refuses to run out of phase
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_apply_membership_change_guards(tmp_path):
    from repro.configs import get_config
    from repro.models.lm import build
    from repro.optim import adamw
    from repro.runtime.controller import ClusterController, ElasticConfig
    cfg = get_config("tinyllama-1.1b", smoke=True)
    ctl = ClusterController(
        build(cfg), cfg, adamw(lr=1e-3),
        TokenPipeline(DataCfg(global_batch=8, seq_len=32, vocab=cfg.vocab,
                              seed=0)),
        CheckpointManager(str(tmp_path), keep=1),
        elastic=ElasticConfig(topology=HostTopology.uniform(2, 1, TPU_V5E)),
        batch=8, seq=32, verbose=False)
    assert ctl.phase == RUNNING
    with pytest.raises(IllegalTransition, match="outside REBALANCING"):
        ctl.apply_membership_change(MembershipChange(evict=(1,)), at_step=0)
    ctl.machine.state = REBALANCING
    with pytest.raises(ValueError, match="no-op"):
        ctl.apply_membership_change(MembershipChange(abort=True), at_step=0)


# ---------------------------------------------------------------------------
# end-to-end: spot drain → shed → re-admit → regrow
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_spot_drain_and_regrow_end_to_end(tmp_path):
    """Acceptance scenario: a spot notice drains host 1 within its
    deadline, the job rebalances onto the survivor, the host's capacity
    re-joins later, and the regrown plan's predicted step cost matches a
    never-preempted fleet's to within 5% (here: identical spec, so
    identical prediction).  The data stream is consumed exactly-once
    throughout — both membership changes committed their drains."""
    run_py(f"""
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.data.pipeline import DataCfg, TokenPipeline
        from repro.models.lm import build, model_graph
        from repro.optim import adamw
        from repro.runtime.controller import ClusterController, ElasticConfig
        from repro.runtime.elastic import HostTopology
        from repro.runtime.elastic import search_cluster
        from repro.runtime.faults import (FaultInjector, JoinHost,
                                          SpotPreemption)

        N = 24
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)

        class Recording(TokenPipeline):
            seen = []
            def next_batch(self):
                b = super().next_batch()
                Recording.seen.append(b["tokens"].tobytes())
                return b

        dcfg = DataCfg(global_batch=8, seq_len=32, vocab=cfg.vocab, seed=3)
        topo = HostTopology.uniform(2, 2, TPU_V5E)
        inj = FaultInjector(scenarios=(
            SpotPreemption(host=1, warn_step=6, deadline_steps=2),
            JoinHost(host=2, step=14, n_devices=2)), n_hosts=2,
            nominal=0.05)
        ctl = ClusterController(
            model, cfg, adamw(lr=1e-3), Recording(dcfg),
            CheckpointManager({str(tmp_path)!r}, keep=3),
            elastic=ElasticConfig(topology=topo, max_rebalances=4),
            batch=8, seq=32, save_every=4, injector=inj, log_every=100)
        out = ctl.run(N, seed=0)
        assert out["phase"] == "DONE" and out["final_step"] == N, out
        kinds = [e["kind"] for e in out["events"]]
        warns = [e for e in out["events"] if e["kind"] == "preempt_warn"]
        evicts = [e for e in out["events"] if e["kind"] == "evict"]
        joins = [e for e in out["events"] if e["kind"] == "join"]
        rebs = [e for e in out["events"] if e["kind"] == "rebalance"]
        assert warns and warns[0]["host"] == 1 \
            and warns[0]["deadline_step"] == 8, out["events"]
        # the drain beat the deadline: shed at or before step 8, no abort
        assert evicts and evicts[0]["hosts"] == [1] \
            and evicts[0]["step"] <= 8, out["events"]
        assert "host_lost" not in kinds, out["events"]
        assert joins and joins[0]["hosts"] == [2] \
            and joins[0]["total_devices"] == 4, out["events"]
        assert len(rebs) == 2, out["events"]
        # shed then regrown: back to 2 hosts x 2 devices
        assert out["topology"].host_ids == (0, 2)
        assert out["topology"].n_devices == 4

        # post-grow plan within 5% of the never-preempted plan's predicted
        # cost (ISSUE acceptance: re-admission restores full capacity)
        meta = model_graph(cfg, 8, 32).workload_meta()
        kw = {{"max_pp": 1}}
        t_grown = search_cluster(meta, out["topology"].cluster_spec(),
                                 search_kw=kw).total
        t_never = search_cluster(meta, topo.cluster_spec(),
                                 search_kw=kw).total
        assert abs(t_grown / t_never - 1.0) <= 0.05, (t_grown, t_never)

        # exactly-once: both drains committed, so no batch repeated/skipped
        ref = TokenPipeline(dcfg)
        want = [ref.next_batch()["tokens"].tobytes() for _ in range(N)]
        assert Recording.seen == want, (len(Recording.seen), len(want))
        print("OK spot drain+regrow:", kinds)
    """)


@pytest.mark.slow
def test_spot_deadline_missed_falls_back_exactly_once(tmp_path):
    """deadline_steps=0 models a missed notice: warn and loss land on the
    same step, no drain checkpoint can commit, and the controller must
    restore the last *committed* checkpoint and replay the lost steps on
    the survivors — each replayed step re-draws its original batch."""
    run_py(f"""
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.data.pipeline import DataCfg, TokenPipeline
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.runtime.controller import ClusterController, ElasticConfig
        from repro.runtime.elastic import HostTopology
        from repro.runtime.faults import FaultInjector, SpotPreemption

        N = 12
        SAVE = 4
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)

        class Recording(TokenPipeline):
            seen = []
            def next_batch(self):
                b = super().next_batch()
                Recording.seen.append(b["tokens"].tobytes())
                return b

        dcfg = DataCfg(global_batch=8, seq_len=32, vocab=cfg.vocab, seed=4)
        inj = FaultInjector(scenarios=(
            SpotPreemption(host=1, warn_step=6, deadline_steps=0),),
            n_hosts=2, nominal=0.05)
        ctl = ClusterController(
            model, cfg, adamw(lr=1e-3), Recording(dcfg),
            CheckpointManager({str(tmp_path)!r}, keep=3),
            elastic=ElasticConfig(topology=HostTopology.uniform(2, 2,
                                                               TPU_V5E)),
            batch=8, seq=32, save_every=SAVE, injector=inj, log_every=100)
        out = ctl.run(N, seed=0)
        assert out["phase"] == "DONE" and out["final_step"] == N, out
        lost = [e for e in out["events"] if e["kind"] == "host_lost"]
        evicts = [e for e in out["events"] if e["kind"] == "evict"]
        rebs = [e for e in out["events"] if e["kind"] == "rebalance"]
        assert lost and lost[0]["host"] == 1, out["events"]
        assert evicts and evicts[0]["hosts"] == [1], out["events"]
        # the abort threw away the uncommitted tail: the rebalance resumed
        # from the last periodic checkpoint, not from the abort step
        assert rebs and rebs[0]["step"] == SAVE, out["events"]
        assert out["topology"].host_ids == (0,)

        # exactly-once under replay: the run drew batches 0..6 (abort hit
        # after step 6 ran), fell back to step 4, then replayed 4..N-1
        # with byte-identical content — the committed trajectory saw each
        # batch exactly once
        lost_at = lost[0]["step"]
        ref = TokenPipeline(dcfg)
        want = [ref.next_batch()["tokens"].tobytes() for _ in range(N)]
        seen = Recording.seen
        assert seen == want[:lost_at + 1] + want[SAVE:], \
            (lost_at, len(seen), len(want))
        print("OK deadline missed: lost at", lost_at, "resumed at", SAVE)
    """)


@pytest.mark.slow
def test_pure_scale_up_join_end_to_end(tmp_path):
    """No failure at all: a host simply offers capacity mid-run and the
    controller grows onto it — the symmetric half of the evict loop."""
    run_py(f"""
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.data.pipeline import DataCfg, TokenPipeline
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.runtime.controller import ClusterController, ElasticConfig
        from repro.runtime.elastic import HostTopology
        from repro.runtime.faults import FaultInjector, JoinHost

        N = 12
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)
        dcfg = DataCfg(global_batch=8, seq_len=32, vocab=cfg.vocab, seed=6)
        inj = FaultInjector(scenarios=(JoinHost(host=1, step=5,
                                                n_devices=2),),
                            n_hosts=1, nominal=0.05)
        ctl = ClusterController(
            model, cfg, adamw(lr=1e-3), TokenPipeline(dcfg),
            CheckpointManager({str(tmp_path)!r}, keep=3),
            elastic=ElasticConfig(topology=HostTopology.uniform(1, 2,
                                                               TPU_V5E)),
            batch=8, seq=32, save_every=4, injector=inj, log_every=100)
        out = ctl.run(N, seed=0)
        assert out["phase"] == "DONE" and out["final_step"] == N, out
        joins = [e for e in out["events"] if e["kind"] == "join"]
        assert joins and joins[0]["hosts"] == [1], out["events"]
        assert out["topology"].host_ids == (0, 1)
        assert out["topology"].n_devices == 4
        assert not any(e["kind"] == "evict" for e in out["events"])
        print("OK scale-up join at step", joins[0]["step"])
    """)

"""Kernel families vs pure-jnp oracles: values AND gradients (interpret).

Built on tests/kernel_harness.py — see its module docstring for the
tolerance policy and for why SSD-pallas and quant are value-only.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ops import flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant.quant import dequantize, quantize
from repro.kernels.quant.ref import dequant_ref, quant_ref
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd.ssd import ssd_scan_pallas
from repro.kernels.xent.ops import xent, xent_with_lse
from repro.kernels.xent.ref import xent_ref
from repro.kernels.xent.xent import xent_fwd

from kernel_harness import check_fwd_bwd, rand, tol_for


def _qkv(key, B, Sq, Sk, H, K, D, dtype):
    q = rand(key, (B, Sq, H, D), dtype)
    k = rand(jax.random.fold_in(key, 1), (B, Sk, K, D), dtype)
    v = rand(jax.random.fold_in(key, 2), (B, Sk, K, D), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention: fwd + the custom-VJP backward kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,D,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),      # MHA
    (2, 256, 4, 2, 64, 128, 64),     # GQA group 2
    (1, 256, 8, 1, 64, 64, 128),     # MQA
    (1, 128, 4, 4, 16, 128, 128),    # block == seq (single block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_bwd_matches_ref(B, S, H, K, D, bq, bk, dtype):
    q, k, v = _qkv(jax.random.key(0), B, S, S, H, K, D, dtype)
    check_fwd_bwd(
        lambda q, k, v: flash(q, k, v, True, bq, bk, True, True),
        lambda q, k, v: attention_ref(q, k, v, causal=True),
        (q, k, v), diff_argnums=(0, 1, 2), tol=tol_for(dtype),
        msg=f"flash B{B}S{S}H{H}K{K}D{D}")


@pytest.mark.parametrize("remat", [True, False])
def test_flash_bwd_residual_policies_agree(remat):
    """bwd_remat only changes what is saved, never the gradients."""
    q, k, v = _qkv(jax.random.key(1), 1, 128, 128, 4, 2, 32, jnp.float32)
    check_fwd_bwd(
        lambda q, k, v: flash(q, k, v, True, 64, 64, True, remat),
        lambda q, k, v: attention_ref(q, k, v, causal=True),
        (q, k, v), diff_argnums=(0, 1, 2), tol=tol_for(jnp.float32),
        msg=f"flash remat={remat}")


def test_flash_non_causal_uneven_lengths():
    """Cross-attention shape: Sq != Sk, no mask, grads included."""
    q, k, v = _qkv(jax.random.key(2), 1, 128, 256, 2, 2, 32, jnp.float32)
    check_fwd_bwd(
        lambda q, k, v: flash(q, k, v, False, 64, 64, True, True),
        lambda q, k, v: attention_ref(q, k, v, causal=False),
        (q, k, v), diff_argnums=(0, 1, 2), tol=tol_for(jnp.float32),
        msg="flash non-causal Sq!=Sk")


def test_flash_lse_matches_ref():
    """The saved residual itself (logsumexp over keys) is exact."""
    q, k, v = _qkv(jax.random.key(3), 1, 128, 128, 2, 2, 32, jnp.float32)
    _, lse = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True, return_lse=True)
    D = q.shape[-1]
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) / (D ** 0.5)
    mask = jnp.arange(128)[:, None] >= jnp.arange(128)[None, :]
    s = jnp.where(mask[None, None], s, -jnp.inf)
    ref = jax.scipy.special.logsumexp(s, axis=-1)          # (B, H, Sq)
    got = jnp.moveaxis(lse.reshape(1, 128, 2), 2, 1)       # K*G == H here
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=2e-5)


def test_flash_rejects_ragged_blocks():
    q = jnp.zeros((1, 100, 2, 32))
    k = v = jnp.zeros((1, 100, 2, 32))
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)


def test_blocked_gqa_rejects_ragged_blocks():
    """Regression (PR 6): _blocked_gqa used to silently rewrite user
    block sizes that don't divide the sequence; it must now raise."""
    from repro.models.attention import _blocked_gqa
    q = jnp.zeros((1, 100, 2, 1, 16))
    k = v = jnp.zeros((1, 100, 2, 16))
    with pytest.raises(ValueError, match="must divide"):
        _blocked_gqa(q, k, v, causal=True, block_q=64, block_k=64)
    # block > seq stays benign: clamped to one block, no error
    out = _blocked_gqa(q, k, v, causal=True, block_q=512, block_k=512)
    assert out.shape == (1, 100, 2, 1, 16)


# ---------------------------------------------------------------------------
# fused xent: fwd + both custom VJPs (nll-only and nll+lse for z-loss)
# ---------------------------------------------------------------------------

def _xent_inputs(key, T, E, V, vocab):
    h = rand(key, (T, E))
    w = rand(jax.random.fold_in(key, 1), (E, V), scale=0.1)
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, vocab)
    return h, w, lab


@pytest.mark.parametrize("T,E,V,vocab,bt,bv", [
    (128, 64, 512, 500, 64, 128),        # padded vocab
    (256, 32, 1024, 1024, 128, 512),     # exact vocab
    (128, 128, 256, 256, 128, 256),      # single vocab tile
])
def test_xent_fwd_matches_ref(T, E, V, vocab, bt, bv):
    h, w, lab = _xent_inputs(jax.random.key(0), T, E, V, vocab)
    nll, lse = xent_fwd(h, w, lab, vocab=vocab, block_t=bt, block_v=bv,
                        interpret=True)
    nll_ref, lse_ref = xent_ref(h, w, lab, vocab=vocab)
    np.testing.assert_allclose(nll, nll_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(lse, lse_ref, atol=1e-4, rtol=1e-4)


def test_xent_custom_vjp_matches_autodiff():
    h, w, lab = _xent_inputs(jax.random.key(3), 128, 32, 512, 500)
    check_fwd_bwd(
        lambda h, w: xent(h, w, lab, 500, 64, 128, True),
        lambda h, w: xent_ref(h, w, lab, vocab=500)[0],
        (h, w), diff_argnums=(0, 1), tol=tol_for(jnp.float32),
        msg="xent nll")


def test_xent_with_lse_vjp_matches_autodiff():
    """Both outputs carry cotangents — the z-loss gradient path."""
    h, w, lab = _xent_inputs(jax.random.key(4), 128, 32, 512, 500)
    check_fwd_bwd(
        lambda h, w: xent_with_lse(h, w, lab, 500, 64, 128, True),
        lambda h, w: xent_ref(h, w, lab, vocab=500),
        (h, w), diff_argnums=(0, 1), tol=tol_for(jnp.float32),
        msg="xent nll+lse")


def test_fused_xent_loss_head_matches_chunked():
    """models.lm.fused_xent (the pallas loss head) ≡ chunked_xent, grads
    included — the hook `xent_impl="pallas"` routes training through."""
    from repro.models.lm import chunked_xent, fused_xent
    key = jax.random.key(5)
    B, T, E, V, vocab = 2, 64, 32, 512, 500
    h = rand(key, (B, T, E))
    w = rand(jax.random.fold_in(key, 1), (E, V), scale=0.1)
    lab = jax.random.randint(jax.random.fold_in(key, 2), (B, T), 0, vocab)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, T)) > 0.2) \
        .astype(jnp.float32)

    def total(fn):
        def s(h, w):
            nll, zl, n = fn(h, w)
            return (nll + zl) / jnp.maximum(n, 1.0)
        return s

    kern = total(lambda h, w: fused_xent(
        h, w, lab, mask, vocab=vocab, block_t=64, block_v=128,
        z_loss_coef=1e-3, interpret=True))
    ref = total(lambda h, w: chunked_xent(
        h, w, lab, mask, vocab=vocab, chunk=32, z_loss_coef=1e-3))
    np.testing.assert_allclose(kern(h, w), ref(h, w), atol=1e-5, rtol=1e-5)
    gk = jax.grad(kern, argnums=(0, 1))(h, w)
    gr = jax.grad(ref, argnums=(0, 1))(h, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# SSD: pallas fwd vs oracle; gradients via the trainable jnp twin
# (pallas_call with scratch accumulators has no autodiff — by design the
# training path is models.mamba2.ssd_scan, gradchecked below)
# ---------------------------------------------------------------------------

def _ssd_inputs(key, B, S, H, P, G, N):
    x = rand(key, (B, S, H, P))
    dt = jax.nn.softplus(rand(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(rand(jax.random.fold_in(key, 2), (H,), scale=0.3))
    Bm = rand(jax.random.fold_in(key, 3), (B, S, G, N), scale=0.3)
    Cm = rand(jax.random.fold_in(key, 4), (B, S, G, N), scale=0.3)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("B,S,H,P,G,N,C", [
    (1, 128, 2, 32, 1, 16, 64),
    (2, 256, 4, 16, 2, 32, 128),      # grouped B/C
    (1, 64, 2, 64, 1, 64, 64),        # single chunk
])
def test_ssd_pallas_matches_sequential_oracle(B, S, H, P, G, N, C):
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.key(0), B, S, H, P, G, N)
    y_ref, h_ref = ssd_ref(x, dt, A, Bm, Cm)
    y, hT = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=C, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(hT, h_ref, atol=5e-4, rtol=5e-4)


def test_ssd_trainable_path_fwd_bwd_matches_oracle():
    """models.mamba2.ssd_scan (what training differentiates) vs the
    sequential oracle — values and gradients."""
    from repro.models.mamba2 import ssd_scan
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.key(5), 2, 128, 4, 16, 1, 32)
    check_fwd_bwd(
        lambda x, dt, Bm, Cm: ssd_scan(x, dt, A, Bm, Cm, chunk=32)[0],
        lambda x, dt, Bm, Cm: ssd_ref(x, dt, A, Bm, Cm)[0],
        (x, dt, Bm, Cm), diff_argnums=(0, 1, 2, 3),
        tol=dataclasses.replace(tol_for(jnp.float32), fwd=5e-4, grad=5e-3),
        msg="ssd jnp chunked")


def test_ssd_decode_matches_scan():
    """O(1)-state decode steps reproduce the chunked scan token-by-token."""
    from repro.models import mamba2
    cfg = mamba2.SSDCfg(d_model=32, n_heads=2, headdim=32, d_state=16,
                        d_conv=4, chunk=16)
    key = jax.random.key(0)
    params = mamba2.init_ssd(key, cfg, jnp.float32)
    x = rand(jax.random.fold_in(key, 9), (1, 32, 32), scale=0.5)
    y_full = mamba2.ssd_block(params, x, cfg)
    state = mamba2.init_ssd_state(1, cfg, jnp.float32)
    ys = []
    for t in range(32):
        y_t, state = mamba2.ssd_decode_step(params, x[:, t], state, cfg)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_full, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# LM integration: the "--attn pallas --xent pallas" training path is
# loss- AND gradient-identical to the ref path (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_lm_pallas_training_matches_ref_path():
    import dataclasses as dc

    from repro.configs import get_config
    from repro.models import lm
    cfg = get_config("tinyllama-1.1b", smoke=True)
    cfg = dc.replace(cfg, n_layers=1, dtype="float32")
    cfg_p = dc.replace(cfg, attn_impl="pallas", xent_impl="pallas",
                       attn_bwd_remat=True)
    key = jax.random.key(0)
    tokens = jax.random.randint(jax.random.fold_in(key, 7), (2, 64), 0,
                                cfg.vocab)
    batch = {"tokens": tokens}
    m_ref, m_pal = lm.build(cfg), lm.build(cfg_p)
    params = m_ref.init(key)
    (l_ref, _), g_ref = jax.value_and_grad(m_ref.loss_fn, has_aux=True)(
        params, batch)
    (l_pal, _), g_pal = jax.value_and_grad(m_pal.loss_fn, has_aux=True)(
        params, batch)
    np.testing.assert_allclose(l_pal, l_ref, atol=1e-4, rtol=1e-4)
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(g_ref),
                            jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(
            b, a, atol=5e-4, rtol=5e-3,
            err_msg=jax.tree_util.keystr(path))


# ---------------------------------------------------------------------------
# quant (+ hypothesis property) — non-differentiable by construction:
# round() has zero gradient a.e., so only value/roundtrip properties apply
# ---------------------------------------------------------------------------

def test_quant_matches_ref():
    x = jax.random.normal(jax.random.key(0), (2048,)) * 5
    q, s = quantize(x, block=256, interpret=True)
    qr, sr = quant_ref(x, block=256)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    np.testing.assert_allclose(dequantize(q, s, block=256, interpret=True),
                               dequant_ref(qr, sr, block=256), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 256]),
       st.floats(1e-3, 1e3))
def test_quant_roundtrip_error_bound(seed, block, scale):
    """Property: |dequant(quant(x)) − x|∞ ≤ max|x|/127 per block."""
    x = (np.random.default_rng(seed).standard_normal(4 * block)
         * scale).astype(np.float32)
    qr, sr = quant_ref(jnp.asarray(x), block=block)
    xd = np.asarray(dequant_ref(qr, sr, block=block))
    bound = np.abs(x).reshape(4, block).max(1, keepdims=True) / 127.0 + 1e-6
    assert (np.abs(xd - x).reshape(4, block) <= bound + 1e-7).all()

"""Per-kernel allclose vs pure-jnp oracles, shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant.quant import dequantize, quantize
from repro.kernels.quant.ref import dequant_ref, quant_ref
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd.ssd import ssd_scan_pallas
from repro.kernels.xent.ops import xent
from repro.kernels.xent.ref import xent_ref
from repro.kernels.xent.xent import xent_fwd


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,D,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),      # MHA
    (2, 256, 4, 2, 64, 128, 64),     # GQA group 2
    (1, 256, 8, 1, 64, 64, 128),     # MQA
    (1, 128, 4, 4, 16, 128, 128),    # block == seq (single block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, S, H, K, D, bq, bk, dtype):
    key = jax.random.key(0)
    q = jax.random.normal(key, (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=tol, rtol=tol)


def test_flash_non_causal():
    key = jax.random.key(1)
    q = jax.random.normal(key, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_rejects_ragged_blocks():
    q = jnp.zeros((1, 100, 2, 32))
    k = v = jnp.zeros((1, 100, 2, 32))
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)


# ---------------------------------------------------------------------------
# fused xent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,E,V,vocab,bt,bv", [
    (128, 64, 512, 500, 64, 128),        # padded vocab
    (256, 32, 1024, 1024, 128, 512),     # exact vocab
    (128, 128, 256, 256, 128, 256),      # single vocab tile
])
def test_xent_fwd_matches_ref(T, E, V, vocab, bt, bv):
    key = jax.random.key(0)
    h = jax.random.normal(key, (T, E))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, V)) * 0.1
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, vocab)
    nll, lse = xent_fwd(h, w, lab, vocab=vocab, block_t=bt, block_v=bv,
                        interpret=True)
    nll_ref, lse_ref = xent_ref(h, w, lab, vocab=vocab)
    np.testing.assert_allclose(nll, nll_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(lse, lse_ref, atol=1e-4, rtol=1e-4)


def test_xent_custom_vjp_matches_autodiff():
    key = jax.random.key(3)
    T, E, V, vocab = 128, 32, 512, 500
    h = jax.random.normal(key, (T, E))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, V)) * 0.1
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, vocab)
    gk = jax.grad(lambda h, w: xent(h, w, lab, vocab, 64, 128, True).mean(),
                  argnums=(0, 1))(h, w)
    gr = jax.grad(lambda h, w: xent_ref(h, w, lab, vocab=vocab)[0].mean(),
                  argnums=(0, 1))(h, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,C", [
    (1, 128, 2, 32, 1, 16, 64),
    (2, 256, 4, 16, 2, 32, 128),      # grouped B/C
    (1, 64, 2, 64, 1, 64, 64),        # single chunk
])
def test_ssd_matches_sequential_oracle(B, S, H, P, G, N, C):
    key = jax.random.key(0)
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) * 0.3
    y_ref, h_ref = ssd_ref(x, dt, A, Bm, Cm)
    y, hT = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=C, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(hT, h_ref, atol=5e-4, rtol=5e-4)


def test_ssd_chunked_jnp_path_matches_oracle():
    """models.mamba2.ssd_scan (the trainable path) vs sequential truth."""
    from repro.models.mamba2 import ssd_scan
    key = jax.random.key(5)
    B, S, H, P, N = 2, 128, 4, 16, 32
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, 1, N)) * 0.3
    y_ref, _ = ssd_ref(x, dt, A, Bm, Cm)
    y, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-4)


def test_ssd_decode_matches_scan():
    """O(1)-state decode steps reproduce the chunked scan token-by-token."""
    from repro.models import mamba2
    cfg = mamba2.SSDCfg(d_model=32, n_heads=2, headdim=32, d_state=16,
                        d_conv=4, chunk=16)
    key = jax.random.key(0)
    params = mamba2.init_ssd(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 9), (1, 32, 32)) * 0.5
    y_full = mamba2.ssd_block(params, x, cfg)
    state = mamba2.init_ssd_state(1, cfg, jnp.float32)
    ys = []
    for t in range(32):
        y_t, state = mamba2.ssd_decode_step(params, x[:, t], state, cfg)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_full, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# quant (+ hypothesis property)
# ---------------------------------------------------------------------------

def test_quant_matches_ref():
    x = jax.random.normal(jax.random.key(0), (2048,)) * 5
    q, s = quantize(x, block=256, interpret=True)
    qr, sr = quant_ref(x, block=256)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    np.testing.assert_allclose(dequantize(q, s, block=256, interpret=True),
                               dequant_ref(qr, sr, block=256), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 256]),
       st.floats(1e-3, 1e3))
def test_quant_roundtrip_error_bound(seed, block, scale):
    """Property: |dequant(quant(x)) − x|∞ ≤ max|x|/127 per block."""
    x = (np.random.default_rng(seed).standard_normal(4 * block)
         * scale).astype(np.float32)
    qr, sr = quant_ref(jnp.asarray(x), block=block)
    xd = np.asarray(dequant_ref(qr, sr, block=block))
    bound = np.abs(x).reshape(4, block).max(1, keepdims=True) / 127.0 + 1e-6
    assert (np.abs(xd - x).reshape(4, block) <= bound + 1e-7).all()

"""Self-healing elastic runtime: straggler → evict → rebalance → resume.

Unit layers (monitor seeding/one-shot, aggregator eviction, fault
injector, cooperative loop stop, cluster shrinking, exactly-once data)
run in-process; the end-to-end controller scenarios run in subprocesses
with virtual CPU devices (XLA device count is fixed at first jax import).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   StrategySpec, T4_16G, TPU_V5E,
                                   V100_PAPER)
from repro.core.hetero import shrink_cluster
from repro.data.pipeline import DataCfg, TokenPipeline
from repro.runtime.elastic import HostTopology, SimHost, shrink_devices
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.faults import (CrashStep, FaultInjector, SimClock,
                                  SlowHost)
from repro.runtime.straggler import HostStragglerAggregator, StragglerMonitor

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 540):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# StragglerMonitor: warmup variance seeding + one-shot flag
# ---------------------------------------------------------------------------

def test_monitor_seeds_variance_from_warmup():
    """A post-warmup sample inside the warmup spread must NOT be an
    outlier.  The pre-fix monitor left var=0 after warmup, so the first
    comparison ran against the 5%-of-mean floor and flagged normal
    jitter."""
    m = StragglerMonitor(threshold=2.0, patience=1, warmup=5)
    for dt in (1.0, 1.2, 0.9, 1.1, 1.0):
        assert not m.observe(dt)
    assert m.var > 0.0, "warmup must seed the variance"
    # mean≈1.04, std≈0.114 → threshold ≈ 1.27; 1.25 is within spread
    # (under var=0 the floor gives threshold ≈ 1.14 → spurious flag)
    assert not m.observe(1.25)
    assert m.consecutive == 0 and not m.flagged


def test_monitor_one_shot_flag_and_reset():
    m = StragglerMonitor(threshold=2.0, patience=2, warmup=3)
    for _ in range(3):
        m.observe(1.0)
    assert not m.observe(5.0)          # first outlier: patience not met
    assert m.observe(5.0)              # second: flag trips → True ONCE
    assert m.flagged
    for _ in range(5):
        assert not m.observe(5.0)      # latched, never re-reported
    assert m.flagged
    m.reset()                          # re-arm, stats kept
    assert not m.flagged and m.n > 0
    assert not m.observe(5.0)
    assert m.observe(5.0)              # flags again after re-arm
    m.reset(clear_stats=True)
    assert m.n == 0 and m.var == 0.0


def test_monitor_constant_warmup_still_detects():
    """Zero-variance warmup (identical times) falls back to the
    5%-of-mean floor and still detects a genuine 2× straggler."""
    m = StragglerMonitor(threshold=2.0, patience=2, warmup=3)
    for _ in range(3):
        m.observe(0.1)
    assert not m.observe(0.2)
    assert m.observe(0.2)


# ---------------------------------------------------------------------------
# HostStragglerAggregator: no re-reporting, eviction, reset
# ---------------------------------------------------------------------------

def test_aggregator_reports_once_and_respects_eviction():
    agg = HostStragglerAggregator(n_hosts=4, patience=2, warmup=3)
    reported = []
    for step in range(20):
        times = {h: 0.1 for h in range(4)}
        if step >= 6:
            times[2] = 0.4
        reported.extend(agg.observe(times))
    # the pre-fix aggregator re-reported host 2 on every call after the
    # flag; one-shot semantics report it exactly once
    assert reported == [2]
    agg.evict(2)
    assert 2 not in agg.monitors and 2 in agg.evicted
    # the dying host may keep emitting heartbeats — ignored
    assert agg.observe({h: (0.4 if h == 2 else 0.1) for h in range(4)}) == []


def test_aggregator_reset_renumbers_survivors():
    agg = HostStragglerAggregator(n_hosts=3, patience=2, warmup=2)
    agg.evict(1)
    agg.reset([0, 2])
    assert sorted(agg.monitors) == [0, 2]
    agg.reset([0, 1, 2])               # evicted host stays excluded
    assert sorted(agg.monitors) == [0, 2]


# ---------------------------------------------------------------------------
# fault injector: deterministic clock, crash budget, sim clock
# ---------------------------------------------------------------------------

def test_injector_deterministic_and_slow_factor():
    inj = FaultInjector(scenarios=(SlowHost(host=1, start_step=5,
                                            factor=3.0),),
                        n_hosts=2, seed=42)
    inj2 = FaultInjector(scenarios=(SlowHost(host=1, start_step=5,
                                             factor=3.0),),
                         n_hosts=2, seed=42)
    for step in (0, 4, 5, 9):
        assert inj.host_times(step, base=0.1) == inj2.host_times(step,
                                                                 base=0.1)
    before = inj.host_times(4, base=0.1)
    after = inj.host_times(5, base=0.1)
    assert abs(before[1] / before[0] - 1.0) < 0.2       # jitter only
    assert after[1] / after[0] > 2.0                    # 3× straggler


def test_injector_nominal_clock_ignores_measured_base():
    """With a nominal step time the timeline is a pure function of
    (seed, step, host) — load spikes in the measured base can't leak in."""
    inj = FaultInjector(n_hosts=2, nominal=0.05)
    assert inj.host_times(3, base=99.0) == inj.host_times(3, base=0.001)
    assert 0.04 < inj.host_times(3, base=99.0)[0] < 0.06


def test_injector_crash_budget_and_clock():
    inj = FaultInjector(scenarios=(CrashStep(step=3, times=2),), n_hosts=1)
    inj.maybe_fail(2)                                   # no-op
    for _ in range(2):
        with pytest.raises(RuntimeError, match="injected"):
            inj.maybe_fail(3)
    inj.maybe_fail(3)                                   # budget exhausted
    clock = SimClock()
    clock.advance({0: 0.1, 1: 0.4})
    clock.charge(1.0)
    assert clock.t == pytest.approx(1.4) and clock.steps == 1


# ---------------------------------------------------------------------------
# FaultTolerantLoop: cooperative stop + step-aware extra_fn + retry save
# ---------------------------------------------------------------------------

def test_loop_request_stop_commits_final_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    loop = FaultTolerantLoop(mgr, save_every=100, async_save=False)
    calls = []

    def step_fn(i, st):
        calls.append(i)
        return {"x": st["x"] + 1}

    def on_step(i, st, dt):
        if i == 3:
            loop.request_stop()

    step, state = loop.run(state={"x": np.zeros(())}, step_fn=step_fn,
                           n_steps=100, on_step=on_step,
                           extra_fn=lambda st, s: {"pos": s})
    assert step == 4 and calls == [0, 1, 2, 3]
    assert float(state["x"]) == 4.0
    got = mgr.restore_latest({"x": np.zeros(())})
    assert got is not None
    ck_step, _, extra = got
    assert ck_step == 4 and extra["pos"] == 4   # two-arg extra_fn got step


def test_loop_extra_fn_defaulted_second_param_stays_one_arg(tmp_path):
    """A defaulted second parameter keeps the one-arg contract — the step
    must not be misbound into it."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    loop = FaultTolerantLoop(mgr, save_every=100, async_save=False)
    step, _ = loop.run(state={"x": np.zeros(())},
                       step_fn=lambda i, st: st, n_steps=2,
                       extra_fn=lambda st, verbose=False: {"v": verbose})
    assert step == 2
    _, _, extra = mgr.restore_latest({"x": np.zeros(())})
    assert extra["v"] is False                  # not the step number


def test_loop_retry_exhausted_saves_at_failed_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    loop = FaultTolerantLoop(mgr, save_every=100, max_retries=2,
                             async_save=False)

    def step_fn(i, st):
        if i == 2:
            raise RuntimeError("persistent")
        return st

    saved = []
    with pytest.raises(RuntimeError, match="persistent"):
        loop.run(state={"x": np.zeros(())}, step_fn=step_fn, n_steps=10,
                 extra_fn=lambda st, s: saved.append(s) or {"pos": s})
    # the final save commits at the FAILED step (2), not past it
    assert saved[-1] == 2 and mgr.latest_step() == 2


# ---------------------------------------------------------------------------
# cluster shrinking: ClusterSpec, HostTopology, shrink_devices
# ---------------------------------------------------------------------------

def test_shrink_cluster_removes_and_drops_empty():
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                               DeviceGroup("t4", T4_16G, 4)))
    out = shrink_cluster(spec, {"v100": 4})
    assert [(g.name, g.n_devices) for g in out.groups] == [("v100", 4),
                                                           ("t4", 4)]
    out = shrink_cluster(spec, {"t4": 4})
    assert [(g.name, g.n_devices) for g in out.groups] == [("v100", 8)]
    with pytest.raises(ValueError, match="unknown device group"):
        shrink_cluster(spec, {"p100": 1})
    with pytest.raises(ValueError, match="cannot remove"):
        shrink_cluster(spec, {"t4": 5})
    with pytest.raises(ValueError, match="whole cluster"):
        shrink_cluster(spec, {"v100": 8, "t4": 4})


class _FakeDev:
    def __init__(self, i, proc=0):
        self.id = i
        self.process_index = proc


def test_shrink_devices_default_and_topology():
    devs = [_FakeDev(i, proc=i // 2) for i in range(6)]
    assert [d.id for d in shrink_devices(devs, {1})] == [0, 1, 4, 5]
    topo = HostTopology.uniform(3, 2, TPU_V5E)
    out = shrink_devices(devs, {0, 2}, topology=topo)
    assert [d.id for d in out] == [2, 3]
    # the deprecated callable form warns but still filters identically
    # (the mixed-fleet agreement regression lives in test_controller.py)
    with pytest.warns(DeprecationWarning, match="host_of"):
        legacy = shrink_devices(devs, {0, 2}, host_of=topo.host_of)
    assert [d.id for d in legacy] == [2, 3]


def test_host_topology_mapping_and_spec_merging():
    topo = HostTopology(hosts=(SimHost(0, V100_PAPER, 4),
                               SimHost(1, V100_PAPER, 4),
                               SimHost(2, T4_16G, 8)))
    assert topo.n_devices == 16
    assert topo.host_of(_FakeDev(0)) == 0
    assert topo.host_of(_FakeDev(7)) == 1
    assert topo.host_of(_FakeDev(8)) == 2
    with pytest.raises(ValueError):
        topo.host_of(_FakeDev(16))
    spec = topo.cluster_spec()
    # consecutive same-hardware hosts merge into one group
    assert [(g.hw.name, g.n_devices) for g in spec.groups] == [
        ("v100_eth35", 8), ("t4_16g", 8)]
    surv = topo.without({1})
    assert surv.host_ids == (0, 2)
    spec2 = surv.cluster_spec()
    assert [(g.hw.name, g.n_devices) for g in spec2.groups] == [
        ("v100_eth35", 4), ("t4_16g", 8)]
    assert not spec2.is_homogeneous
    devs = [_FakeDev(i) for i in range(16)]
    assert [d.id for d in topo.devices(devs, exclude={1})] == \
        list(range(4)) + list(range(8, 16))
    with pytest.raises(ValueError, match="every host"):
        topo.without({0, 1, 2})


def test_host_topology_eviction_keeps_survivor_devices():
    """Evicting a NON-last host must not slide survivors onto the evicted
    host's physical devices — offsets are preserved across without()."""
    topo = HostTopology.uniform(2, 2, TPU_V5E)
    surv = topo.without({0})
    devs = [_FakeDev(i) for i in range(4)]
    assert [d.id for d in surv.devices(devs)] == [2, 3]
    assert surv.host_of(_FakeDev(2)) == 1
    with pytest.raises(ValueError):
        surv.host_of(_FakeDev(0))          # evicted range is gone
    mid = HostTopology.uniform(3, 2, TPU_V5E).without({1})
    assert [d.id for d in mid.devices([_FakeDev(i) for i in range(6)])] \
        == [0, 1, 4, 5]


def test_host_topology_non_contiguous_hw_does_not_merge():
    topo = HostTopology(hosts=(SimHost(0, V100_PAPER, 2),
                               SimHost(1, P100_16G, 2),
                               SimHost(2, V100_PAPER, 2)))
    spec = topo.cluster_spec()
    assert [g.hw.name for g in spec.groups] == ["v100_eth35", "p100_16g",
                                                "v100_eth35"]
    assert {g.name for g in spec.groups} == {"v100_eth35#0", "p100_16g#1",
                                             "v100_eth35#2"}


# ---------------------------------------------------------------------------
# exactly-once data pipeline: mid-epoch restore + host-count invariance
# ---------------------------------------------------------------------------

def _hashes(pipe, n):
    return [pipe.next_batch()["tokens"].tobytes() for _ in range(n)]


def test_pipeline_exactly_once_mid_epoch_restore():
    """No repeated or skipped samples across a mid-epoch restore — the
    guarantee fault_tolerance.py's docstring claims."""
    cfg = DataCfg(global_batch=4, seq_len=8, vocab=101, seed=9,
                  steps_per_epoch=4)              # restore crosses an epoch
    reference = _hashes(TokenPipeline(cfg), 12)

    live = TokenPipeline(cfg)
    consumed = _hashes(live, 5)                   # 5 committed steps
    snapshot = live.state_dict()
    _hashes(live, 3)                              # lost post-ckpt work
    restored = TokenPipeline(cfg)
    restored.load_state_dict(snapshot)
    resumed = _hashes(restored, 7)
    assert consumed + resumed == reference        # exactly-once


def test_pipeline_content_invariant_to_host_count():
    """The global sample stream must not re-deal when the host count
    changes (straggler eviction re-shards the same global batch)."""
    cfg = DataCfg(global_batch=8, seq_len=16, vocab=997, seed=5)
    for step in range(3):
        full = TokenPipeline(cfg, host_id=0, n_hosts=1)
        for _ in range(step):
            full.next_batch()
        want = full.next_batch()["tokens"]
        shards = []
        for h in range(2):
            p = TokenPipeline(cfg, host_id=h, n_hosts=2)
            for _ in range(step):
                p.next_batch()
            shards.append(p.next_batch()["tokens"])
        np.testing.assert_array_equal(np.concatenate(shards), want)


def test_pipeline_reshard_continues_stream():
    cfg = DataCfg(global_batch=8, seq_len=16, vocab=997, seed=5)
    ref = _hashes(TokenPipeline(cfg, host_id=0, n_hosts=1), 6)
    p = TokenPipeline(cfg, host_id=0, n_hosts=2)
    for _ in range(3):
        p.next_batch()
    p1 = p.reshard(host_id=0, n_hosts=1)          # survivors re-divide
    assert _hashes(p1, 3) == ref[3:]              # position preserved


# ---------------------------------------------------------------------------
# eviction path: shrink_devices + remesh/rebalance onto survivors
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_evict_remesh_onto_surviving_devices(tmp_path):
    """Checkpoint on the full 2-host mesh, evict host 0 (the harder,
    non-last case), restore onto the survivors' devices — values
    identical, arrays actually live on the surviving half."""
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.core.planner import compile_plan
        from repro.models.lm import build, model_graph
        from repro.optim import adamw
        from repro.runtime.elastic import ElasticContext, HostTopology
        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build(cfg)
        opt = adamw(lr=1e-3)
        topo = HostTopology.uniform(2, 2, TPU_V5E)
        mesh1 = jax.make_mesh((4,), ("data",))
        plan1 = compile_plan(model, mesh1)
        with mesh1:
            params = plan1.init_params(jax.random.key(1))
            ost = opt.init(params)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
        mgr.save(5, {{"params": params, "opt": ost}},
                 extra={{"data": {{"epoch": 0, "step": 5, "seed": 0}}}})
        # --- evict host 0: survivors keep THEIR devices (2..3) ---
        surv = topo.without({{0}})
        devices = surv.devices(jax.devices())
        assert [d.id for d in devices] == [2, 3]
        ctx = ElasticContext(model=model, optimizer=opt)
        meta = model_graph(cfg, 8, 32).workload_meta()
        step, plan2, p2, o2, extra = ctx.rebalance(
            mgr, surv.cluster_spec(), meta, devices=devices,
            search_kw={{"max_pp": 1}})
        assert step == 5 and extra["data"]["step"] == 5
        assert set(d.id for d in plan2.mesh.devices.flat) == {{2, 3}}
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        # restored leaves live only on the surviving devices
        for leaf in jax.tree.leaves(p2):
            assert set(d.id for d in leaf.sharding.device_set) <= {{2, 3}}
        batch = {{"tokens": jnp.zeros((4, 32), jnp.int32)}}
        with plan2.mesh:
            loss, _ = plan2.jit_loss(batch)(p2, batch)
        assert np.isfinite(float(loss))
        print("OK evict+rebalance restores onto survivors")
    """)


# ---------------------------------------------------------------------------
# end-to-end: the full self-healing loop under fault injection
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_self_healing_controller_end_to_end(tmp_path):
    """Acceptance scenario: a slow host is flagged and evicted, the job
    rebalances onto the survivors, resumes from the committed checkpoint
    with exactly-once data (including a transient crash retry), and the
    final loss matches an uninterrupted reference run on the same
    surviving cluster."""
    run_py(f"""
        import numpy as np
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.data.pipeline import DataCfg, TokenPipeline
        from repro.launch.train import TrainController, ElasticConfig
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.runtime.elastic import HostTopology
        from repro.runtime.faults import CrashStep, FaultInjector, SlowHost

        N = 12
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)

        class Recording(TokenPipeline):
            def __init__(self, *a, **k):
                super().__init__(*a, **k)
                self.seen = []
            def next_batch(self):
                b = super().next_batch()
                self.seen.append(b["tokens"].tobytes())
                return b

        dcfg = DataCfg(global_batch=8, seq_len=64, vocab=cfg.vocab, seed=0)

        # --- self-healing run: host 1 goes 5x slower at step 4, plus a
        #     transient crash at step 9 (retried on the SAME batch) ---
        data = Recording(dcfg)
        inj = FaultInjector(scenarios=(
            SlowHost(host=1, start_step=4, factor=5.0),
            CrashStep(step=9, times=1)), n_hosts=2, seed=0,
            nominal=0.05)    # simulated clock: immune to CI load spikes
        ctl = TrainController(
            model, cfg, adamw(lr=1e-3), data,
            CheckpointManager({str(tmp_path)!r} + "/heal", keep=3),
            elastic=ElasticConfig(
                topology=HostTopology.uniform(2, 2, TPU_V5E),
                patience=2, warmup=2),
            batch=8, seq=64, save_every=4, injector=inj, log_every=100)
        out = ctl.run(N, seed=0)
        assert out["phase"] == "DONE" and out["final_step"] == N, out["phase"]
        evicts = [e for e in out["events"] if e["kind"] == "evict"]
        rebs = [e for e in out["events"] if e["kind"] == "rebalance"]
        assert evicts and evicts[0]["hosts"] == [1], out["events"]
        assert rebs and rebs[0]["step"] == evicts[0]["step"], out["events"]
        assert out["topology"].host_ids == (0,)

        # --- exactly-once: the consumed global stream equals the
        #     reference stream, no repeats, no skips, crash included ---
        ref = TokenPipeline(dcfg)
        want = [ref.next_batch()["tokens"].tobytes() for _ in range(N)]
        assert data.seen == want, (len(data.seen), len(want))

        # --- uninterrupted reference on the surviving cluster ---
        data2 = Recording(dcfg)
        ctl2 = TrainController(
            model, cfg, adamw(lr=1e-3), data2,
            CheckpointManager({str(tmp_path)!r} + "/ref", keep=3),
            elastic=ElasticConfig(
                topology=HostTopology.uniform(1, 2, TPU_V5E)),
            batch=8, seq=64, save_every=100, log_every=100)
        out2 = ctl2.run(N, seed=0)
        assert out2["phase"] == "DONE"
        np.testing.assert_allclose(out["losses"][-1], out2["losses"][-1],
                                   rtol=2e-3)
        print("OK self-healing == uninterrupted reference:",
              out["losses"][-1], out2["losses"][-1])
    """)


@pytest.mark.slow
def test_preemption_checkpoint_and_resume(tmp_path):
    """SIGTERM mid-run commits a final checkpoint; a relaunched controller
    auto-resumes and the combined run consumes the stream exactly-once."""
    run_py(f"""
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.cost_model import TPU_V5E
        from repro.data.pipeline import DataCfg, TokenPipeline
        from repro.launch.train import TrainController, ElasticConfig
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.runtime.elastic import HostTopology
        from repro.runtime.faults import FaultInjector, Preemption

        N = 10
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)

        class Recording(TokenPipeline):
            seen = []
            def next_batch(self):
                b = super().next_batch()
                Recording.seen.append(b["tokens"].tobytes())
                return b

        dcfg = DataCfg(global_batch=4, seq_len=32, vocab=cfg.vocab, seed=1)

        def controller(injector=None):
            return TrainController(
                model, cfg, adamw(lr=1e-3), Recording(dcfg),
                CheckpointManager({str(tmp_path)!r}, keep=3),
                elastic=ElasticConfig(
                    topology=HostTopology.uniform(2, 1, TPU_V5E)),
                batch=4, seq=32, save_every=100, injector=injector,
                log_every=100)

        inj = FaultInjector(scenarios=(Preemption(step=5),), n_hosts=2,
                            nominal=0.05)
        out = controller(inj).run(N, seed=0)
        pre = [e for e in out["events"] if e["kind"] == "preempted"]
        assert pre and out["final_step"] == 6, out["events"]
        assert out["phase"] == "PREEMPTED", out["phase"]

        out2 = controller().run(N, seed=0)      # relaunch: auto-resume
        assert out2["final_step"] == N and out2["phase"] == "DONE"
        # steps 0..5 from run 1, 6..9 from run 2 — exactly once overall
        ref = TokenPipeline(dcfg)
        want = [ref.next_batch()["tokens"].tobytes() for _ in range(N)]
        assert Recording.seen == want, (len(Recording.seen), len(want))
        print("OK preempt at 6, resumed to", out2["final_step"])
    """)


# ---------------------------------------------------------------------------
# aggregator reset: the evicted set stays authoritative
# ---------------------------------------------------------------------------

def test_aggregator_reset_never_resurrects_evicted():
    """``reset(hosts)`` with a stale host list that still names an evicted
    host (e.g. a caller passing the pre-eviction ids) must not rebuild a
    monitor for it — an evicted host's heartbeats can keep arriving for a
    few steps and must never re-flag it."""
    agg = HostStragglerAggregator(n_hosts=3, threshold=2.0, patience=1,
                                  warmup=2)
    agg.evict(1)
    agg.reset([0, 1, 2])                    # 1 is evicted: must stay out
    assert set(agg.monitors) == {0, 2}
    for t in ({0: 1.0, 1: 1.0, 2: 1.0},) * 2:
        assert agg.observe(t) == []
    # a blatant outlier from the evicted host is silently ignored forever
    assert agg.observe({0: 1.0, 1: 50.0, 2: 1.0}) == []
    assert 1 not in agg.monitors and agg.evicted == {1}
    # default reset() (no host list) keeps the exclusion too
    agg.reset()
    assert set(agg.monitors) == {0, 2}


def test_aggregator_reset_after_rebalance_rearms_survivors():
    """Post-rebalance reset gives survivors *fresh* monitors (step times
    change shape under the new plan) while keeping eviction permanent."""
    agg = HostStragglerAggregator(n_hosts=2, threshold=2.0, patience=1,
                                  warmup=2)
    for t in ({0: 1.0, 1: 1.0},) * 2:
        agg.observe(t)
    assert agg.observe({0: 1.0, 1: 9.0}) == [1]
    agg.evict(1)
    agg.reset([0])
    assert agg.monitors[0].n == 0           # fresh stats, not carried over
    for t in ({0: 3.0},) * 2:               # new plan: slower baseline is OK
        assert agg.observe(t) == []
    assert agg.observe({0: 3.1}) == []


# ---------------------------------------------------------------------------
# kernel tiles across a hardware-mix-changing rebalance (stale-tiles fix)
# ---------------------------------------------------------------------------

def _tile_cfg():
    import dataclasses as dc

    from repro.configs import get_config
    return dc.replace(get_config("tinyllama-1.1b", smoke=True), n_layers=2,
                      attn_impl="pallas")


def test_plan_tiles_change_across_mix_changing_rebalance():
    """Re-planning after evicting the quarter-VMEM P100 group must re-run
    the autotuner: the conservative cross-group tiling gives way to the
    V100's larger blocks.  (A plan carrying the old tiles would run the
    survivors at the evicted part's geometry forever.)"""
    from repro.core.planner import compile_plan, mesh_for_strategy
    from repro.models.lm import build
    cfg = _tile_cfg()
    model = build(cfg)
    mixed = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 4),
                                DeviceGroup("p100", P100_16G, 4)))
    survivors = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 4),))
    mesh = mesh_for_strategy(StrategySpec(dp=1))
    before = compile_plan(model, mesh, cluster_spec=mixed)
    after = compile_plan(model, mesh, cluster_spec=survivors)
    assert before.tiles_for(None) != after.tiles_for(None)
    assert after.tiles_for(None).block_q > before.tiles_for(None).block_q
    assert set(after.kernel_tiles) == {"v100"}


def test_controller_retunes_baked_tiles_on_mix_change(tmp_path):
    """The regression the drift loop exposed: plans re-autotune, but the
    *executing model* bakes tile block sizes into its config at startup.
    ``_retune_model`` must re-size them when the hardware mix changes and
    emit a ``retune`` event."""
    from repro.launch.train import ElasticConfig, TrainController
    from repro.models.lm import build
    from repro.optim import adamw
    cfg = _tile_cfg()
    topo = HostTopology(hosts=(SimHost(0, V100_PAPER, 2),
                               SimHost(1, P100_16G, 2)))
    ctl = TrainController(
        build(cfg), cfg, adamw(lr=1e-3),
        TokenPipeline(DataCfg(global_batch=8, seq_len=64, vocab=cfg.vocab,
                              seed=0)),
        CheckpointManager(str(tmp_path / "tiles"), keep=1),
        elastic=ElasticConfig(topology=topo), batch=8, seq=64,
        verbose=False)
    ctl._retune_model(topo.cluster_spec())
    q_mixed = ctl.cfg.attn_block_q          # capped by the P100's 4 MiB VMEM
    ctl._retune_model(ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER,
                                                      4),)))
    q_survivor = ctl.cfg.attn_block_q
    assert q_survivor > q_mixed, (q_mixed, q_survivor)
    assert any(e["kind"] == "retune" for e in ctl.events), ctl.events
    # the rebuilt model carries the new tiles (same parameter shapes)
    assert ctl.model.cfg.attn_block_q == q_survivor

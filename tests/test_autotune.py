"""Properties of the per-Hardware kernel tile autotuner (PR 6).

The autotuner is pure arithmetic over the Hardware tables, so everything
here is exact: tiles divide the lengths they're snapped to, fit the VMEM
working-set models, and degrade monotonically as the part shrinks.
"""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import (P100_16G, T4_16G, TPU_V5E, V100_PAPER,
                                   ClusterSpec, DeviceGroup)
from repro.kernels.autotune import (DEFAULT_TILES, KernelTiles, autotune,
                                    autotune_cluster, fit_block)

ALL_HW = [TPU_V5E, V100_PAPER, P100_16G, T4_16G]


# ---------------------------------------------------------------------------
# fit_block
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,target,want", [
    (2048, 512, 512),     # target divides
    (100, 64, 50),        # largest divisor ≤ 64
    (97, 64, 1),          # prime → 1
    (64, 512, 64),        # target > n → n itself
    (96, 128, 96),
])
def test_fit_block_examples(n, target, want):
    assert fit_block(n, target) == want


def test_fit_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        fit_block(0, 64)
    with pytest.raises(ValueError):
        fit_block(-8, 64)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 1 << 16), st.integers(1, 1024))
def test_fit_block_properties(n, target):
    b = fit_block(n, target)
    assert 1 <= b <= min(n, target)
    assert n % b == 0
    # maximality: no larger divisor ≤ target
    assert all(n % d for d in range(b + 1, min(n, target) + 1))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 512), st.integers(1, 512))
def test_fit_block_monotone_in_target(n, t1, t2):
    lo, hi = sorted((t1, t2))
    assert fit_block(n, lo) <= fit_block(n, hi)


# ---------------------------------------------------------------------------
# tiles divide the (padded) lengths they are snapped to
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw", ALL_HW, ids=lambda h: h.name)
@pytest.mark.parametrize("seq,vocab", [(2048, 32768), (96, 50304),
                                       (640, 32000), (1, 7)])
def test_snapped_tiles_divide_lengths(hw, seq, vocab):
    t = autotune(hw, head_dim=128, group=4, d_model=2048, vocab=vocab,
                 seq=seq)
    assert seq % t.block_q == 0 and seq % t.block_k == 0
    assert seq % t.ssd_chunk == 0
    assert vocab % t.xent_block_v == 0


def test_shrink_to_divides():
    t = DEFAULT_TILES.shrink_to(seq=96, vocab=1000)
    assert 96 % t.block_q == 0 and 96 % t.block_k == 0
    assert 96 % t.ssd_chunk == 0 and 1000 % t.xent_block_v == 0


# ---------------------------------------------------------------------------
# the chosen tiles fit the per-family VMEM working-set models
# ---------------------------------------------------------------------------

def _flash_bytes(t, G, D):
    return 4 * (3 * t * G * D + 2 * t * D + t * G * t)


def _xent_bytes(bt, bv, E):
    return 4 * (bt * E + E * bv + bt * bv)


def _ssd_bytes(c, D):
    return 4 * (4 * c * D + c * c)


@pytest.mark.parametrize("hw", ALL_HW, ids=lambda h: h.name)
def test_tiles_fit_vmem_budget(hw):
    G, D, E = 4, 128, 2048
    t = autotune(hw, head_dim=D, group=G, d_model=E)
    budget = hw.vmem_bytes / 2          # other half: double buffering
    assert _flash_bytes(t.block_q, G, D) <= budget
    assert _xent_bytes(t.xent_block_t, t.xent_block_v, E) <= budget
    assert _ssd_bytes(t.ssd_chunk, D) <= budget


def test_distinct_parts_tile_distinctly():
    """The headline hetero property: V100 and P100 groups in one job get
    different static block sizes (P100: quarter the VMEM, ~10:1 roofline)."""
    v100 = autotune(V100_PAPER, head_dim=128, group=4, d_model=2048)
    p100 = autotune(P100_16G, head_dim=128, group=4, d_model=2048)
    tpu = autotune(TPU_V5E, head_dim=128, group=4, d_model=2048)
    assert p100.block_q < v100.block_q <= tpu.block_q
    # the xent VOCAB tile trades off against the token tile inside one
    # budget (a small bt frees room for a wide bv), so compare the whole
    # working set, not the single knob
    assert (_xent_bytes(p100.xent_block_t, p100.xent_block_v, 2048)
            < _xent_bytes(v100.xent_block_t, v100.xent_block_v, 2048))


# ---------------------------------------------------------------------------
# monotone degradation: a strictly smaller part never gets a larger tile
# ---------------------------------------------------------------------------

def _leq(a: KernelTiles, b: KernelTiles) -> bool:
    return all(getattr(a, f.name) <= getattr(b, f.name)
               for f in dataclasses.fields(KernelTiles))


@pytest.mark.parametrize("hw", ALL_HW, ids=lambda h: h.name)
def test_monotone_in_vmem(hw):
    prev = autotune(hw, head_dim=128, group=4, d_model=2048)
    for shrink in (2, 4, 8, 16):
        cur = autotune(dataclasses.replace(hw, vmem_bytes=hw.vmem_bytes
                                           / shrink),
                       head_dim=128, group=4, d_model=2048)
        assert _leq(cur, prev), (shrink, cur, prev)
        prev = cur


@pytest.mark.parametrize("hw", ALL_HW, ids=lambda h: h.name)
def test_monotone_in_compute_ratio(hw):
    """Lower arithmetic intensity → smaller cap-driven tiles.  The xent
    vocab tile is exempt: it fills whatever budget the (shrinking) token
    tile frees, so only the joint working set is bounded (checked in
    test_tiles_fit_vmem_budget), not the single knob."""
    cap_fields = ("block_q", "block_k", "xent_block_t", "ssd_chunk")
    prev = autotune(hw, head_dim=128, group=1)
    for shrink in (2, 4, 8, 16):
        cur = autotune(dataclasses.replace(hw, peak_flops=hw.peak_flops
                                           / shrink),
                       head_dim=128, group=1)
        for f in cap_fields:
            assert getattr(cur, f) <= getattr(prev, f), (shrink, f, cur,
                                                         prev)
        prev = cur


# ---------------------------------------------------------------------------
# unknown hardware → the pre-autotune defaults
# ---------------------------------------------------------------------------

def test_unknown_hardware_falls_back_to_defaults():
    assert autotune(None) == DEFAULT_TILES
    snapped = autotune(None, seq=96, vocab=1000)
    assert snapped == DEFAULT_TILES.shrink_to(seq=96, vocab=1000)


# ---------------------------------------------------------------------------
# plan integration: compile_plan carries per-group tiles
# ---------------------------------------------------------------------------

def _mixed_plan():
    from repro.configs import get_config
    from repro.core.planner import (StrategySpec, compile_plan,
                                    mesh_for_strategy)
    from repro.models.lm import build
    cfg = dataclasses.replace(get_config("tinyllama-1.1b", smoke=True),
                              n_layers=2)
    model = build(cfg)
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 4),
                               DeviceGroup("p100", P100_16G, 4)))
    mesh = mesh_for_strategy(StrategySpec(dp=1))
    return compile_plan(model, mesh, cluster_spec=spec), cfg


def test_compile_plan_autotunes_per_group():
    plan, _ = _mixed_plan()
    assert set(plan.kernel_tiles) == {"v100", "p100"}
    v, p = plan.kernel_tiles["v100"], plan.kernel_tiles["p100"]
    assert p.block_q < v.block_q          # quarter-VMEM part tiles smaller
    assert plan.tiles_for("v100") == v
    assert plan.tiles_for("p100") == p
    assert plan.tiles_for("no-such-group") == DEFAULT_TILES


def test_tiles_for_none_is_elementwise_min():
    plan, _ = _mixed_plan()
    lo = plan.tiles_for(None)
    for f in dataclasses.fields(KernelTiles):
        assert getattr(lo, f.name) == min(
            getattr(t, f.name) for t in plan.kernel_tiles.values())


def test_plan_without_cluster_uses_defaults():
    from repro.configs import get_config
    from repro.core.planner import (StrategySpec, compile_plan,
                                    mesh_for_strategy)
    from repro.models.lm import build
    cfg = dataclasses.replace(get_config("tinyllama-1.1b", smoke=True),
                              n_layers=2)
    plan = compile_plan(build(cfg), mesh_for_strategy(StrategySpec(dp=1)))
    assert plan.kernel_tiles is None
    assert plan.tiles_for() == DEFAULT_TILES


def test_autotune_cluster_names_every_group():
    spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                               DeviceGroup("t4", T4_16G, 4),
                               DeviceGroup("p100", P100_16G, 4)))
    tiles = autotune_cluster(spec, head_dim=128, group=4, d_model=2048,
                             vocab=32768, seq=2048)
    assert set(tiles) == {"v100", "t4", "p100"}
    for t in tiles.values():
        assert 2048 % t.block_q == 0 and 32768 % t.xent_block_v == 0

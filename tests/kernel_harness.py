"""Shared fwd+bwd gradcheck harness for the Pallas kernel families.

The kernel analogue of PR 3's schedule-equivalence harness: every kernel
family is checked against its ``ref.py`` oracle *both ways* —

- **values**: kernel outputs (run in ``interpret=True`` on CPU) allclose
  to the full-materialisation reference, and
- **gradients**: for a random cotangent ``ct``, the VJP of
  ``vdot(kernel(·), ct)`` allclose to autodiff through the reference —
  this exercises the hand-written ``jax.custom_vjp`` backward kernels
  (flash dq/dkv, xent recompute-over-vocab) against ground truth.

Tolerance policy (per compute dtype of the *inputs*; kernels accumulate
in f32 regardless):

- f32 inputs: 2e-5 on values.  Gradients get 10× headroom (2e-4):
  the backward recomputes ``p = exp(s − lse)`` rather than reusing the
  forward's online-softmax factors, so fwd and bwd see differently-rounded
  probabilities.
- bf16 inputs: 2e-2 / 5e-2 — one bf16 ulp at the magnitudes the sweeps
  produce, again with bwd headroom.

Two families are exempt from kernel-side gradcheck by design, and their
tests say so: the SSD pallas scan uses scratch accumulators (``pallas_call``
is not differentiable; training runs the chunked jnp twin in
``models.mamba2.ssd_scan``, whose autodiff IS checked against the
sequential oracle here), and quant is inherently non-differentiable
(value/roundtrip properties only).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Tol:
    fwd: float
    grad: float


TOLS = {
    jnp.dtype(jnp.float32): Tol(fwd=2e-5, grad=2e-4),
    jnp.dtype(jnp.bfloat16): Tol(fwd=2e-2, grad=5e-2),
}


def tol_for(dtype) -> Tol:
    return TOLS[jnp.dtype(dtype)]


def _tree_vdot(a, b):
    return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def assert_tree_close(got, want, tol: float, msg: str = ""):
    for i, (g, w) in enumerate(zip(jax.tree.leaves(got),
                                   jax.tree.leaves(want))):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            atol=tol, rtol=tol, err_msg=f"{msg} [leaf {i}]")


def check_fwd_bwd(kernel_fn, ref_fn, args: tuple, *, diff_argnums: tuple,
                  tol: Tol, seed: int = 0, msg: str = ""):
    """Assert kernel_fn ≡ ref_fn on ``args``, values AND gradients.

    ``kernel_fn``/``ref_fn``: called as ``fn(*args)``; outputs may be any
    pytree (compared leaf-wise).  ``diff_argnums``: positions of the args
    to differentiate (the rest are closed over).  Gradients are compared
    through a random-cotangent scalarisation, which checks the full VJP
    rather than one directional derivative.
    """
    out_k = kernel_fn(*args)
    out_r = ref_fn(*args)
    assert_tree_close(out_k, out_r, tol.fwd, msg=f"{msg} fwd")

    key = jax.random.key(seed)
    leaves = jax.tree.leaves(out_r)
    cts = [jax.random.normal(jax.random.fold_in(key, i), leaf.shape,
                             jnp.float32)
           for i, leaf in enumerate(leaves)]

    def scalar(fn):
        def s(*diff):
            full = list(args)
            for pos, val in zip(diff_argnums, diff):
                full[pos] = val
            return _tree_vdot(fn(*full), cts)
        return s

    diff = tuple(args[i] for i in diff_argnums)
    g_k = jax.grad(scalar(kernel_fn), argnums=tuple(range(len(diff))))(*diff)
    g_r = jax.grad(scalar(ref_fn), argnums=tuple(range(len(diff))))(*diff)
    for pos, gk, gr in zip(diff_argnums, g_k, g_r):
        assert_tree_close(gk, gr, tol.grad, msg=f"{msg} grad(arg{pos})")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)

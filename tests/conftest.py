"""Pytest config — NOTE: no XLA_FLAGS here; smoke tests run single-device.
Multi-device coverage lives in test_distributed.py via subprocesses."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests (dry-run cells)")

"""Pytest config — NOTE: no XLA_FLAGS here; smoke tests run single-device.
Multi-device coverage lives in test_distributed.py via subprocesses.

Also installs a skip-if-missing shim for ``hypothesis``: property tests are
written against the real library (see requirements-dev.txt), but the bare
container may not ship it.  Rather than failing the whole module at
collection (ModuleNotFoundError), the shim below makes every
``@given``-decorated test an individual skip, so the rest of the suite
stays green.
"""
import sys
import types

import pytest


def _install_hypothesis_stub() -> None:
    """Register a fake ``hypothesis`` package whose ``@given`` skips the test.

    Only activated when the real library is absent.  The stub mirrors the
    small API surface the test-suite uses (``given``, ``settings``,
    ``strategies.*``, ``HealthCheck``); strategy constructors return opaque
    placeholders since the decorated test body never runs.
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "skip-if-missing shim installed by tests/conftest.py"

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def skipped(*args, **kwargs):   # pragma: no cover - never runs
                pass
            skipped.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipped.__doc__ = getattr(fn, "__doc__", None)
            return skipped
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Opaque placeholder; supports chaining (.map/.filter/.flatmap)."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies("hypothesis.strategies")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    mod.assume = lambda *_a, **_k: True
    mod.example = lambda *_a, **_k: (lambda fn: fn)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests (dry-run cells)")

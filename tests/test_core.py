"""Whale core: IR capture, strategy scopes, sharding rules, cost model, auto."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

import repro as wh
from repro.core.auto import enumerate_strategies, search
from repro.core.cost_model import (StrategySpec, TPU_V5E, V100_PAPER,
                                   WorkloadMeta, all_gather_time,
                                   all_reduce_time, step_cost)
from repro.core.ir import TaskGraph, TensorMeta, capture_meta, jaxpr_flops
from repro.core.sharding import hybrid_rules
from repro.models.lm import model_graph


# ---------------------------------------------------------------------------
# IR: meta capture is abstract + FLOPs are trip-count exact
# ---------------------------------------------------------------------------

def test_capture_meta_no_execution():
    calls = []

    def fn(x):
        calls.append(1)        # traced once; never executed
        return x @ x.T

    x = jnp.ones((8, 4))
    inputs, outputs, flops, _ = capture_meta(fn, x)
    assert outputs[0].shape == (8, 8)
    assert flops == 2 * 8 * 8 * 4


def test_jaxpr_flops_counts_scan_trips():
    def f(x):
        def body(c, _):
            return c @ jnp.eye(16), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    jaxpr = jax.make_jaxpr(f)(jnp.ones((16, 16)))
    assert jaxpr_flops(jaxpr.jaxpr) == 7 * 2 * 16 * 16 * 16


def test_jaxpr_flops_counts_remat_body():
    def f(x):
        return jax.checkpoint(lambda y: y @ y)(x).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.ones((8, 8)))
    assert jaxpr_flops(jaxpr.jaxpr) == 2 * 8 * 8 * 8
    gjax = jax.make_jaxpr(jax.grad(f))(jnp.ones((8, 8)))
    # grad of remat: fwd + recompute + 2 transpose dots
    assert jaxpr_flops(gjax.jaxpr) >= 3 * 2 * 8 * 8 * 8


def test_cluster_repeats_groups_identical_layers():
    tg = TaskGraph()
    for i in range(5):
        sg = wh.Subgraph(name=f"l{i}", fn=None, strategy=[],
                         params=[TensorMeta((4, 4), jnp.float32)],
                         outputs=[TensorMeta((2, 4), jnp.float32)])
        tg.add(sg)
    tg.add(wh.Subgraph(name="head", fn=None, strategy=[],
                       params=[TensorMeta((4, 100), jnp.float32)],
                       outputs=[TensorMeta((2, 100), jnp.float32)]))
    groups = tg.cluster_repeats()
    assert len(groups) == 2
    assert len(groups[0]["nodes"]) == 5


# ---------------------------------------------------------------------------
# strategy scopes → IR → inferred StrategySpec
# ---------------------------------------------------------------------------

def test_scopes_record_and_infer():
    def net(params, x):
        return x @ params["w"]

    params = {"w": jnp.ones((4, 8))}
    with wh.cluster(mesh_shape=(1, 1), axis_names=("data", "model")) as cl:
        with wh.replica():
            h = wh.sub("backbone", net)(params, jnp.ones((2, 4)))
        with wh.split(dim=-1):
            wh.sub("fc", net)({"w": jnp.ones((8, 16))}, h)
    names = [n.name for n in cl.taskgraph.nodes]
    assert names == ["backbone", "fc"]
    assert cl.taskgraph.by_name("backbone").strategy_kinds() == ("replica",)
    assert cl.taskgraph.by_name("fc").strategy_kinds() == ("split",)
    # param metadata split from data inputs (first dict arg convention)
    assert cl.taskgraph.by_name("fc").params[0].shape == (8, 16)
    strat = wh.strategy_from_taskgraph(cl)
    assert strat.vocab_split
    assert strat.dp == 1 and strat.tp == 1


def test_pipeline_scope_records_stages_and_micro():
    with wh.cluster(mesh_shape=(1,), axis_names=("data",)) as cl:
        with wh.replica():
            with wh.pipeline(micro_batch=6):
                with wh.stage():
                    wh.sub("s0", lambda x: x * 1.0)(jnp.ones(3))
                with wh.stage():
                    wh.sub("s1", lambda x: x * 2.0)(jnp.ones(3))
    strat = wh.strategy_from_taskgraph(cl)
    assert strat.micro_batches == 6
    idx = [next(a.options["index"] for a in n.strategy if a.kind == "stage")
           for n in cl.taskgraph.nodes]
    assert idx == [0, 1]


# ---------------------------------------------------------------------------
# sharding rules: divisibility pruning + axis reuse (property)
# ---------------------------------------------------------------------------

def _mesh(shape, names):
    return jax.make_mesh(shape, names)


def test_spec_for_prunes_non_divisible():
    rules = hybrid_rules(_mesh((1, 1), ("data", "model")))
    rules.mesh = _FakeMesh({"data": 4, "model": 16})
    # kv_heads=8 does not divide 16 → replicated
    spec = rules.spec_for(("batch", None, "kv_heads", None), (32, 1, 8, 64))
    assert spec == P("data", None, None, None)
    # q_heads=32 divides → sharded
    spec = rules.spec_for(("batch", None, "q_heads", None), (32, 1, 32, 64))
    assert spec == P("data", None, "model", None)


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_param_spec_fsdp_picks_largest_free_dim():
    rules = hybrid_rules(_mesh((1, 1), ("data", "model")))
    rules.mesh = _FakeMesh({"data": 8, "model": 4})
    spec = rules.param_spec(("embed", "mlp"), (1024, 4096),
                            fsdp_axes=("data",))
    assert spec == P("data", "model")          # mlp→model, fsdp takes embed
    # small tensors are not FSDP-sharded
    spec = rules.param_spec(("embed",), (128,), fsdp_axes=("data",))
    assert spec == P(None)


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.sampled_from(
        ["batch", "embed", "q_heads", "kv_heads", "mlp", "vocab", None]),
        min_size=1, max_size=4),
    shape=st.lists(st.sampled_from([1, 2, 3, 8, 16, 30, 64, 256]),
                   min_size=1, max_size=4),
)
def test_spec_property_legal(dims, shape):
    """Property: spec_for never reuses a mesh axis and only shards dims
    the axis size divides."""
    n = min(len(dims), len(shape))
    dims, shape = dims[:n], shape[:n]
    rules = hybrid_rules(_mesh((1, 1), ("data", "model")))
    rules.mesh = _FakeMesh({"data": 4, "model": 16, "pod": 2})
    spec = rules.spec_for(dims, shape)
    used = []
    for i, p in enumerate(spec):
        axes = (p,) if isinstance(p, str) else (p or ())
        for a in axes:
            assert a not in used, f"axis {a} reused in {spec}"
            used.append(a)
        if axes:
            sz = 1
            for a in axes:
                sz *= rules.mesh.shape[a]
            assert shape[i] % sz == 0


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_collective_formulas():
    assert all_reduce_time(100.0, 1, 10.0) == 0.0
    assert all_reduce_time(100.0, 4, 10.0) == pytest.approx(15.0)
    assert all_gather_time(100.0, 4, 10.0) == pytest.approx(7.5)


def test_step_cost_memory_decreases_with_zero():
    meta = model_graph(__import__("repro.configs", fromlist=["get_config"]) .get_config("tinyllama-1.1b"), 256, 2048).workload_meta()
    c0 = step_cost(meta, StrategySpec(dp=64, zero=0), TPU_V5E)
    c3 = step_cost(meta, StrategySpec(dp=64, zero=3), TPU_V5E)
    assert c3.mem_bytes < c0.mem_bytes


def test_step_cost_pipeline_bubble():
    meta = model_graph(__import__("repro.configs", fromlist=["get_config"]) .get_config("tinyllama-1.1b"), 64, 512).workload_meta()
    c1 = step_cost(meta, StrategySpec(dp=8, pp=2, micro_batches=1), TPU_V5E)
    c8 = step_cost(meta, StrategySpec(dp=8, pp=2, micro_batches=8), TPU_V5E)
    assert c8.bubble < c1.bubble


def test_vocab_split_beats_gathered_head_on_paper_hw():
    """The Fig-4 technique must win for a giant classifier head."""
    meta = WorkloadMeta(
        name="cls", fwd_flops=1e12, param_bytes=872e6 * 4,
        tp_shardable_param_bytes=782e6 * 4, act_bytes_per_layer=1e6,
        n_layers=50, batch=256, logits_bytes=256 * 1e5 * 4,
        head_param_bytes=782e6 * 4)
    with_split = step_cost(meta, StrategySpec(dp=8, tp=8, vocab_split=True),
                           V100_PAPER)
    without = step_cost(meta, StrategySpec(dp=8, tp=8, vocab_split=False),
                        V100_PAPER)
    assert with_split.comm < without.comm


# ---------------------------------------------------------------------------
# auto-parallel search
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(devices=st.sampled_from([8, 16, 64, 256]))
def test_enumeration_is_pruned_and_legal(devices):
    from repro.configs import get_config
    meta = model_graph(get_config("tinyllama-1.1b"), 256, 512).workload_meta()
    for s in enumerate_strategies(meta, devices):
        assert s.dp * s.tp * s.pp == devices
        assert meta.n_layers % s.pp == 0
        assert meta.batch % s.dp == 0


def test_search_returns_sorted_feasible():
    from repro.configs import get_config
    meta = model_graph(get_config("qwen3-1.7b"), 256, 4096).workload_meta()
    cands = search(meta, 256, TPU_V5E, top_k=8)
    assert cands, "no feasible strategy found"
    totals = [c.total for c in cands]
    assert totals == sorted(totals)
    assert all(c.cost.feasible for c in cands)


def test_auto_parallel_prefers_fitting_strategy_for_giant_model():
    from repro.configs import get_config
    meta = model_graph(get_config("grok-1-314b"), 256, 4096).workload_meta()
    strat = wh.auto_parallel(meta, 256, TPU_V5E)
    # 314B params cannot be pure DP on 16 GB chips
    assert strat.tp > 1 or strat.pp > 1 or strat.zero >= 3

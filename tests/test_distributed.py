"""Distributed integration tests — each runs in a subprocess with virtual
CPU devices (XLA device count is fixed at first jax import, so the main
pytest process stays single-device)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Partially-manual shard_map (manual over one axis, GSPMD-auto over the
# rest — the pipeline and compressed-DP paths) only lowers on current jax;
# the 0.4.x line's XLA aborts on PartitionId / IsManualSubgroup.  See
# repro/core/jax_compat.py for the API shims that cover everything else.
requires_partial_auto_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-auto shard_map does not lower on jax<=0.4 "
           "(XLA PartitionId/IsManualSubgroup)")


def run_py(code: str, devices: int = 8, timeout: int = 540):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_dp_matches_single_device_loss():
    """Data-parallel loss/grads == single-device (same params, same batch)."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.planner import compile_plan
        from repro.models.lm import build
        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build(cfg)
        params = model.init(jax.random.key(0))
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (8, 64)),
            jnp.int32)}
        l_ref, _ = jax.jit(model.loss_fn)(params, batch)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        plan = compile_plan(model, mesh)
        with mesh:
            l_dist, _ = plan.jit_loss(batch)(params, batch)
        np.testing.assert_allclose(float(l_ref), float(l_dist), rtol=2e-4)
        print("OK", float(l_ref), float(l_dist))
    """)


@requires_partial_auto_shard_map
def test_gpipe_loss_matches_reference():
    """Pipeline (2 stages × dp × tp) loss == non-pipelined loss."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        import repro as wh
        import repro.core.pipeline as pipe
        from repro.configs import get_config
        from repro.models.lm import build
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("stage", "data", "model"))
        rules = wh.hybrid_rules(mesh)
        lfn, pspecs = pipe.make_pipeline_loss(model, mesh, rules,
                                              micro_batches=4)
        psh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda t: isinstance(
                               t, jax.sharding.PartitionSpec))
        with mesh:
            params = jax.jit(model.init, out_shardings=psh)(jax.random.key(0))
            tokens = jnp.asarray(np.random.default_rng(0).integers(
                0, cfg.vocab, (8, 64)), jnp.int32)
            l_pipe = jax.jit(lfn)(params, tokens)
        l_ref, _ = jax.jit(model.loss_fn)(
            model.init(jax.random.key(0)), {"tokens": tokens})
        np.testing.assert_allclose(float(l_pipe), float(l_ref), rtol=2e-3)
        print("OK", float(l_pipe), float(l_ref))
    """)


@requires_partial_auto_shard_map
def test_gpipe_training_reduces_loss():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        import repro as wh
        import repro.core.pipeline as pipe
        from repro.configs import get_config
        from repro.models.lm import build
        from repro.optim import adamw
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)
        mesh = jax.make_mesh((2, 2, 1), ("stage", "data", "model"))
        rules = wh.hybrid_rules(mesh)
        opt = adamw(lr=1e-3)
        step = pipe.make_pipeline_train_step(model, mesh, rules, opt,
                                             micro_batches=2, donate=False)
        pspecs = pipe.staged_specs(rules, model.axes(), model.param_shapes())
        psh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda t: isinstance(
                               t, jax.sharding.PartitionSpec))
        with mesh:
            params = jax.jit(model.init, out_shardings=psh)(jax.random.key(0))
            ost = opt.init(params)
            tokens = jnp.asarray(np.random.default_rng(0).integers(
                0, cfg.vocab, (8, 64)), jnp.int32)
            losses = []
            for i in range(4):
                params, ost, loss = step(params, ost, tokens, i)
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print("OK", losses)
    """)


@requires_partial_auto_shard_map
def test_uneven_hetero_plan_pipeline_matches_reference():
    """The tentpole acceptance path: a mixed V100/P100 ClusterSpec →
    hetero planner emits an uneven latency-equalizing stage allocation →
    the plan's pipeline step executes it end to end (padded stage-sharded
    params, 1F1B schedule on the strategy) and the loss matches the
    single-device reference."""
    run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.cost_model import (ClusterSpec, DeviceGroup,
                                           P100_16G, StrategySpec,
                                           V100_PAPER)
        from repro.core.planner import compile_plan, mesh_for_strategy
        from repro.models.lm import build, model_graph
        from repro.optim import adamw
        import repro.core.pipeline as pipe
        cfg = dataclasses.replace(get_config("tinyllama-1.1b", smoke=True),
                                  n_layers=8)
        model = build(cfg)
        meta = model_graph(cfg, 64, 512).workload_meta()   # planning scale
        spec = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 4),
                                   DeviceGroup("p100", P100_16G, 4)))
        strat = StrategySpec(dp=2, pp=4, micro_batches=4, schedule="1f1b")
        mesh = mesh_for_strategy(strat)
        plan = compile_plan(model, mesh, strategy=strat, cluster_spec=spec,
                            workload_meta=meta, overlap=0.5)
        sl = plan.stage_layers()
        assert sum(sl) == 8 and len(set(sl)) > 1, f"expected uneven: {sl}"
        opt = adamw(lr=1e-3)
        step = plan.jit_pipeline_train_step(opt, donate=False)
        params = plan.init_pipeline_params(jax.random.key(0))
        tokens = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (8, 64)), jnp.int32)
        with mesh:
            ost = jax.jit(opt.init)(params)
            lfn, _ = pipe.make_pipeline_loss(
                model, mesh, plan.rules, micro_batches=4, stage_layers=sl)
            l_pipe = jax.jit(lfn)(params, tokens)
            losses = []
            for i in range(3):
                params, ost, loss = step(params, ost, tokens, i)
                losses.append(float(loss))
        l_ref, _ = jax.jit(model.loss_fn)(
            model.init(jax.random.key(0)), {"tokens": tokens})
        np.testing.assert_allclose(float(l_pipe), float(l_ref), rtol=2e-3)
        np.testing.assert_allclose(losses[0], float(l_ref), rtol=2e-3)
        assert losses[-1] < losses[0], losses
        print("OK", sl, float(l_pipe), float(l_ref), losses)
    """)


@requires_partial_auto_shard_map
def test_compress_pod_training_step():
    """Cross-pod int8 error-feedback gradient reduction end-to-end."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.planner import compile_plan, mesh_for_strategy
        from repro.core.cost_model import StrategySpec
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.optim import grad_compress
        cfg = get_config("tinyllama-1.1b", smoke=True)
        model = build(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        plan = compile_plan(model, mesh)
        opt = adamw(lr=1e-3)
        batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (8, 64)), jnp.int32)}
        with mesh:
            params = plan.init_params(jax.random.key(0))
            ost = opt.init(params)
            err = grad_compress.init_error_tree(params)
            step = plan.jit_train_step(opt, batch, compress_pod=True,
                                       donate=False)
            losses = []
            for i in range(4):
                params, ost, m, err = step(params, ost, batch, i, err)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("OK", losses)
    """)


def test_expert_parallel_moe_matches_reference():
    """Tentpole acceptance: the nested replica{split[experts]} executor —
    moe_block_ep's shard_map with explicit all-to-all dispatch/combine
    bridges — equals the single-device moe_block to fp32 tolerance,
    forward AND backward (runs on jax 0.4.x too: the shard_map is fully
    manual over the expert axis)."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import (MoECfg, init_moe, moe_block,
                                      moe_block_ep)
        cfg = MoECfg(d_model=32, n_experts=8, top_k=2, d_ff_expert=64,
                     n_shared=1)
        params = init_moe(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (8, 16, 32), jnp.float32)
        mesh = jax.make_mesh((4,), ("expert",))

        y_ref, aux_ref = jax.jit(lambda p, x: moe_block(p, x, cfg))(params, x)
        with mesh:
            y_ep, aux_ep = jax.jit(
                lambda p, x: moe_block_ep(p, x, cfg, mesh))(params, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                                   rtol=2e-5, atol=2e-5)
        for k in ("lb_loss", "z_loss"):
            np.testing.assert_allclose(float(aux_ref[k]), float(aux_ep[k]),
                                       rtol=1e-5)

        def loss(block):
            def f(p, x):
                y, aux = block(p, x)
                return (y ** 2).mean() + aux["lb_loss"] + aux["z_loss"]
            return f
        g_ref = jax.jit(jax.grad(loss(lambda p, x: moe_block(p, x, cfg))))(
            params, x)
        with mesh:
            g_ep = jax.jit(jax.grad(loss(
                lambda p, x: moe_block_ep(p, x, cfg, mesh))))(params, x)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-4, atol=1e-6), g_ref, g_ep)
        print("OK ep fwd+bwd == reference")
    """, devices=4)


def test_expert_parallel_rejects_indivisible():
    run_py("""
        import jax, jax.numpy as jnp
        from repro.models.moe import MoECfg, init_moe, moe_block_ep
        cfg = MoECfg(d_model=16, n_experts=6, top_k=2, d_ff_expert=32)
        params = init_moe(jax.random.key(0), cfg, jnp.float32)
        mesh = jax.make_mesh((4,), ("expert",))
        try:
            moe_block_ep(params, jnp.ones((8, 16, 16)), cfg, mesh)
        except ValueError as e:
            assert "n_experts" in str(e), e
            print("OK raises on E % ep != 0")
        else:
            raise SystemExit("expected ValueError")
    """, devices=4)


def test_elastic_remesh_roundtrip(tmp_path):
    """Checkpoint on a 4×1 mesh, restore on 2×2 — values identical."""
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core.planner import compile_plan
        from repro.models.lm import build
        from repro.optim import adamw
        from repro.runtime.elastic import ElasticContext
        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build(cfg)
        opt = adamw(lr=1e-3)
        mesh1 = jax.make_mesh((4, 1), ("data", "model"))
        plan1 = compile_plan(model, mesh1)
        with mesh1:
            params = plan1.init_params(jax.random.key(1))
            ost = opt.init(params)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
        mgr.save(7, {{"params": params, "opt": ost}}, extra={{"k": 1}})
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        ctx = ElasticContext(model=model, optimizer=opt)
        step, plan2, p2, o2, extra = ctx.remesh(mgr, mesh2)
        assert step == 7 and extra["k"] == 1
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        # restored params actually usable on the new mesh
        batch = {{"tokens": jnp.zeros((4, 32), jnp.int32)}}
        with mesh2:
            loss, _ = plan2.jit_loss(batch)(p2, batch)
        assert np.isfinite(float(loss))
        print("OK")
    """)


@pytest.mark.slow
def test_production_dryrun_one_cell():
    """The real 256-chip dry-run machinery on one (arch × shape) cell."""
    out = run_py("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("tinyllama-1.1b", "decode_32k")
        assert rec["status"] == "ok", rec
        assert rec["mem_temp_gib"] + rec["mem_args_gib"] < 16.0
        assert rec["coll_bytes_per_dev"] > 0
        assert rec["flops_per_dev"] > 0
        print("OK", rec["bottleneck"], round(rec["roofline_frac"], 4))
    """, devices=8)   # XLA_FLAGS overridden inside dryrun to 512
    assert "OK" in out


# ---------------------------------------------------------------------------
# encoder–decoder two-tower pipeline (PR 9: the M6 multimodal cut)
# ---------------------------------------------------------------------------

def test_encdec_pipeline_loss_matches_reference():
    """Two-tower pipeline (stage 0 = frontend+encoder, stage 1 = decoder)
    loss == the non-pipelined encdec loss.  Forward-only, so it runs on
    every supported jax (the grad path is gated below)."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        import repro as wh
        import repro.core.pipeline as pipe
        from repro.configs import get_config
        from repro.models.lm import build
        cfg = get_config("seamless-m4t-medium", smoke=True)
        model = build(cfg)
        mesh = jax.make_mesh((2, 1, 1), ("stage", "data", "model"))
        rules = wh.hybrid_rules(mesh)
        lfn, pspecs = pipe.make_encdec_pipeline_loss(model, mesh, rules,
                                                     micro_batches=2)
        rng = np.random.default_rng(0)
        frames = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)),
                             jnp.float32)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
        params = model.init(jax.random.key(0))
        with mesh:
            l_pipe = jax.jit(lfn)(params, frames, tokens)
        l_ref, _ = jax.jit(model.loss_fn)(
            params, {"frames": frames, "tokens": tokens})
        np.testing.assert_allclose(float(l_pipe), float(l_ref), rtol=2e-4)
        print("OK", float(l_pipe), float(l_ref))
    """, devices=2)


def test_encdec_pipeline_rejects_wrong_stage_count():
    run_py("""
        import jax
        import repro as wh
        import repro.core.pipeline as pipe
        from repro.configs import get_config
        from repro.models.lm import build
        model = build(get_config("seamless-m4t-medium", smoke=True))
        mesh = jax.make_mesh((4, 1, 1), ("stage", "data", "model"))
        try:
            pipe.make_encdec_pipeline_loss(model, mesh,
                                           wh.hybrid_rules(mesh),
                                           micro_batches=2)
        except ValueError as e:
            assert "2-stage" in str(e)
            print("OK")
        else:
            raise SystemExit("4-stage encdec should have been rejected")
    """, devices=4)


def test_encdec_plan_routes_to_two_tower_engine():
    """compile_plan on an encdec arch: stage_layers() reports the fixed
    tower edge and jit_pipeline_train_step dispatches to the encdec
    engine (no layer-stack splitting)."""
    run_py("""
        import jax
        from repro.configs import get_config
        from repro.core.cost_model import StrategySpec
        from repro.core.planner import compile_plan, mesh_for_strategy
        from repro.models.lm import build
        cfg = get_config("seamless-m4t-medium", smoke=True)
        model = build(cfg)
        assert model.stack is None     # encdec has no repeated layer stack
        strat = StrategySpec(dp=1, pp=2, micro_batches=2)
        mesh = mesh_for_strategy(strat)
        plan = compile_plan(model, mesh, strategy=strat)
        assert plan.stage_layers() == (cfg.n_enc_layers, cfg.n_dec_layers), \
            plan.stage_layers()
        print("OK", plan.stage_layers())
    """, devices=2)


@requires_partial_auto_shard_map
def test_encdec_pipeline_training_reduces_loss():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.cost_model import StrategySpec
        from repro.core.planner import compile_plan, mesh_for_strategy
        from repro.models.lm import build
        from repro.optim import adamw
        cfg = get_config("seamless-m4t-medium", smoke=True)
        model = build(cfg)
        strat = StrategySpec(dp=1, pp=2, micro_batches=2)
        mesh = mesh_for_strategy(strat)
        plan = compile_plan(model, mesh, strategy=strat)
        opt = adamw(lr=1e-3)
        step = plan.jit_pipeline_train_step(opt, donate=False)
        params = plan.init_pipeline_params(jax.random.key(0))
        rng = np.random.default_rng(0)
        frames = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)),
                             jnp.float32)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
        with mesh:
            ost = jax.jit(opt.init)(params)
            losses = []
            for i in range(4):
                params, ost, loss = step(params, ost, frames, tokens,
                                         jnp.asarray(i))
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print("OK", losses)
    """, devices=2)


def test_multimodal_pipeline_determinism_and_reshard():
    """MultimodalPipeline: modality stream is deterministic, resumable,
    and host-count invariant (the token-pipeline guarantees extend to
    frames/patch_embeds)."""
    import numpy as np
    from repro.data.pipeline import DataCfg, MultimodalPipeline
    cfg = DataCfg(global_batch=8, seq_len=16, vocab=512, seed=3)
    p1 = MultimodalPipeline(cfg, modality="encdec", d_model=32, src_len=8,
                            host_id=0, n_hosts=1)
    batches = [p1.next_batch() for _ in range(4)]
    assert batches[0]["frames"].shape == (8, 8, 32)
    # determinism: a fresh pipeline replays the same stream
    p2 = MultimodalPipeline(cfg, modality="encdec", d_model=32, src_len=8,
                            host_id=0, n_hosts=1)
    for b in batches:
        b2 = p2.next_batch()
        np.testing.assert_array_equal(b["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b["frames"], b2["frames"])
    # reshard: 2-host shards concatenate to the 1-host batch
    h0 = p1.reshard(host_id=0, n_hosts=2)
    h1 = p1.reshard(host_id=1, n_hosts=2)
    full = p1.next_batch()
    a, b = h0.next_batch(), h1.next_batch()
    np.testing.assert_array_equal(
        np.concatenate([a["frames"], b["frames"]]), full["frames"])
    np.testing.assert_array_equal(
        np.concatenate([a["tokens"], b["tokens"]]), full["tokens"])
    # vlm modality emits patch_embeds of the frontend length
    pv = MultimodalPipeline(cfg, modality="vlm", d_model=32, frontend_len=4,
                            host_id=0, n_hosts=1)
    assert pv.next_batch()["patch_embeds"].shape == (8, 4, 32)

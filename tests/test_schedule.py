"""Pipeline scheduling subsystem (repro.core.schedule + the executors).

Three layers of evidence:

1. **Table properties** (pure Python, random (S, M)): every schedule runs
   each (stage, micro-batch) fwd and bwd exactly once, respects the
   pipeline dependencies, spans 2·(M+S−1) ticks with the closed-form
   bubble (S−1)/(M+S−1), and 1F1B's peak in-flight activations are
   ≤ min(S, M) while GPipe's are exactly M.
2. **Schedule equivalence** (single device, f32 smoke model): the
   order-faithful interpreter (`pipeline.schedule_grads`) reproduces the
   single-device reference loss and gradients for even *and* uneven
   stage splits, GPipe and 1F1B produce identical results on the same
   params/tokens (schedule changes order, not math), and the measured
   activation-buffer high-water mark matches the schedule's accounting.
3. **Edges**: the B % micro_batches guard raises a clear ValueError
   everywhere a truncated reshape used to lurk, and the uneven param
   pad/unpad round-trips.
"""
import dataclasses
import random

import pytest

from repro.core.schedule import (FWD, BWD, Schedule, SCHEDULE_NAMES,
                                 bubble_fraction_closed_form,
                                 gpipe_schedule, in_flight_micro_batches,
                                 make_schedule, one_f_one_b_schedule)


def random_cases(n=25, seed=0):
    rng = random.Random(seed)
    cases = [(2, 2), (2, 8), (4, 4), (4, 1), (1, 4), (3, 5), (8, 2)]
    while len(cases) < n:
        cases.append((rng.randint(1, 8), rng.randint(1, 16)))
    return cases


# ---------------------------------------------------------------------------
# 1. table properties over random (S, M)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SCHEDULE_NAMES)
def test_every_unit_scheduled_exactly_once(name):
    for S, M in random_cases():
        sc = make_schedule(name, S, M)
        seen = {}
        for _, s, mb, phase in sc.slots():
            key = (s, mb, phase)
            assert key not in seen, f"{name} S={S} M={M}: {key} twice"
            seen[key] = True
        assert len(seen) == 2 * S * M, \
            f"{name} S={S} M={M}: {len(seen)} slots, expected {2 * S * M}"


@pytest.mark.parametrize("name", SCHEDULE_NAMES)
def test_dependencies_respected(name):
    """fwd s−1 before fwd s; bwd s+1 before bwd s; own fwd before bwd —
    re-checked here independently of Schedule.validate()."""
    for S, M in random_cases():
        sc = make_schedule(name, S, M)
        when = {(s, mb, ph): t for t, s, mb, ph in sc.slots()}
        for s in range(S):
            for mb in range(M):
                if s > 0:
                    assert when[(s - 1, mb, FWD)] < when[(s, mb, FWD)]
                if s < S - 1:
                    assert when[(s + 1, mb, BWD)] < when[(s, mb, BWD)]
                assert when[(s, mb, FWD)] < when[(s, mb, BWD)]


@pytest.mark.parametrize("name", SCHEDULE_NAMES)
def test_at_most_one_slot_per_stage_per_tick(name):
    for S, M in random_cases(10, seed=3):
        sc = make_schedule(name, S, M)
        for row in sc.ticks:
            assert len(row) == S     # one cell per stage, idle cells None


def test_peak_in_flight_gpipe_all_1f1b_capped():
    """The memory headline: GPipe buffers all M micro-batches, 1F1B never
    more than min(S, M) — table-measured AND matching the closed forms
    the cost model prices with."""
    for S, M in random_cases():
        g = gpipe_schedule(S, M)
        f = one_f_one_b_schedule(S, M)
        assert g.peak_in_flight() == M
        assert f.peak_in_flight() <= min(S, M)
        assert g.peak_in_flight() == in_flight_micro_batches(S, M, "gpipe")
        assert f.peak_in_flight() == in_flight_micro_batches(S, M, "1f1b")
        if M >= S:
            # per-stage cap is exactly min(S − s, M): stage 0 is tightest
            assert f.per_stage_in_flight()[0] == S


@pytest.mark.parametrize("name", SCHEDULE_NAMES)
def test_bubble_fraction_matches_closed_form(name):
    for S, M in random_cases():
        sc = make_schedule(name, S, M)
        assert sc.bubble_fraction() == pytest.approx(
            bubble_fraction_closed_form(S, M), abs=1e-12)
        assert sc.n_ticks == 2 * (M + S - 1)


def test_validate_catches_broken_tables():
    good = gpipe_schedule(2, 2)
    # drop one bwd slot → incomplete
    ticks = list(good.ticks)
    ticks[-1] = (None, None)
    with pytest.raises(ValueError, match="never runs"):
        Schedule("broken", 2, 2, tuple(ticks)).validate()
    # swap the two forward waves stage-wise → dependency violation
    bad = tuple(tuple(reversed(row)) for row in good.ticks)
    with pytest.raises(ValueError):
        Schedule("swapped", 2, 2, bad).validate()


def test_unknown_schedule_rejected():
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("interleaved-zb", 4, 8)
    with pytest.raises(ValueError, match="unknown schedule"):
        in_flight_micro_batches(4, 8, "interleaved-zb")


# ---------------------------------------------------------------------------
# 2. schedule equivalence through the interpreter (single device, f32)
# ---------------------------------------------------------------------------

def _f32_model(n_layers=4):
    from repro.configs import get_config
    from repro.models.lm import build
    # f32 activations → tight tolerances; remat off → the eager interpreter
    # does not re-trace each checkpointed repeat (pure test-speed choice)
    cfg = dataclasses.replace(get_config("tinyllama-1.1b", smoke=True),
                              n_layers=n_layers, dtype="float32",
                              remat="none", name="sched-f32")
    return build(cfg)


_RUNS = {}


def _interpreter_run(name):
    """Shared (model, params, tokens, reference, interpreter) results for
    the even-split equivalence tests — computed once per schedule."""
    if name not in _RUNS:
        import jax
        from repro.core.pipeline import schedule_grads
        model = _f32_model()
        params = model.init(jax.random.key(0))
        tokens = _tokens(model)
        ref = _reference(model, params, tokens)
        out = schedule_grads(model, params, tokens, micro_batches=4,
                             schedule=name, n_stages=2)
        _RUNS[name] = (ref, out)
    return _RUNS[name]


def _tokens(model, B=8, T=16, seed=0):
    import jax.numpy as jnp
    import numpy as np
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, model.cfg.vocab, (B, T)), jnp.int32)


def _reference(model, params, tokens):
    import jax
    (loss, _), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, {"tokens": tokens})
    return loss, grads


def _assert_trees_close(a, b, rtol=2e-4, atol=2e-5):
    import jax
    import numpy as np
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("name", SCHEDULE_NAMES)
def test_interpreter_matches_single_device_reference(name):
    """Pipelined loss AND grads == the non-pipelined reference."""
    import numpy as np
    (l_ref, g_ref), (loss, grads, stats) = _interpreter_run(name)
    np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-5)
    _assert_trees_close(g_ref, grads)
    assert stats["bubble_fraction"] == pytest.approx(
        bubble_fraction_closed_form(2, 4))


def test_gpipe_and_1f1b_identical_losses_and_grads():
    """Schedule changes order, not math: same params/tokens → same step."""
    import numpy as np
    _, (lg, gg, sg) = _interpreter_run("gpipe")
    _, (lf, gf, sf) = _interpreter_run("1f1b")
    np.testing.assert_allclose(float(lg), float(lf), rtol=1e-6)
    _assert_trees_close(gg, gf, rtol=1e-5, atol=1e-7)
    # ...while the memory profiles genuinely differ
    assert sg["peak_in_flight"] == 4 and sf["peak_in_flight"] == 2


@pytest.mark.parametrize("stage_layers", [(3, 1), (1, 2, 1)])
def test_uneven_stages_match_reference(stage_layers):
    """The tentpole numerics: latency-equalizing *uneven* layer splits
    (what HeteroPlacement.layer_alloc produces) change nothing about the
    math."""
    import jax
    import numpy as np
    from repro.core.pipeline import schedule_grads
    model = _f32_model(n_layers=sum(stage_layers))
    params = model.init(jax.random.key(2))
    tokens = _tokens(model, seed=2)
    l_ref, g_ref = _reference(model, params, tokens)
    loss, grads, stats = schedule_grads(model, params, tokens,
                                        micro_batches=2, schedule="1f1b",
                                        stage_layers=stage_layers)
    np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-5)
    _assert_trees_close(g_ref, grads)
    assert stats["stage_layers"] == tuple(stage_layers)


def test_interpreter_buffer_audit_matches_schedule_accounting():
    """schedule_grads measures its live activation buffer per stage and
    fails loudly if it disagrees with Schedule.per_stage_in_flight — here
    we confirm the measured numbers surface correctly."""
    for name in SCHEDULE_NAMES:
        sc = make_schedule(name, 2, 4)
        _, (_, _, stats) = _interpreter_run(name)
        assert stats["per_stage_in_flight"] == sc.per_stage_in_flight()
        assert stats["n_ticks"] == sc.n_ticks


# ---------------------------------------------------------------------------
# 3. edges: B % M guard, pad/unpad round-trip, alloc mapping
# ---------------------------------------------------------------------------

def test_batch_not_divisible_by_micro_batches_raises():
    """Regression: the old truncated reshape path must be a loud error."""
    import jax
    from repro.core.pipeline import schedule_grads
    model = _f32_model()
    params = model.init(jax.random.key(0))
    tokens = _tokens(model, B=7)
    with pytest.raises(ValueError, match="micro_batches"):
        schedule_grads(model, params, tokens, micro_batches=4,
                       schedule="1f1b", n_stages=2)


def test_grad_accumulation_batch_guard_in_planner():
    """Same edge through ExecutionPlan.train_step_fn's accumulator."""
    import jax
    import jax.numpy as jnp
    from repro.core.planner import compile_plan
    from repro.optim.optimizer import adamw
    model = _f32_model(n_layers=2)
    mesh = jax.make_mesh((1,), ("data",))
    plan = compile_plan(model, mesh)
    fn = plan.train_step_fn(adamw(lr=1e-3), micro_batches=3)
    params = model.init(jax.random.key(0))
    opt_state = adamw(lr=1e-3).init(params)
    batch = {"tokens": _tokens(model, B=8)}
    with mesh:
        with pytest.raises(ValueError, match="silently drop"):
            jax.eval_shape(fn, params, opt_state, batch,
                           jnp.zeros((), jnp.int32))


def test_pad_unpad_round_trip_and_zero_grad_rows():
    import jax
    import numpy as np
    from repro.core.pipeline import pad_stage_stack, unpad_stage_stack
    model = _f32_model(n_layers=4)
    blocks = model.init(jax.random.key(0))["blocks"]
    for sl in ((3, 1), (1, 2, 1), (2, 2)):
        padded = pad_stage_stack(blocks, sl)
        lmax = max(sl)
        for leaf in jax.tree.leaves(padded):
            assert leaf.shape[0] == len(sl) * lmax
        rt = unpad_stage_stack(padded, sl)
        for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(blocks)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stage_layers_validation_and_alloc_mapping():
    from repro.core.pipeline import (check_stage_layers, even_stage_layers,
                                     stage_layers_from_alloc)
    model = _f32_model(n_layers=8)
    assert even_stage_layers(8, 4) == (2, 2, 2, 2)
    with pytest.raises(ValueError, match="not divisible"):
        even_stage_layers(8, 3)
    with pytest.raises(ValueError, match="sums to"):
        check_stage_layers((3, 3), 8, 2)
    with pytest.raises(ValueError, match=">= 1"):
        check_stage_layers((8, 0), 8, 2)
    assert stage_layers_from_alloc(model.stack, (3, 3, 1, 1)) == (3, 3, 1, 1)


def test_cost_model_prices_1f1b_memory_below_gpipe():
    """The search's tie-breaker: same bubble, smaller activation term."""
    from repro.configs import get_config
    from repro.core.cost_model import (StrategySpec, TPU_V5E,
                                       step_cost)
    from repro.models.lm import model_graph
    meta = model_graph(get_config("tinyllama-1.1b"), 64, 512).workload_meta()
    g = step_cost(meta, StrategySpec(dp=8, pp=2, micro_batches=8,
                                     schedule="gpipe"), TPU_V5E)
    f = step_cost(meta, StrategySpec(dp=8, pp=2, micro_batches=8,
                                     schedule="1f1b"), TPU_V5E)
    assert f.mem_bytes < g.mem_bytes
    assert f.bubble == g.bubble
    assert f.compute == g.compute


def test_auto_search_enumerates_both_schedules():
    from repro.configs import get_config
    from repro.core.auto import enumerate_strategies
    from repro.models.lm import model_graph
    meta = model_graph(get_config("tinyllama-1.1b"), 256, 512).workload_meta()
    scheds = {(s.pp > 1, s.schedule)
              for s in enumerate_strategies(meta, 8)}
    assert (True, "gpipe") in scheds and (True, "1f1b") in scheds
    assert (False, "1f1b") not in scheds     # schedule only matters for pp>1


def test_gpipe_aliases_are_gone():
    """The pre-schedule-subsystem make_gpipe_* shims (deprecated since the
    schedule subsystem landed) are removed; make_pipeline_* is the API."""
    import repro.core.pipeline as pipe
    assert not hasattr(pipe, "make_gpipe_loss")
    assert not hasattr(pipe, "make_gpipe_train_step")
    assert callable(pipe.make_pipeline_loss)
    assert callable(pipe.make_pipeline_train_step)

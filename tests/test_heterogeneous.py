"""Heterogeneity-aware planning (Whale §5, DESIGN.md §2).

Three properties the balancer must uphold:
  (a) throughput-proportional batch shares always sum to the global batch;
  (b) a returned placement never exceeds any group's HBM;
  (c) a homogeneous cluster reduces *exactly* to the pre-heterogeneous
      plan — same costs, same meshes, same search ranking (the regression
      guard for every pre-existing call site).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.auto import search
from repro.core.cost_model import (ClusterSpec, DeviceGroup, P100_16G,
                                   StrategySpec, T4_16G, TPU_V5E, V100_PAPER,
                                   step_cost)
from repro.core.hetero import (balance_batch, balance_stages,
                               hetero_step_cost, plan_placement,
                               proportional_split, scale_meta_stage,
                               strategy_fits_cluster)
from repro.core.planner import mesh_for_strategy
from repro.models.lm import model_graph


def _meta(batch=256, seq=512, arch="tinyllama-1.1b"):
    from repro.configs import get_config
    return model_graph(get_config(arch), batch, seq).workload_meta()


MIXES = [
    ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                        DeviceGroup("t4", T4_16G, 8))),
    ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 12),
                        DeviceGroup("p100", P100_16G, 4))),
    ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                        DeviceGroup("t4", T4_16G, 4),
                        DeviceGroup("p100", P100_16G, 4))),
    ClusterSpec(groups=(DeviceGroup("tpu", TPU_V5E, 8),
                        DeviceGroup("t4", T4_16G, 8))),
]


# ---------------------------------------------------------------------------
# proportional_split: the integer allocator under both mechanisms
# ---------------------------------------------------------------------------

def test_proportional_split_sums_and_minimum():
    for total, weights, minimum in [
            (256, [1.0, 1.0], 0), (256, [3.0, 1.0], 0),
            (22, [56.0, 26.0, 7.5], 1), (7, [1e-9, 1.0], 0),
            (100, [0.0, 0.0], 0), (4, [5.0, 1.0, 1.0, 1.0], 1)]:
        out = proportional_split(total, weights, minimum=minimum)
        assert sum(out) == total
        assert all(x >= minimum for x in out)


def test_proportional_split_even_on_equal_weights():
    """The homogeneous-reduction prerequisite: equal weights + divisible
    total → exactly even."""
    for n in (2, 4, 8):
        assert proportional_split(256, [1.0] * n) == [256 // n] * n


@settings(max_examples=60, deadline=None)
@given(total=st.integers(1, 4096),
       weights=st.lists(st.floats(0.01, 100.0), min_size=1, max_size=6))
def test_proportional_split_property(total, weights):
    out = proportional_split(total, weights)
    assert sum(out) == total and all(x >= 0 for x in out)


# ---------------------------------------------------------------------------
# (a) batch shares sum to the global batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", MIXES)
@pytest.mark.parametrize("batch", [64, 256, 263])
def test_batch_split_sums_to_global_batch(spec, batch):
    meta = _meta(batch=batch)
    strat = StrategySpec(dp=spec.n_devices, zero=3)
    shares = balance_batch(meta, strat, spec)
    assert len(shares) == len(spec.groups)
    assert sum(shares) == batch
    assert all(s >= 0 for s in shares)


def test_batch_split_favours_faster_group():
    spec = MIXES[0]                       # 8×V100 vs 8×T4
    meta = _meta(batch=256)
    shares = balance_batch(meta, StrategySpec(dp=16, zero=3), spec)
    assert shares[0] > shares[1], \
        "V100 group (faster) must receive the larger share"


# ---------------------------------------------------------------------------
# (b) placements never exceed any group's HBM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", MIXES)
def test_placement_respects_hbm(spec):
    meta = _meta(batch=256)
    for strat in (StrategySpec(dp=spec.n_devices, zero=3),
                  StrategySpec(dp=spec.n_devices // 4, tp=4, zero=1),
                  StrategySpec(dp=spec.n_devices // 2, pp=2,
                               micro_batches=8, zero=1)):
        if not strategy_fits_cluster(strat, spec):
            continue
        try:
            pl = plan_placement(meta, strat, spec, overlap=0.5)
        except ValueError:
            continue                       # no feasible balance: also legal
        if not pl.cost.feasible:
            continue
        for u in pl.units:
            assert u.cost.mem_bytes <= u.group.hw.hbm_bytes, \
                f"{u.kind} on {u.group.name} overflows HBM"


def test_stage_allocation_sums_and_respects_hbm():
    spec = MIXES[0]
    meta = _meta(batch=64)
    strat = StrategySpec(dp=4, tp=1, pp=4, micro_batches=8, zero=1)
    sgroups, layers = balance_stages(meta, strat, spec)
    assert sum(layers) == meta.n_layers
    assert all(l >= 1 for l in layers)
    for g, ls in zip(sgroups, layers):
        c = step_cost(scale_meta_stage(meta, ls, strat.pp), strat, g.hw)
        assert c.mem_bytes <= g.hw.hbm_bytes


def test_stage_allocation_gives_fast_stages_more_layers():
    spec = MIXES[1]                       # 12×V100 + 4×P100 (P100 ~4× slower)
    meta = _meta(batch=64)
    strat = StrategySpec(dp=4, tp=1, pp=4, micro_batches=8, zero=1)
    sgroups, layers = balance_stages(meta, strat, spec)
    v100 = [l for g, l in zip(sgroups, layers) if g.hw is V100_PAPER]
    p100 = [l for g, l in zip(sgroups, layers) if g.hw is P100_16G]
    assert min(v100) > max(p100)


def test_infeasible_batch_raises():
    """A global batch no HBM-capped assignment can hold must raise."""
    tiny = ClusterSpec(groups=(DeviceGroup("a", V100_PAPER, 1),
                               DeviceGroup("b", T4_16G, 1)))
    meta = _meta(batch=65536, seq=4096)
    with pytest.raises(ValueError):
        balance_batch(meta, StrategySpec(dp=2, zero=3), tiny)


# ---------------------------------------------------------------------------
# (c) homogeneous reduction: byte-identical to the pre-PR planner
# ---------------------------------------------------------------------------

HOMOG = ClusterSpec.homogeneous(V100_PAPER, 16)


@pytest.mark.parametrize("strat", [
    StrategySpec(dp=16),
    StrategySpec(dp=8, tp=2),
    StrategySpec(dp=8, tp=2, zero=3),
    StrategySpec(dp=4, tp=2, pp=2, micro_batches=8),
    StrategySpec(dp=8, pp=2, micro_batches=4, zero=1),
])
def test_homogeneous_cost_identical(strat):
    """hetero_step_cost on a single-group spec == plain step_cost, term by
    term (pp must divide n_layers, as the search enforces)."""
    meta = _meta(batch=256)
    assert meta.n_layers % strat.pp == 0
    old = step_cost(meta, strat, V100_PAPER, overlap=0.5)
    new = hetero_step_cost(meta, strat, HOMOG, overlap=0.5)
    assert new.compute == old.compute
    assert new.comm == old.comm
    assert new.bubble == old.bubble
    assert new.mem_bytes == old.mem_bytes
    assert new.feasible == old.feasible


def test_homogeneous_balanced_equals_naive():
    meta = _meta(batch=256)
    strat = StrategySpec(dp=8, tp=2)
    b = plan_placement(meta, strat, HOMOG, overlap=0.5)
    n = plan_placement(meta, strat, HOMOG, overlap=0.5, balanced=False)
    assert b.batch_shares == n.batch_shares == (256,)
    assert b.layer_alloc == n.layer_alloc
    assert b.cost.total == n.cost.total


def test_homogeneous_multi_group_even_split():
    """Two identical groups: balanced shares are exactly even."""
    spec = ClusterSpec(groups=(DeviceGroup("a", V100_PAPER, 8),
                               DeviceGroup("b", V100_PAPER, 8)))
    meta = _meta(batch=256)
    shares = balance_batch(meta, StrategySpec(dp=16, zero=3), spec)
    assert shares == (128, 128)


def test_homogeneous_search_identical():
    """Search over a homogeneous ClusterSpec ranks exactly like the plain
    (devices, hw) search — same strategies, same totals, same order."""
    meta = _meta(batch=256)
    via_spec = search(meta, HOMOG, top_k=8, overlap=0.5)
    plain = search(meta, 16, V100_PAPER, top_k=8, overlap=0.5)
    assert [c.strategy for c in via_spec] == [c.strategy for c in plain]
    assert [c.total for c in via_spec] == [c.total for c in plain]


def test_homogeneous_mesh_identical():
    """mesh_for_strategy with a homogeneous cluster_spec returns the same
    mesh as without one (byte-identical plan guarantee)."""
    import numpy as np
    strat = StrategySpec(dp=1, tp=1)
    m0 = mesh_for_strategy(strat)
    m1 = mesh_for_strategy(strat, cluster_spec=ClusterSpec.homogeneous(
        V100_PAPER, strat.devices))
    assert m0.shape == m1.shape and m0.axis_names == m1.axis_names
    assert np.array_equal(m0.devices, m1.devices)


def test_mesh_rejects_straddling_strategy():
    spec = ClusterSpec(groups=(DeviceGroup("a", V100_PAPER, 6),
                               DeviceGroup("b", T4_16G, 10)))
    with pytest.raises(ValueError):
        mesh_for_strategy(StrategySpec(dp=4, tp=4), cluster_spec=spec)


# ---------------------------------------------------------------------------
# end-to-end: search + benchmark headline
# ---------------------------------------------------------------------------

def test_hetero_search_returns_balanced_placements():
    meta = _meta(batch=256)
    cands = search(meta, MIXES[0], top_k=5, overlap=0.5)
    assert cands, "mixed V100/T4 cluster must have feasible strategies"
    totals = [c.total for c in cands]
    assert totals == sorted(totals)
    for c in cands:
        assert c.placement is not None
        assert sum(c.placement.batch_shares) == meta.batch
        assert strategy_fits_cluster(c.strategy, MIXES[0])


def test_hardware_aware_beats_naive_on_mixed_cluster():
    """The fig7 headline as a test: balanced > naive even split on
    mixed V100/T4, exact tie on homogeneous."""
    meta = _meta(batch=256)
    strat = StrategySpec(dp=8, pp=2, micro_batches=8, zero=1)
    aware = plan_placement(meta, strat, MIXES[0], overlap=0.5)
    naive = plan_placement(meta, strat, MIXES[0], overlap=0.5,
                           balanced=False)
    assert aware.cost.total < naive.cost.total


def test_elastic_rebalance_picks_feasible_strategy():
    """runtime path: re-mesh onto a different hardware mix re-plans via
    the hetero search (plan-level check; no devices needed)."""
    meta = _meta(batch=256)
    cands = search(meta, MIXES[2], top_k=1, overlap=0.5)
    assert cands and cands[0].cost.feasible
    assert cands[0].placement.spec is MIXES[2]


@settings(max_examples=25, deadline=None)
@given(n_fast=st.sampled_from([4, 8, 12]), n_slow=st.sampled_from([4, 8]),
       batch=st.integers(32, 512))
def test_batch_split_property(n_fast, n_slow, batch):
    """Property over cluster shapes: shares sum to batch; the per-device
    share of the fast group is >= the slow group's (monotonicity)."""
    spec = ClusterSpec(groups=(DeviceGroup("fast", V100_PAPER, n_fast),
                               DeviceGroup("slow", T4_16G, n_slow)))
    meta = _meta(batch=batch)
    strat = StrategySpec(dp=spec.n_devices, zero=3)
    try:
        shares = balance_batch(meta, strat, spec)
    except ValueError:
        return
    assert sum(shares) == batch
    assert shares[0] / n_fast >= shares[1] / n_slow - 1

"""Segment-aware ModelGraph (PR 9): legacy byte-identity, partitions, pricing.

Four guarantees this suite freezes:

1. **Byte-identity** — for every non-multimodal shipped config,
   ``model_graph(cfg, b, s).workload_meta()`` equals the retired
   ``lm_workload_meta`` if-ladder field-for-field *exactly* (the formula
   is frozen verbatim in :func:`_legacy_meta` below, so the guarantee
   survives any future refactor of either side).
2. **Multimodal pricing** — vlm is no longer priced identically to dense
   (the frontend's prefix tokens and adapter params now cost something),
   and encdec cross-attention KV is priced (source length moves flops).
3. **Segment-respecting partitions** — stage enumeration never splits
   inside an atomic segment, and the exact min-max DP only returns valid
   partitions (hypothesis-fuzzed over random segment structures).
4. **Removal** — the two legacy derivation paths (``lm_workload_meta``,
   ``meta_from_taskgraph``) are gone for good: tombstone tests pin the
   names absent so a revert cannot silently resurrect them (the
   ``make_gpipe_*`` removal pattern from tests/test_schedule.py).
"""
import dataclasses
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_NAMES, get_config
from repro.core.auto import graph_from_taskgraph
from repro.core.cost_model import (ClusterSpec, DeviceGroup, ModelGraph,
                                   SegmentMeta, StrategySpec, T4_16G,
                                   V100_PAPER, WorkloadMeta,
                                   as_workload_meta)
from repro.core.hetero import (partition_min_max, plan_placement,
                               scale_meta_stage)
from repro.core.ir import Subgraph, TaskGraph, TensorMeta
from repro.models.lm import build, model_graph

MULTIMODAL_FAMILIES = ("vlm", "encdec")
SHAPES = ((8, 128), (256, 2048), (3, 77))


# ---------------------------------------------------------------------------
# the retired lm_workload_meta if-ladder, frozen verbatim (do not "fix")
# ---------------------------------------------------------------------------

def _legacy_meta(cfg, batch: int, seq: int,
                 act_dtype_bytes: int = 2,
                 param_dtype_bytes: int = 4) -> WorkloadMeta:
    E, V, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    T = batch * seq
    hd = cfg.hd

    def attn_flops() -> float:
        H, K = cfg.n_heads, cfg.n_kv_heads
        proj = 2 * T * E * (H * hd) + 2 * 2 * T * E * (K * hd) \
            + 2 * T * (H * hd) * E
        scores = 2 * T * seq * H * hd * 2 * 0.5          # causal half
        return proj + scores

    def dense_mlp_flops() -> float:
        mult = 3 if cfg.gated_mlp else 2
        return 2 * T * E * cfg.d_ff * mult

    def moe_mlp_flops() -> float:
        mult = 3
        routed = 2 * T * E * cfg.d_ff_expert * mult * cfg.top_k
        shared = 2 * T * E * cfg.d_ff_expert * mult * cfg.n_shared
        router = 2 * T * E * cfg.n_experts
        return routed + shared + router

    def ssd_flops() -> float:
        scfg = cfg.ssd_cfg()
        H, P, N, C = scfg.n_heads, scfg.headdim, scfg.d_state, scfg.chunk
        proj = 2 * T * E * (2 * H * P + 2 * N + H) + 2 * T * H * P * E
        intra = 2 * T * C * H * (N + P)
        inter = 2 * T * H * P * N * 2
        return proj + intra + inter

    n_attn = n_ssd = n_moe = n_dense = 0
    if cfg.family in ("dense", "vlm"):
        n_attn, n_dense = L, L
    elif cfg.family == "moe":
        n_attn = L
        n_moe = L // cfg.moe_every
        n_dense = L - n_moe
    elif cfg.family == "ssm":
        n_ssd = L
    elif cfg.family == "hybrid":
        n_attn = L // cfg.attn_period
        n_ssd = L - n_attn
        n_moe = L // 2
        n_dense = L - n_moe
    elif cfg.family == "encdec":
        n_attn = cfg.n_enc_layers + 2 * cfg.n_dec_layers
        n_dense = cfg.n_enc_layers + cfg.n_dec_layers
        L = cfg.n_enc_layers + cfg.n_dec_layers
    flops = (n_attn * attn_flops() + n_ssd * ssd_flops()
             + n_moe * moe_mlp_flops() + n_dense * dense_mlp_flops())
    head = 2 * T * E * V
    flops += head

    def attn_params():
        return E * (cfg.n_heads * hd) * 2 + E * (cfg.n_kv_heads * hd) * 2

    def mlp_params():
        return E * cfg.d_ff * (3 if cfg.gated_mlp else 2)

    def moe_params():
        return (cfg.n_experts + cfg.n_shared) * E * cfg.d_ff_expert * 3 \
            + E * cfg.n_experts

    def ssd_params():
        scfg = cfg.ssd_cfg()
        return E * scfg.d_inner * 3 + 2 * E * scfg.d_state + E * scfg.n_heads

    p_count = (n_attn * attn_params() + n_ssd * ssd_params()
               + n_moe * moe_params() + n_dense * mlp_params())
    embed = V * E * (1 if cfg.tie_embeddings else 2)
    param_bytes = (p_count + embed) * param_dtype_bytes
    tp_shardable = param_bytes * 0.98

    act_per_layer = T * E * act_dtype_bytes * 4
    logits_bytes = T * V * 4

    expert_param_bytes = 0.0
    moe_dispatch_bytes = 0.0
    if n_moe:
        expert_param_bytes = (n_moe * cfg.n_experts * E * cfg.d_ff_expert
                              * 3 * param_dtype_bytes)
        moe_dispatch_bytes = (T * cfg.top_k * cfg.capacity_factor
                              * E * act_dtype_bytes)

    return WorkloadMeta(
        name=cfg.name, fwd_flops=float(flops), param_bytes=float(param_bytes),
        tp_shardable_param_bytes=float(tp_shardable),
        act_bytes_per_layer=float(act_per_layer), n_layers=max(L, 1),
        batch=batch, logits_bytes=float(logits_bytes),
        head_param_bytes=float(E * V * param_dtype_bytes),
        n_experts=int(cfg.n_experts if n_moe else 0),
        n_moe_layers=int(n_moe),
        expert_param_bytes=float(expert_param_bytes),
        moe_dispatch_bytes=float(moe_dispatch_bytes))


# ---------------------------------------------------------------------------
# 1. byte-identity with the legacy formula (non-multimodal families)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("smoke", (False, True))
def test_flatten_matches_legacy_formula(arch, smoke):
    cfg = get_config(arch, smoke=smoke)
    if cfg.family in MULTIMODAL_FAMILIES:
        pytest.skip("multimodal pricing intentionally diverges from legacy")
    for batch, seq in SHAPES:
        got = dataclasses.asdict(model_graph(cfg, batch, seq).workload_meta())
        want = dataclasses.asdict(_legacy_meta(cfg, batch, seq))
        assert got == want, (arch, smoke, batch, seq)


def test_flatten_is_exact_not_close():
    """The identity is ``==``, not allclose: same association order."""
    cfg = get_config("grok-1-314b")
    m = model_graph(cfg, 256, 2048).workload_meta()
    legacy = _legacy_meta(cfg, 256, 2048)
    assert m.fwd_flops == legacy.fwd_flops
    assert m.param_bytes == legacy.param_bytes
    assert m.tp_shardable_param_bytes == legacy.tp_shardable_param_bytes
    assert m.expert_param_bytes == legacy.expert_param_bytes


@pytest.mark.parametrize("arch", ("tinyllama-1.1b", "qwen2-vl-2b",
                                  "seamless-m4t-medium"))
def test_model_graph_method_equals_builder(arch):
    cfg = get_config(arch, smoke=True)
    assert build(cfg).graph(4, 64) == model_graph(cfg, 4, 64)


# ---------------------------------------------------------------------------
# 2. multimodal pricing fixes
# ---------------------------------------------------------------------------

def test_vlm_not_priced_as_dense():
    """The old ladder priced vlm == dense: frontend tokens and adapter
    params cost nothing.  The graph builder prices both."""
    cfg = get_config("qwen2-vl-2b")
    twin = dataclasses.replace(cfg, family="dense", frontend=None,
                               frontend_len=0, mrope_sections=None)
    vlm = model_graph(cfg, 8, 2048).workload_meta()
    dense = model_graph(twin, 8, 2048).workload_meta()
    assert vlm.fwd_flops > dense.fwd_flops
    assert vlm.param_bytes > dense.param_bytes
    assert vlm.n_layers == dense.n_layers + 1      # the frontend segment
    # ... and the dense twin still matches the legacy formula exactly
    assert dataclasses.asdict(dense) == dataclasses.asdict(
        _legacy_meta(twin, 8, 2048))


def test_vlm_graph_has_atomic_frontend():
    g = model_graph(get_config("qwen2-vl-2b"), 8, 2048)
    assert [s.name for s in g.segments] == ["vision-frontend", "decoder"]
    assert g.segments[0].atomic
    assert g.segments[0].param_bytes > 0
    assert g.segments[0].fwd_flops > 0


def test_encdec_cross_attention_kv_priced():
    """Cross-attention reads the source memory: a longer source must cost
    decoder flops, not just encoder flops."""
    cfg = get_config("seamless-m4t-medium")
    short = model_graph(cfg, 8, 256, src_seq=64)
    long = model_graph(cfg, 8, 256, src_seq=512)
    dec_short = short.segments[-1]
    dec_long = long.segments[-1]
    assert dec_long.fwd_flops > dec_short.fwd_flops
    assert long.workload_meta().fwd_flops > short.workload_meta().fwd_flops


def test_encdec_towers_priced_differently():
    """Decoder layers (self-attn + cross-attn + mlp) must cost more than
    encoder layers (self-attn + mlp) per layer — the whole reason the
    two-tower split needs segment-aware balancing."""
    g = model_graph(get_config("seamless-m4t-medium"), 8, 256)
    segs = {s.name: s for s in g.segments}
    enc, dec = segs["encoder"], segs["decoder"]
    assert (dec.fwd_flops / dec.n_layers) > (enc.fwd_flops / enc.n_layers)
    assert (dec.param_bytes / dec.n_layers) > (enc.param_bytes / enc.n_layers)


def test_encdec_graph_structure():
    g = model_graph(get_config("seamless-m4t-medium"), 8, 256)
    assert [s.name for s in g.segments] == [
        "audio-frontend", "encoder", "decoder"]
    assert g.segments[0].atomic
    assert g.boundaries() == (0, 1, 13, 25)


# ---------------------------------------------------------------------------
# 3. segment-respecting partitions
# ---------------------------------------------------------------------------

def _synthetic_graph(seg_shapes):
    """seg_shapes: [(n_layers, atomic), ...] → a ModelGraph with unit-ish
    per-layer costs (distinct per segment so balancing is non-trivial)."""
    segs = tuple(
        SegmentMeta(name=f"s{i}", n_layers=n, fwd_flops=float(n * (i + 1)),
                    param_bytes=float(n * 8), act_bytes_per_layer=4.0,
                    atomic=atomic)
        for i, (n, atomic) in enumerate(seg_shapes))
    return ModelGraph(name="synth", segments=segs, batch=4)


def test_valid_span_never_cuts_inside_atomic():
    g = _synthetic_graph([(4, True), (4, False)])
    assert not g.valid_span(0, 2)          # cuts the atomic tower
    assert not g.valid_span(2, 6)          # enters it partway
    assert g.valid_span(0, 4)              # covers it whole
    assert g.valid_span(0, 5)              # whole + spill into next
    assert g.valid_span(4, 6)              # entirely outside
    assert g.valid_span(5, 7)              # non-atomic splits freely


def test_valid_partition_respects_atomic_edges():
    g = _synthetic_graph([(4, True), (4, False)])
    assert g.valid_partition([4, 4])
    assert g.valid_partition([5, 3])
    assert not g.valid_partition([2, 6])
    assert not g.valid_partition([3, 5])
    assert not g.valid_partition([4, 3])   # wrong total
    assert g.valid_partition([8])          # one stage covering everything
    assert g.valid_partition([4, 2, 2])


def test_feasible_pp_counts_atomic_as_one_unit():
    g = _synthetic_graph([(4, True), (4, False)])
    # the atomic 4-layer tower is one unit: at most 1 + 4 stages
    assert g.feasible_pp(1)
    assert g.feasible_pp(5)
    assert not g.feasible_pp(6)
    assert not g.feasible_pp(9)            # more stages than layers
    g2 = _synthetic_graph([(4, False), (4, False)])
    assert g2.feasible_pp(8)


def test_partition_min_max_exact_on_known_case():
    # two segments, second 2x the per-layer cost: [6, 6] layers with
    # costs 1 and 2 → the even [6, 6] split costs max(6, 12) = 12;
    # the exact DP must find [8, 4] = max(8+... ) — compute directly
    g = _synthetic_graph([(6, False), (6, False)])
    costs = g.layer_costs()

    def span_cost(_i, lo, hi):
        return sum(costs[lo:hi])

    counts = partition_min_max(g, 2, span_cost)
    assert counts is not None and sum(counts) == 12
    best = min(max(span_cost(0, 0, k), span_cost(1, k, 12))
               for k in range(1, 12))
    lo = counts[0]
    assert max(span_cost(0, 0, lo), span_cost(1, lo, 12)) == best


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 6), st.booleans()),
                min_size=1, max_size=5),
       st.integers(1, 8))
def test_partition_min_max_only_returns_valid_partitions(seg_shapes, pp):
    """Fuzz: whatever the segment structure, the DP either proves
    infeasibility (None, agreeing with feasible_pp) or returns a
    partition that never splits an atomic segment."""
    g = _synthetic_graph(seg_shapes)
    costs = g.layer_costs()

    def span_cost(_i, lo, hi):
        return sum(costs[lo:hi])

    counts = partition_min_max(g, pp, span_cost)
    if pp > g.n_layers or not g.feasible_pp(pp):
        assert counts is None
    else:
        assert counts is not None
        assert len(counts) == pp
        assert g.valid_partition(counts)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 6), st.booleans()),
                min_size=1, max_size=5))
def test_boundaries_and_spans_consistent(seg_shapes):
    g = _synthetic_graph(seg_shapes)
    b = g.boundaries()
    assert b[0] == 0 and b[-1] == g.n_layers
    assert list(b) == sorted(b)
    spans = g.segment_spans()
    assert len(spans) == len(g.segments)
    for (s0, s1), seg in zip(spans, g.segments):
        assert s1 - s0 == seg.n_layers
    assert len(g.layer_costs()) == g.n_layers


# ---------------------------------------------------------------------------
# stage_meta: per-stage slicing consistency
# ---------------------------------------------------------------------------

def test_stage_meta_slices_sum_to_flattened_totals():
    g = model_graph(get_config("seamless-m4t-medium"), 8, 256)
    total = g.workload_meta()
    for counts in ([1, 12, 12], [13, 12], [5, 10, 10]):
        assert g.valid_partition(counts)
        pp = len(counts)
        lo = 0
        flops = pbytes = 0.0
        for n in counts:
            sm = g.stage_meta(lo, lo + n, pp)
            flops += sm.fwd_flops / pp       # undo the ·pp convention
            pbytes += sm.param_bytes / pp
            lo += n
        assert math.isclose(flops, total.fwd_flops, rel_tol=1e-12)
        assert math.isclose(pbytes, total.param_bytes, rel_tol=1e-12)


def test_stage_meta_reduces_to_scale_meta_stage_on_single_segment():
    """On a layer-homogeneous graph the per-segment slicer must be the
    legacy uniform slicer, byte-for-byte."""
    cfg = get_config("tinyllama-1.1b")
    g = model_graph(cfg, 64, 512)
    assert len(g.segments) == 1
    flat = g.workload_meta()
    L, pp = g.n_layers, 4
    lo = 0
    for n in (L // 2, L // 4, L - L // 2 - L // 4):
        got = g.stage_meta(lo, lo + n, pp)
        want = scale_meta_stage(flat, n, pp)
        for f in dataclasses.fields(WorkloadMeta):
            if f.name == "name":
                continue
            gv, wv = getattr(got, f.name), getattr(want, f.name)
            assert math.isclose(gv, wv, rel_tol=1e-12, abs_tol=1e-12), \
                (f.name, gv, wv)
        lo += n


# ---------------------------------------------------------------------------
# balanced placement from per-segment costs
# ---------------------------------------------------------------------------

MIXED = ClusterSpec(groups=(DeviceGroup("v100", V100_PAPER, 8),
                            DeviceGroup("t4", T4_16G, 8)))


@pytest.mark.parametrize("arch,batch,seq", (
    ("seamless-m4t-medium", 128, 256),
    ("qwen2-vl-2b", 64, 1024),
))
def test_balanced_stage_allocation_never_worse_than_even(arch, batch, seq):
    g = model_graph(get_config(arch), batch, seq)
    strat = StrategySpec(dp=4, pp=4, micro_batches=8)
    even = plan_placement(g, strat, MIXED, overlap=0.5, balanced=False)
    bal = plan_placement(g, strat, MIXED, overlap=0.5)
    assert bal.step_time <= even.step_time + 1e-9
    # the balancer's partition must itself be segment-respecting
    layers = [u.layers for u in bal.units if u.kind == "stage"]
    if layers:
        assert g.valid_partition(layers)


def test_as_workload_meta_passthrough_and_flatten():
    g = model_graph(get_config("tinyllama-1.1b"), 8, 128)
    flat = g.workload_meta()
    assert as_workload_meta(g) == flat
    assert as_workload_meta(flat) is flat


# ---------------------------------------------------------------------------
# 4. tombstones: the legacy derivation paths are gone for good
# ---------------------------------------------------------------------------

def test_legacy_meta_shims_removed():
    """The PR 9 DeprecationWarning shims were deleted — the graph builders
    are the only derivation path.  A revert that resurrects the old names
    must fail here (same pattern as the make_gpipe_* tombstones in
    tests/test_schedule.py)."""
    import repro.core as core
    from repro.core import auto, cost_model
    assert not hasattr(cost_model, "lm_workload_meta")
    assert not hasattr(auto, "meta_from_taskgraph")
    assert not hasattr(core, "lm_workload_meta")
    assert not hasattr(core, "meta_from_taskgraph")


def _toy_taskgraph() -> TaskGraph:
    import jax.numpy as jnp
    tg = TaskGraph()
    for i in range(5):
        tg.add(Subgraph(name=f"l{i}", fn=None, strategy=[],
                        params=[TensorMeta((64, 64), jnp.float32)],
                        outputs=[TensorMeta((8, 64), jnp.float32)]))
    tg.add(Subgraph(name="head", fn=None, strategy=[],
                    params=[TensorMeta((64, 1000), jnp.float32)],
                    outputs=[TensorMeta((8, 1000), jnp.float32)]))
    return tg


def test_graph_from_taskgraph_clusters_repeats():
    # repeated substructure clusters → segments (the structural assertion
    # the retired meta_from_taskgraph test carried)
    g = graph_from_taskgraph(_toy_taskgraph(), 8)
    assert len(g.segments) == 2
    assert g.segments[0].n_layers == 5
    assert g.workload_meta().batch == 8

"""Paper Case 2 / §3.2 — large-scale classification with DP + operator split.

A ResNet-style feature extractor is replicated (data parallel) while the
100,000-class FC + softmax head is sharded over the `model` axis — the
hybrid that gave Whale its 14.8× over pure DP (Fig 5).  Here the backbone is
an MLP stand-in (the paper's point is the *strategy*, not the conv stack)
and the class count is scaled to CPU.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/classification_split.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro as wh

N_CLASSES = 10_000
D_FEAT = 256
BATCH = 32


def backbone(params, x):
    h = x
    for w in params["layers"]:
        h = jax.nn.relu(h @ w)
    return h


def fc_head(params, feats):
    return feats @ params["w"]                 # (B, N_CLASSES)


def loss_fn(params, x, labels):
    # Case 2: replica around the backbone, split around the head.
    with wh.replica():
        feats = wh.sub("backbone", backbone)(params["backbone"], x)
    with wh.split(dim=-1):
        logits = wh.sub("fc", fc_head)(params["head"], feats)
    # vocab-split-safe cross entropy (max/sumexp stay sharded; see lm.py)
    logits = logits.astype(jnp.float32)
    m = logits.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[:, 0]
    correct = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - correct).mean()


def main():
    n = len(jax.devices())
    model_par = min(4, n)
    data_par = n // model_par
    key = jax.random.key(0)
    params = {
        "backbone": {"layers": [
            jax.random.normal(key, (D_FEAT, D_FEAT)) * 0.05 for _ in range(4)]},
        "head": {"w": jax.random.normal(key, (D_FEAT, N_CLASSES)) * 0.05},
    }
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(BATCH, D_FEAT)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, N_CLASSES, BATCH), jnp.int32)

    cluster = wh.cluster(mesh_shape=(data_par, model_par),
                         axis_names=("data", "model"))
    with cluster:
        loss = loss_fn(params, x, labels)          # records the TaskGraph
    strat = wh.strategy_from_taskgraph(cluster)
    print(f"[case 2] inferred strategy: {strat.describe()}")

    # grads under the hybrid sharding (jit; GSPMD inserts the collectives)
    with cluster.mesh:
        def wrapped(p, x, y):
            with cluster:
                return loss_fn(p, x, y)
        gfn = jax.jit(jax.value_and_grad(wrapped))
        for i in range(5):
            loss, grads = gfn(params, x, labels)
            params = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
            print(f"  step {i} loss {float(loss):.4f}")

    # cost-model comparison at the paper's scale: DP vs DP+split on 64 GPUs
    # (the fig5 benchmark does this properly — here just the headline)
    from repro.core.cost_model import (V100_PAPER, ModelGraph, SegmentMeta,
                                       StrategySpec, step_cost)
    meta = ModelGraph(
        name="resnet50-100k",
        segments=(SegmentMeta(name="resnet50", n_layers=50,
                              fwd_flops=2 * 4e9 * 256,
                              param_bytes=90e6 * 4,
                              act_bytes_per_layer=256 * 2048 * 4),),
        batch=256, extra_param_bytes=782e6 * 4,
        logits_bytes=256 * 100_000 * 4, head_param_bytes=782e6 * 4,
        tp_shardable_fraction=782e6 / (90e6 + 782e6)).workload_meta()
    dp = step_cost(meta, StrategySpec(dp=64, vocab_split=False), V100_PAPER)
    hy = step_cost(meta, StrategySpec(dp=16, tp=4, vocab_split=True), V100_PAPER)
    print(f"[fig5 headline] 64-GPU DP: {dp.total*1e3:.0f} ms/step; "
          f"DP×split: {hy.total*1e3:.0f} ms/step; "
          f"speedup {dp.total/hy.total:.1f}×")
    print("classification_split OK")


if __name__ == "__main__":
    main()

"""Paper Case 5 — automatic parallel strategy via the meta-driven cost model.

One call ranks the pruned strategy space for each assigned architecture on a
256-chip pod and prints the frontier — no lowering, no execution (the
"meta-driven, not dry-run" methodology of §2).

    PYTHONPATH=src python examples/auto_parallel.py [--devices 256]
"""
import argparse

import repro as wh
from repro.configs import ARCH_NAMES, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    args = ap.parse_args()

    print(f"auto_parallel over {args.devices} TPU v5e chips, "
          f"batch {args.batch} × seq {args.seq}\n")
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        if cfg.family == "encdec":
            seq = min(args.seq, 1024)          # enc-dec: source length
        else:
            seq = args.seq
        meta = wh.model_graph(cfg, args.batch, seq).workload_meta()
        cands = wh.search(meta, args.devices, wh.TPU_V5E, top_k=3)
        if not cands:
            print(f"{arch:24s} NO feasible strategy")
            continue
        best = cands[0]
        print(f"{arch:24s} {best.strategy.describe():44s} "
              f"{best.total*1e3:9.1f} ms/step  "
              f"mem {best.cost.mem_bytes/2**30:5.1f} GiB")
        for c in cands[1:]:
            print(f"{'':24s} {c.strategy.describe():44s} "
                  f"{c.total*1e3:9.1f} ms/step  "
                  f"mem {c.cost.mem_bytes/2**30:5.1f} GiB")
        print()


if __name__ == "__main__":
    main()

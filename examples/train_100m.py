"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps through the full production stack (planner, data pipeline,
fault-tolerant loop, async checkpoints).

By default this runs a reduced step count sized for CPU; pass --steps 300
for the full run.  The config is tinyllama shrunk to ~100M params (d_model
768, 12 layers, 8 heads, vocab 32000 — GPT-2-small-ish).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    overrides = ("n_layers=12,d_model=768,n_heads=12,n_kv_heads=4,"
                 "head_dim=64,d_ff=2048,vocab=32000,loss_chunk=256,"
                 "name=llama-100m")
    argv = ["--arch", "tinyllama-1.1b", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--overrides", overrides, "--ckpt-dir", args.ckpt_dir,
            "--save-every", "100", "--log-every", "10", "--lr", "3e-4"]
    if args.mesh:
        argv += ["--mesh", args.mesh]
    out = train_main(argv)
    losses = out["losses"]
    drop = losses[0] - losses[-1]
    print(f"[train_100m] loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"(Δ {drop:.3f} over {len(losses)} steps)")
    assert drop > 0.3, "expected meaningful loss reduction"
    print("train_100m OK")


if __name__ == "__main__":
    main()

"""Paper Case 4 / §3.1 — Bert-style training with pipeline × data parallel.

24 encoder layers are evenly partitioned into pipeline stages (the paper
used 3 stages over 24 layers; we use a CPU-sized bert-like config), stages
shard over a `stage` mesh axis, micro-batches flow with ppermute, and the
whole pipeline is replicated over the `data` axis — exactly Case 4:

    with wh.cluster():
      with wh.replica():
        with wh.pipeline(micro_batch=4):
          with wh.stage(): ...

Here the scopes configure the engine, and the executable schedule comes
from repro.core.pipeline (GPipe via shard_map + ppermute; DESIGN.md §2).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/bert_pipeline.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro as wh
import repro.core.pipeline as pipe
from repro.configs import get_config
from repro.models.lm import build, model_graph
from repro.optim import adamw

MICRO = 4


def main():
    n = len(jax.devices())
    stages = 2 if n >= 2 else 1
    data_par = max(n // (stages * 2), 1)
    model_par = n // (stages * data_par)

    # bert-like: 4 layers (stands in for 24), gelu, LN — smoke-sized
    cfg = dataclasses.replace(
        get_config("stablelm-3b", smoke=True),
        n_layers=4, norm="ln", act="gelu", name="bert-like")
    model = build(cfg)

    mesh = jax.make_mesh((stages, data_par, model_par),
                         ("stage", "data", "model"))
    rules = wh.hybrid_rules(mesh)
    opt = adamw(lr=1e-3)

    # --- Case 4 scopes record the strategy into the IR ---
    with wh.cluster(mesh=mesh) as cl:
        with wh.replica():
            with wh.pipeline(micro_batch=MICRO):
                with wh.stage():
                    pass   # stage boundaries; executable schedule below
                with wh.stage():
                    pass
    strat = wh.strategy_from_taskgraph(cl)
    print(f"[case 4] mesh {dict(mesh.shape)} strategy {strat.describe()}")

    # --- executable pipelined train step (pick a schedule; uneven
    #     stage_layers also welcome here — see DESIGN.md §5) ---
    step = pipe.make_pipeline_train_step(model, mesh, rules, opt,
                                         micro_batches=MICRO,
                                         schedule="gpipe", donate=False)
    pspecs = pipe.staged_specs(rules, model.axes(), model.param_shapes())
    psh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda t: isinstance(t, jax.sharding.PartitionSpec))
    with mesh:
        params = jax.jit(model.init, out_shardings=psh)(jax.random.key(0))
        opt_state = opt.init(params)
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (8, 128)),
            jnp.int32)
        losses = []
        for i in range(6):
            params, opt_state, loss = step(params, opt_state, tokens, i)
            losses.append(float(loss))
            print(f"  step {i} pipeline loss {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "pipeline training must reduce loss"

    # --- the paper's Fig-2 headline from the cost model (64 V100s) ---
    from repro.core.cost_model import (V100_PAPER, StrategySpec,
                                       step_cost)
    bert = dataclasses.replace(get_config("stablelm-3b"), n_layers=24,
                               d_model=1024, n_heads=16, n_kv_heads=16,
                               d_ff=4096, vocab=30522, name="bert-large")
    meta = model_graph(bert, 512, 128).workload_meta()
    hdp = step_cost(meta, StrategySpec(dp=64, zero=0, remat=False,
                                       vocab_split=False), V100_PAPER,
                    overlap=0.0)            # Horovod: no overlap with bwd
    whale = step_cost(meta, StrategySpec(dp=16, pp=4, micro_batches=8,
                                         remat=False, vocab_split=False),
                      V100_PAPER, overlap=0.5)
    print(f"[fig2 headline] 64-GPU HDP {hdp.total*1e3:.0f} ms/step vs "
          f"Whale pipeline {whale.total*1e3:.0f} ms/step → "
          f"{hdp.total/whale.total:.2f}×")
    print("bert_pipeline OK")


if __name__ == "__main__":
    main()

"""Quickstart — the paper's Case 1 (pure data parallelism) plus the engine.

Runs on however many devices exist (set XLA_FLAGS for virtual CPUs)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro as wh
from repro.configs import get_config
from repro.models.lm import build
from repro.optim import adamw

# ---- Case 1: replica scope around an arbitrary model fn -------------------
# wh.cluster owns the device mesh; wh.replica() marks the enclosed subgraph
# for data parallelism; wh.sub records it in the Whale IR.


def tiny_net(params, x):
    h = jax.nn.relu(x @ params["w1"])
    return h @ params["w2"]


key = jax.random.key(0)
params = {"w1": jax.random.normal(key, (32, 64)) * 0.1,
          "w2": jax.random.normal(key, (64, 8)) * 0.1}
x = jax.random.normal(key, (16, 32))

with wh.cluster() as cl:                       # mesh over all devices
    with wh.replica():
        out = wh.sub("net", tiny_net)(params, x)
print(f"[case 1] out {out.shape}; recorded "
      f"{len(cl.taskgraph.nodes)} subgraph(s): "
      f"{[n.name for n in cl.taskgraph.nodes]}, "
      f"flops={cl.taskgraph.nodes[0].flops:,}")

# ---- the engine on a real architecture -------------------------------------
cfg = get_config("tinyllama-1.1b", smoke=True)
model = build(cfg)
n_dev = len(jax.devices())
mesh = jax.make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else \
    jax.make_mesh((1,), ("data",))
plan = wh.compile_plan(model, mesh)

opt = adamw(lr=1e-3)
batch = {"tokens": jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab, (8, 128)), jnp.int32)}
with mesh:
    params = plan.init_params(jax.random.key(0))
    opt_state = jax.jit(opt.init)(params)
    step = plan.jit_train_step(opt, batch, donate=False)
    for i in range(5):
        params, opt_state, m = step(params, opt_state, batch, i)
        print(f"[engine] step {i} loss {float(m['loss']):.4f}")
print("quickstart OK")

"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Sharding strategy (selected implicitly by the Multi-Dimension rules +
divisibility pruning, no model-code branches):

- *Expert parallelism* (EP): when n_experts divides the model axis
  (deepseek-moe 64/16, jamba 16/16) the `experts` dim of both the dispatch
  buffers and the expert weights shards over `model`; dispatch is comm-free
  because activations are model-replicated under the hybrid strategy, and the
  combine lowers to one (B, S, D) all-reduce — the same bytes as a Megatron
  TP MLP.
- *Expert tensor parallelism*: when it doesn't (grok-1: 8 experts on a 16-way
  axis) the `experts` dim prunes and the `expert_mlp` (d_ff) dim takes the
  model axis instead — every shard holds a 1/16 slice of every expert and the
  combine is the standard row-parallel partial-sum all-reduce.

Dispatch is sort-based (argsort over token→expert assignments, rank-in-expert
capacity cutoff) rather than one-hot-einsum based, so no (B, S, E, C) tensor
is ever materialised — the buffers are O(B · E · C · D).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    act: str = "silu"
    router_z_coef: float = 1e-3
    lb_coef: float = 1e-2

    def capacity(self, seq_len: int) -> int:
        c = int(seq_len * self.top_k * self.capacity_factor / self.n_experts) + 1
        return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def init_moe(key, cfg: MoECfg, dtype) -> dict:
    kr, k1, kg, k2, ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": {"w": layers.dense_init(kr, D, (D, E), jnp.float32)},
        "w_in": layers.dense_init(k1, D, (E, D, F), dtype),
        "w_gate": layers.dense_init(kg, D, (E, D, F), dtype),
        "w_out": layers.dense_init(k2, F, (E, F, D), dtype),
    }
    if cfg.n_shared:
        p["shared"] = layers.init_mlp(ks, D, F * cfg.n_shared, dtype, gated=True)
    return p


def axes_moe(cfg: MoECfg) -> dict:
    a = {
        "router": {"w": ("embed", None)},           # router stays replicated
        "w_in": ("experts", "embed", "expert_mlp"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_out": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared:
        a["shared"] = layers.axes_mlp(gated=True)
    return a


def _dispatch_indices(expert_idx: jax.Array, weights: jax.Array, E: int, C: int,
                      seq_len: int):
    """expert_idx/weights: (B, S, k) → per-slot token indices + weights.

    Returns tok (B, E, C) int32 in [0, S] (S = dropped) and w (B, E, C) f32.
    """
    B, S, k = expert_idx.shape
    T = S * k
    flat_e = expert_idx.reshape(B, T)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # rank of each assignment within its expert = i - first index of expert
    start = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(sorted_e)
    rank = jnp.arange(T)[None, :] - start
    valid = rank < C
    slot = jnp.where(valid, sorted_e * C + rank, E * C)   # E*C = dropped sentinel
    tok_sorted = order // k
    w_sorted = jnp.take_along_axis(weights.reshape(B, T), order, axis=-1)

    tok = jnp.full((B, E * C), seq_len, jnp.int32)
    tok = jax.vmap(lambda t, s, v: t.at[s].set(v, mode="drop"))(tok, slot, tok_sorted)
    wbuf = jnp.zeros((B, E * C), jnp.float32)
    wbuf = jax.vmap(lambda t, s, v: t.at[s].set(v, mode="drop"))(wbuf, slot, w_sorted)
    return tok.reshape(B, E, C), wbuf.reshape(B, E, C)


def _route(params: dict, x: jax.Array, cfg: MoECfg):
    """Per-token routing (f32): (logits, normalized top-k weights, expert
    ids, per-batch mean prob `me`, per-batch assignment fraction `ce`).
    The means are over the *local* batch — callers running batch-sharded
    (moe_block_ep) pmean them to the global mean."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    w_topk, e_idx = jax.lax.top_k(probs, cfg.top_k)                # (B, S, k)
    w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=(0, 1))                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(e_idx, cfg.n_experts, dtype=jnp.float32),
                  axis=(0, 1, 2))
    return logits, w_topk, e_idx, me, ce


def _aux_losses(cfg: MoECfg, me, ce, mean_sq_lse):
    """Load balance (GShard-style) + router z-loss from routing stats."""
    lb_loss = cfg.lb_coef * cfg.n_experts * jnp.sum(me * ce)
    z_loss = cfg.router_z_coef * mean_sq_lse
    return lb_loss, z_loss


def _expert_ffn(params: dict, xin: jax.Array, cfg: MoECfg, dtype):
    """SwiGLU over per-expert capacity buffers: (..., E', C, D) →
    (..., E', C, D) with the experts dim of the weights matching E'."""
    h = jnp.einsum("becd,edf->becf", xin, params["w_in"].astype(dtype))
    g = jnp.einsum("becd,edf->becf", xin, params["w_gate"].astype(dtype))
    h = layers._ACTS[cfg.act](g) * h
    h = constrain(h, ("batch", "experts", None, "expert_mlp"))
    return jnp.einsum("becf,efd->becd", h, params["w_out"].astype(dtype))


def _combine(tok: jax.Array, out: jax.Array, seq_len: int) -> jax.Array:
    """Weighted capacity buffers (B, E, C, D) → (B, S, D) scatter-add."""
    B = out.shape[0]
    D = out.shape[-1]
    y = jnp.zeros((B, seq_len, D), out.dtype)
    return jax.vmap(
        lambda yb, tb, ub: yb.at[tb.reshape(-1)].add(
            ub.reshape(-1, D), mode="drop")
    )(y, tok, out)


def moe_block(params: dict, x: jax.Array, cfg: MoECfg):
    """x: (B, S, D) → (B, S, D), aux-loss dict."""
    B, S, D = x.shape
    E = cfg.n_experts
    C = cfg.capacity(S)

    # --- routing (f32; replicated over the model axis) ---
    logits, w_topk, e_idx, me, ce = _route(params, x, cfg)
    lb_loss, z_loss = _aux_losses(cfg, me, ce, jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1))))

    tok, w = _dispatch_indices(e_idx, w_topk, E, C, S)             # (B, E, C)
    tok = constrain(tok, ("batch", "experts", None))
    w = constrain(w, ("batch", "experts", None))
    tok_safe = jnp.minimum(tok, S - 1)

    # --- dispatch gather: (B, E, C, D); sharded batch × experts ---
    xin = jax.vmap(lambda xb, tb: xb[tb])(x, tok_safe)
    xin = constrain(xin, ("batch", "experts", None, None))

    # --- expert FFN (SwiGLU) ---
    out = _expert_ffn(params, xin, cfg, x.dtype)
    out = out * w[..., None].astype(out.dtype)
    out = constrain(out, ("batch", "experts", None, None))

    # --- combine scatter-add back to (B, S, D) (partial sums → all-reduce) ---
    y = _combine(tok, out, S)
    y = constrain(y, ("batch", None, None))

    if cfg.n_shared:
        y = y + layers.mlp(params["shared"], x, act=cfg.act)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "expert_load": jax.lax.stop_gradient(ce)}
    return y, aux


# ---------------------------------------------------------------------------
# explicit expert parallelism: the nested replica{split[experts]} executor
# ---------------------------------------------------------------------------

def moe_block_ep(params: dict, x: jax.Array, cfg: MoECfg, mesh, *,
                 axis: str = "expert"):
    """Expert-parallel `moe_block` via an explicit ``shard_map``.

    The graph optimizer's ``replica{split[experts]}`` lowering made
    concrete (graph_opt.plan_bridge's ``all_to_all`` bridges as real
    collectives): the batch shards over the ``axis`` mesh axis, expert
    weights shard their leading ``experts`` dim over the same axis, and
    dispatch/combine are ``jax.lax.all_to_all`` exchanges —

    - *dispatch*: each shard routes its local tokens into per-expert
      capacity buffers, then all-to-all regroups them so shard ``e`` holds
      **every** batch shard's tokens for **its** experts
      ((B/ep, E, C, D) → (B, E/ep, C, D));
    - *combine*: the reverse all-to-all returns expert outputs to their
      home batch shard, where the weighted scatter-add rebuilds (B/ep, S, D).

    Routing (and its aux losses, ``pmean``-ed to the global batch mean) is
    per-token, and the reference's capacity cutoff is per (batch-row,
    expert) — batch sharding therefore commutes with dispatch and the
    result equals single-device :func:`moe_block` to fp32 tolerance
    (asserted by tests/test_distributed.py), forward *and* backward: the
    all-to-all is its own autodiff transpose, and replicated-in params
    (the router) get their gradient ``psum`` from the shard_map transpose.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.jax_compat import shard_map

    ep = mesh.shape[axis]
    B = x.shape[0]
    E = cfg.n_experts
    if E % ep:
        raise ValueError(
            f"expert parallelism needs n_experts % ep == 0; "
            f"got E={E} over {ep}-way axis {axis!r}")
    if B % ep:
        raise ValueError(
            f"expert parallelism shards the batch over {axis!r}: "
            f"batch {B} % ep {ep} != 0")

    def body(p, xl):
        S = xl.shape[1]
        C = cfg.capacity(S)

        # routing on the local batch shard; aux stats pmean to the global
        # batch mean (routing is per-token, so sharding commutes)
        logits, w_topk, e_idx, me, ce = _route(p, xl, cfg)
        me = jax.lax.pmean(me, axis)
        ce = jax.lax.pmean(ce, axis)
        lb_loss, z_loss = _aux_losses(cfg, me, ce, jax.lax.pmean(jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))), axis))

        tok, w = _dispatch_indices(e_idx, w_topk, E, C, S)
        tok_safe = jnp.minimum(tok, S - 1)
        xin = jax.vmap(lambda xb, tb: xb[tb])(xl, tok_safe)   # (Bl, E, C, D)

        # dispatch bridge: shard e receives every batch shard's tokens for
        # its own E/ep experts
        xg = jax.lax.all_to_all(xin, axis, split_axis=1, concat_axis=0,
                                tiled=True)                   # (B, E/ep, C, D)
        out = _expert_ffn(p, xg, cfg, xl.dtype)

        # combine bridge: expert outputs return to their home batch shard
        out = jax.lax.all_to_all(out, axis, split_axis=0, concat_axis=1,
                                 tiled=True)                  # (Bl, E, C, D)
        out = out * w[..., None].astype(out.dtype)
        y = _combine(tok, out, S)

        if cfg.n_shared:
            y = y + layers.mlp(p["shared"], xl, act=cfg.act)
        aux = {"lb_loss": lb_loss, "z_loss": z_loss,
               "expert_load": jax.lax.stop_gradient(ce)}
        return y, aux

    pspec = {
        "router": {"w": P()},
        "w_in": P(axis),            # experts is the leading weight dim
        "w_gate": P(axis),
        "w_out": P(axis),
    }
    if "shared" in params:
        pspec["shared"] = jax.tree.map(lambda _: P(), params["shared"])
    aux_spec = {"lb_loss": P(), "z_loss": P(), "expert_load": P()}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(pspec, P(axis)),
                   out_specs=(P(axis), aux_spec))
    return fn(params, x)

"""STUB modality frontends (per assignment: the transformer backbone is the
deliverable; vision/audio towers provide *precomputed* embeddings).

``input_specs`` supplies (B, P, D) patch embeddings (qwen2-vl) or
(B, S_src, D) frame embeddings (seamless).  The stub applies one trainable
linear adapter so the frontend participates in the parameter/sharding story
without pretending to be a real ViT/conformer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_adapter(key, d_model: int, dtype) -> dict:
    return {"w": layers.dense_init(key, d_model, (d_model, d_model), dtype),
            "b": jnp.zeros((d_model,), dtype)}


def axes_adapter() -> dict:
    return {"w": ("embed", None), "b": (None,)}


def adapt(params: dict, embeds: jax.Array) -> jax.Array:
    return embeds @ params["w"].astype(embeds.dtype) + params["b"].astype(embeds.dtype)


def mrope_positions(batch: int, seq: int, n_patches: int, grid: int | None = None
                    ) -> jax.Array:
    """qwen2-vl style (B, 3, S) positions: (t, h, w) grid over the patch
    prefix, then text positions continuing from the max patch position."""
    if n_patches == 0:
        p = jnp.broadcast_to(jnp.arange(seq)[None, None], (batch, 3, seq))
        return p
    g = grid or max(int(n_patches ** 0.5), 1)
    idx = jnp.arange(n_patches)
    t = jnp.zeros_like(idx)
    h = idx // g
    w = idx % g
    text = jnp.arange(seq - n_patches) + (n_patches // g)  # continue after max(h,w)
    pos3 = jnp.stack([
        jnp.concatenate([t, text]),
        jnp.concatenate([h, text]),
        jnp.concatenate([w, text]),
    ])                                                      # (3, S)
    return jnp.broadcast_to(pos3[None], (batch, 3, seq))

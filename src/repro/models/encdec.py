"""Encoder–decoder backbone (seamless-m4t style) on the shared primitives.

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, D) supplied by ``input_specs``.
Both towers scan over stacked layers; the decoder adds cross-attention whose
K/V are computed once from encoder memory (cached for decode).

RoPE is used for positional encoding in both towers (deviation from the
original sinusoidal/relative scheme; positional flavour is irrelevant to the
distribution work — noted in DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import layers
from repro.models.attention import AttnCfg


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    d_model: int
    n_enc_layers: int
    n_dec_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    norm: str = "ln"
    act: str = "relu"
    gated_mlp: bool = False
    rope_theta: float = 10000.0
    remat: str = "full"
    scan: bool = True
    attn_block_q: int = 512
    attn_block_k: int = 512

    def attn_cfg(self, causal: bool) -> AttnCfg:
        return AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                       n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
                       causal=causal, rope_theta=self.rope_theta)


def _init_layer(key, cfg: EncDecCfg, dtype, cross: bool) -> dict:
    ks, kc, kf = jax.random.split(key, 3)
    norm_init, _, _ = layers.make_norm(cfg.norm)
    p = {
        "norm1": norm_init(cfg.d_model, dtype),
        "self_attn": attn_mod.init_attention(ks, cfg.attn_cfg(cross), dtype),
        "norm3": norm_init(cfg.d_model, dtype),
        "mlp": layers.init_mlp(kf, cfg.d_model, cfg.d_ff, dtype,
                               gated=cfg.gated_mlp),
    }
    if cross:
        p["norm2"] = norm_init(cfg.d_model, dtype)
        p["cross_attn"] = attn_mod.init_attention(kc, cfg.attn_cfg(False), dtype)
    return p


def _axes_layer(cfg: EncDecCfg, cross: bool) -> dict:
    _, norm_axes, _ = layers.make_norm(cfg.norm)
    a = {
        "norm1": norm_axes(),
        "self_attn": attn_mod.axes_attention(cfg.attn_cfg(cross)),
        "norm3": norm_axes(),
        "mlp": layers.axes_mlp(gated=cfg.gated_mlp),
    }
    if cross:
        a["norm2"] = norm_axes()
        a["cross_attn"] = attn_mod.axes_attention(cfg.attn_cfg(False))
    return a


def init_encdec(key, cfg: EncDecCfg, dtype) -> dict:
    ke, kd = jax.random.split(key)
    enc_keys = jax.random.split(ke, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_dec_layers)
    return {
        "encoder": jax.vmap(lambda k: _init_layer(k, cfg, dtype, False))(enc_keys),
        "decoder": jax.vmap(lambda k: _init_layer(k, cfg, dtype, True))(dec_keys),
    }


def axes_encdec(cfg: EncDecCfg) -> dict:
    stackify = lambda ax: jax.tree.map(lambda t: ("layers",) + t, ax,
                                       is_leaf=lambda t: isinstance(t, tuple))
    return {"encoder": stackify(_axes_layer(cfg, False)),
            "decoder": stackify(_axes_layer(cfg, True))}


def _remat(fn, mode):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def encode(params: dict, frames: jax.Array, cfg: EncDecCfg) -> jax.Array:
    """frames: (B, S_src, D) precomputed frame embeddings → memory."""
    _, _, norm = layers.make_norm(cfg.norm)
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, lp):
        h = norm(lp["norm1"], x)
        x = x + attn_mod.attention(lp["self_attn"], h, positions,
                                   cfg.attn_cfg(False),
                                   block_q=cfg.attn_block_q,
                                   block_k=cfg.attn_block_k)
        h = norm(lp["norm3"], x)
        x = x + layers.mlp(lp["mlp"], h, act=cfg.act)
        return constrain(x, ("batch", None, None)), None

    x, _ = jax.lax.scan(_remat(body, cfg.remat), frames, params["encoder"])
    return x


def decode_train(params: dict, tokens_emb: jax.Array, memory: jax.Array,
                 cfg: EncDecCfg) -> jax.Array:
    """tokens_emb: (B, S_tgt, D) target embeddings → decoder output."""
    _, _, norm = layers.make_norm(cfg.norm)
    B, S, _ = tokens_emb.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, lp):
        h = norm(lp["norm1"], x)
        x = x + attn_mod.attention(lp["self_attn"], h, positions,
                                   cfg.attn_cfg(True),
                                   block_q=cfg.attn_block_q,
                                   block_k=cfg.attn_block_k)
        h = norm(lp["norm2"], x)
        x = x + attn_mod.cross_attention(lp["cross_attn"], h, memory,
                                         cfg.attn_cfg(False),
                                         block_q=cfg.attn_block_q,
                                         block_k=cfg.attn_block_k)
        h = norm(lp["norm3"], x)
        x = x + layers.mlp(lp["mlp"], h, act=cfg.act)
        return constrain(x, ("batch", None, None)), None

    x, _ = jax.lax.scan(_remat(body, cfg.remat), tokens_emb, params["decoder"])
    return x


# ---------------------------------------------------------------------------
# decode-time state
# ---------------------------------------------------------------------------

def init_dec_state(params: dict, memory: jax.Array, cfg: EncDecCfg,
                   batch: int, max_len: int, dtype) -> dict:
    """Self-attn KV cache + per-layer cross K/V precomputed from memory."""
    acfg = cfg.attn_cfg(False)

    def cross_kv(lp):
        k = jnp.einsum("bse,ekd->bskd", memory, lp["cross_attn"]["wk"].astype(memory.dtype))
        v = jnp.einsum("bse,ekd->bskd", memory, lp["cross_attn"]["wv"].astype(memory.dtype))
        return {"ck": k, "cv": v}

    cross = jax.vmap(cross_kv)(params["decoder"])
    self_kv = {
        "k": jnp.zeros((cfg.n_dec_layers, batch, max_len,
                        acfg.n_kv_heads, acfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_dec_layers, batch, max_len,
                        acfg.n_kv_heads, acfg.head_dim), dtype),
    }
    return {**self_kv, **cross}


def axes_dec_state() -> dict:
    return {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "ck": ("layers", "batch", None, "kv_heads", None),
            "cv": ("layers", "batch", None, "kv_heads", None)}


def _cross_decode(lp: dict, x: jax.Array, ck: jax.Array, cv: jax.Array,
                  cfg: AttnCfg) -> jax.Array:
    """Single-token cross attention vs precomputed (B, S_src, K, D) K/V."""
    B, E = x.shape
    K, G, D = cfg.n_kv_heads, cfg.group, cfg.head_dim
    q = jnp.einsum("be,ehd->bhd", x, lp["wq"].astype(x.dtype)).reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", q, ck,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(B, cfg.n_heads, D)
    return jnp.einsum("bhd,hde->be", out, lp["wo"].astype(x.dtype))


def decode_step(params: dict, x: jax.Array, state: dict, pos: jax.Array,
                cfg: EncDecCfg):
    """x: (B, D) current target-token embedding → (y, state')."""
    _, _, norm = layers.make_norm(cfg.norm)

    def body(x, inp):
        lp, st = inp
        h = norm(lp["norm1"], x[:, None, :])[:, 0]
        out, k_new, v_new = attn_mod.decode_attention(
            lp["self_attn"], h, st["k"], st["v"], pos, cfg.attn_cfg(True))
        x = x + out
        h = norm(lp["norm2"], x[:, None, :])[:, 0]
        x = x + _cross_decode(lp["cross_attn"], h, st["ck"], st["cv"],
                              cfg.attn_cfg(False))
        h = norm(lp["norm3"], x[:, None, :])
        x = x + layers.mlp(lp["mlp"], h, act=cfg.act)[:, 0]
        return x, {"k": k_new, "v": v_new, "ck": st["ck"], "cv": st["cv"]}

    x, new_state = jax.lax.scan(body, x, (params["decoder"], state))
    return x, new_state

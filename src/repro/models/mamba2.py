"""Mamba2 — SSD (state-space duality) mixer, chunked-scan formulation.

The sequence is processed in chunks of ``chunk`` tokens: within a chunk the
SSD dual form is a masked (decay-weighted) quadratic attention computed on the
MXU; across chunks a single (B, H, P, N) state is carried by a `lax.scan` —
O(S) work, O(1) decode state.  Heads (`ssm_heads`) are the tensor-parallel
target; B/C projections use ngroups=1 and stay replicated (they are tiny).

Projections are stored per-role (wz/wx/wB/wC/wdt) rather than one fused
in_proj so each weight gets a clean Multi-Dimension annotation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models import layers


@dataclasses.dataclass(frozen=True)
class SSDCfg:
    d_model: int
    n_heads: int              # d_inner // headdim
    headdim: int = 64
    d_state: int = 128
    d_conv: int = 4
    chunk: int = 256
    ngroups: int = 1
    act: str = "silu"

    @property
    def d_inner(self) -> int:
        return self.n_heads * self.headdim


def init_ssd(key, cfg: SSDCfg, dtype) -> dict:
    kz, kx, kb, kc, kd, ko, kcv = jax.random.split(key, 7)
    D, H, Pd, G, N = cfg.d_model, cfg.n_heads, cfg.headdim, cfg.ngroups, cfg.d_state
    return {
        "wz": layers.dense_init(kz, D, (D, H, Pd), dtype),
        "wx": layers.dense_init(kx, D, (D, H, Pd), dtype),
        "wB": layers.dense_init(kb, D, (D, G, N), dtype),
        "wC": layers.dense_init(kc, D, (D, G, N), dtype),
        "wdt": layers.dense_init(kd, D, (D, H), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_x": (jax.random.normal(kcv, (H, Pd, cfg.d_conv), jnp.float32)
                   * 0.1).astype(dtype),
        "norm_scale": jnp.ones((H, Pd), dtype),
        "wo": layers.dense_init(ko, cfg.d_inner, (H, Pd, D), dtype),
    }


def axes_ssd(cfg: SSDCfg) -> dict:
    return {
        "wz": ("embed", "ssm_heads", None),
        "wx": ("embed", "ssm_heads", None),
        "wB": ("embed", None, "state"),
        "wC": ("embed", None, "state"),
        "wdt": ("embed", "ssm_heads"),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D_skip": ("ssm_heads",),
        "conv_x": ("ssm_heads", None, None),
        "norm_scale": ("ssm_heads", None),
        "wo": ("ssm_heads", None, "embed"),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, H, P), kernel: (H, P, W)."""
    W = kernel.shape[-1]
    out = x * kernel[None, None, :, :, -1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0), (0, 0)))[:, :-i or None]
        out = out + shifted * kernel[None, None, :, :, -1 - i]
    return out


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    """Mamba2 gated norm over the full d_inner = (H, P) dims."""
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=(-2, -1), keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """(..., T) → (..., T, T) lower-triangular segment sums (f32, -inf above)."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    # seg[i, j] = sum_{k=j+1..i} a_k  (decay applied moving j's input to i)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(T)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int, h0: jax.Array | None = None):
    """Chunked SSD forward.

    x: (B, S, H, P)   dt: (B, S, H) post-softplus   A: (H,) negative
    Bm/Cm: (B, S, G, N) with G broadcast over heads.
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = max(S // chunk, 1)
    Q = S // L
    rep = H // G

    dA = (dt * A[None, None, :]).astype(jnp.float32)               # (B,S,H) ≤ 0
    xd = x * dt[..., None].astype(x.dtype)                         # dt-weighted input
    # chunked views
    xc = xd.reshape(Bsz, L, Q, H, Pd)
    Bc = jnp.repeat(Bm.reshape(Bsz, L, Q, G, N), rep, axis=3)       # (B,L,Q,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, L, Q, G, N), rep, axis=3)
    dAc = dA.reshape(Bsz, L, Q, H).transpose(0, 3, 1, 2)            # (B,H,L,Q)
    A_cum = jnp.cumsum(dAc, axis=-1)                                # (B,H,L,Q)

    # --- intra-chunk (dual quadratic form) ---
    Lmat = jnp.exp(_segsum(dAc))                                    # (B,H,L,Q,Q)
    scores = jnp.einsum("blqhn,blshn->bhlqs", Cc, Bc,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bhlqs,bhlqs,blshp->blqhp", scores, Lmat,
                        xc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    # --- chunk states + inter-chunk recurrence (lax.scan) ---
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)                 # (B,H,L,Q)
    states = jnp.einsum("blqhn,bhlq,blqhp->blhpn", Bc, decay_states,
                        xc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)         # (B,L,H,P,N)
    chunk_decay = jnp.exp(A_cum[..., -1])                           # (B,H,L)

    def step(h, inp):
        s_l, d_l = inp                                              # (B,H,P,N), (B,H)
        h_new = h * d_l[..., None, None] + s_l
        return h_new, h                                             # emit state *before* chunk

    init = jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None else h0
    hT, h_prev = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 2, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                             # (B,L,H,P,N)

    # --- contribution of carried state to each position ---
    state_decay = jnp.exp(A_cum)                                    # (B,H,L,Q)
    y_off = jnp.einsum("blqhn,blhpn,bhlq->blqhp", Cc, h_prev, state_decay,
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y, hT


def ssd_block(params: dict, x: jax.Array, cfg: SSDCfg,
              impl: str = "ref"):
    """Full mamba2 mixer. x: (B, S, D) → (B, S, D)."""
    B, S, D = x.shape
    H, Pd, N, G = cfg.n_heads, cfg.headdim, cfg.d_state, cfg.ngroups
    z = jnp.einsum("bsd,dhp->bshp", x, params["wz"].astype(x.dtype))
    xi = jnp.einsum("bsd,dhp->bshp", x, params["wx"].astype(x.dtype))
    Bm = jnp.einsum("bsd,dgn->bsgn", x, params["wB"].astype(x.dtype))
    Cm = jnp.einsum("bsd,dgn->bsgn", x, params["wC"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32),
                    params["wdt"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + params["dt_bias"][None, None, :])

    xi = constrain(xi, ("batch", None, "ssm_heads", None))
    z = constrain(z, ("batch", None, "ssm_heads", None))
    xi = _causal_conv(xi, params["conv_x"].astype(x.dtype))
    xi = jax.nn.silu(xi)

    A = -jnp.exp(params["A_log"])
    if impl == "pallas":
        from repro.kernels.ssd import ops as ssd_ops
        y, _ = ssd_ops.ssd(xi, dt, A, Bm, Cm, chunk=min(cfg.chunk, S),
                           interpret=jax.default_backend() != "tpu")
    else:
        y, _ = ssd_scan(xi, dt, A, Bm, Cm, chunk=cfg.chunk)
    y = y.astype(x.dtype)
    y = y + params["D_skip"].astype(x.dtype)[None, None, :, None] * xi
    y = _gated_rmsnorm(y, z, params["norm_scale"]).astype(x.dtype)
    y = constrain(y, ("batch", None, "ssm_heads", None))
    return jnp.einsum("bshp,hpd->bsd", y, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# O(1)-state decode
# ---------------------------------------------------------------------------

def init_ssd_state(batch: int, cfg: SSDCfg, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.n_heads, cfg.headdim), dtype),
    }


def axes_ssd_state() -> dict:
    return {"h": ("batch", "ssm_heads", None, None),
            "conv": ("batch", None, "ssm_heads", None)}


def ssd_decode_step(params: dict, x: jax.Array, state: dict, cfg: SSDCfg):
    """x: (B, D) single token → (y (B, D), new state)."""
    B, D = x.shape
    H, Pd, N = cfg.n_heads, cfg.headdim, cfg.d_state
    z = jnp.einsum("bd,dhp->bhp", x, params["wz"].astype(x.dtype))
    xi = jnp.einsum("bd,dhp->bhp", x, params["wx"].astype(x.dtype))
    Bm = jnp.einsum("bd,dgn->bgn", x, params["wB"].astype(x.dtype))
    Cm = jnp.einsum("bd,dgn->bgn", x, params["wC"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", x.astype(jnp.float32),
                   params["wdt"].astype(jnp.float32))
        + params["dt_bias"][None, :])

    # rolling causal conv state
    conv_hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B,W,H,P)
    k = params["conv_x"].astype(x.dtype)                                # (H,P,W)
    xi = jnp.einsum("bwhp,hpw->bhp", conv_hist, k)
    xi = jax.nn.silu(xi)
    new_conv = conv_hist[:, 1:]

    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                                       # (B,H)
    rep = H // cfg.ngroups
    Bh = jnp.repeat(Bm, rep, axis=1)                                    # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dBx = (dt[..., None, None] * Bh[:, :, None, :].astype(jnp.float32)
           * xi[..., None].astype(jnp.float32))                          # (B,H,P,N)
    h = state["h"] * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    y = y.astype(x.dtype) + params["D_skip"].astype(x.dtype)[None, :, None] * xi
    y = _gated_rmsnorm(y[:, None].reshape(B, 1, H, Pd),
                       z.reshape(B, 1, H, Pd),
                       params["norm_scale"]).reshape(B, H, Pd).astype(x.dtype)
    out = jnp.einsum("bhp,hpd->bd", y, params["wo"].astype(x.dtype))
    return out, {"h": h, "conv": new_conv}

"""Attention for the LM family: blocked (flash-style) training/prefill paths
and a flash-decode serving path, all strategy-agnostic via logical axes.

Layouts
-------
Grouped-query attention is computed in the *grouped* layout
``q: (B, S, K, G, D)`` vs ``k/v: (B, S, K, D)`` (K = kv heads, G = query group
size) so the KV tensors are never materialised per query head.  When the
planner wants query-head tensor parallelism but K does not divide the model
axis (e.g. grok-1: K=8 on a 16-way axis), KV is physically repeated to the
48 query heads ("repeat" layout, K←Hq, G←1) — the repeat is cheap relative to
scores and lets GSPMD shard the head dim.  When neither head count divides
(gemma-2b: 8 heads, qwen2-vl: 12 heads), the query *sequence* is sharded
instead ("seq" layout) with KV replicated — MQA-style context parallelism.

The training path is a blocked online-softmax (flash) computation expressed
with `lax.scan` over KV blocks so the lowered HLO never materialises the
(S, S) score matrix — this is what makes the 32k prefill dry-run fit HBM.
``wedge=True`` additionally skips fully-masked KV blocks (python-unrolled
per-q-block prefix lengths → ~2× fewer attention FLOPs for causal), used by
the perf hillclimb.

The decode path writes the partial-softmax combine explicitly (local max /
sumexp / weighted values, then tiny cross-shard reductions) so that a KV
cache sharded along the sequence dim lowers to flash-decode-style collectives
instead of an all-gather of the cache.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain, current_rules
from repro.models import layers

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple | None = None
    causal: bool = True
    use_rope: bool = True

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: AttnCfg, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    E, H, K, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": layers.dense_init(kq, E, (E, H, D), dtype),
        "wk": layers.dense_init(kk, E, (E, K, D), dtype),
        "wv": layers.dense_init(kv, E, (E, K, D), dtype),
        "wo": layers.dense_init(ko, H * D, (H, D, E), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(D, dtype)
        p["k_norm"] = layers.init_rmsnorm(D, dtype)
    return p


def axes_attention(cfg: AttnCfg) -> dict:
    a = {
        "wq": ("embed", "q_heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("q_heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        a["q_norm"] = {"scale": ("head_dim",)}
        a["k_norm"] = {"scale": ("head_dim",)}
    return a


def choose_layout(cfg: AttnCfg) -> str:
    """Pick grouped / repeat / seq per the active sharding rules (see module doc)."""
    rules = current_rules()
    if rules is None:
        return "grouped"
    tp = rules.axis_size(rules.rules.get("kv_heads"))
    if cfg.n_kv_heads % tp == 0:
        return "grouped"
    if cfg.n_heads % tp == 0:
        return "repeat"
    return "seq"


# ---------------------------------------------------------------------------
# blocked (flash-style) attention core — grouped layout
# ---------------------------------------------------------------------------

def _blocked_gqa(q, k, v, *, causal: bool, block_q: int, block_k: int,
                 wedge: bool = False, kv_offset: int = 0,
                 bwd_remat: bool = False):
    """q: (B, Sq, K, G, D)  k/v: (B, Sk, K, D)  →  (B, Sq, K, G, D) float32 acc.

    kv_offset: absolute position of q[0] minus k[0] (for cross/chunked use).
    bwd_remat: checkpoint the kv-block step so the backward *recomputes* each
    (block_q, block_k) score tile instead of saving it — the flash-attention
    backward memory/traffic profile (otherwise autodiff of the scan stacks
    every score tile, i.e. the full (Sq, Sk) matrix, as residuals).
    """
    B, Sq, K, G, D = q.shape
    Sk = k.shape[1]
    # a block larger than the sequence is benign (one block); a block that
    # does not DIVIDE the sequence is not — silently rewriting it changed
    # the user's tiling (and FLOP/memory profile) behind their back.  Match
    # the PR 3 truncated-reshape precedent: fail loudly instead.
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(
            f"block_q/block_k ({block_q}, {block_k}) must divide the "
            f"sequence lengths ({Sq}, {Sk}); pick dividing blocks (e.g. via "
            f"repro.kernels.autotune.fit_block) instead of relying on "
            f"silent rounding")
    nq = Sq // block_q
    nk = Sk // block_k
    scale = 1.0 / (D ** 0.5)

    qb = q.reshape(B, nq, block_q, K, G, D)
    kb = k.reshape(B, nk, block_k, K, D)
    vb = v.reshape(B, nk, block_k, K, D)

    def kv_step(carry, inputs):
        m, l, acc, qi = carry
        kj, vj, j = inputs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jnp.arange(block_q) + kv_offset
            kpos = j * block_k + jnp.arange(block_k)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new, qi), None

    def one_q_block(i, qi, nk_i):
        nonlocal q_start
        q_start = i * block_q
        m0 = jnp.full((B, K, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, K, G, block_q, D), jnp.float32)
        ks = jnp.moveaxis(kb[:, :nk_i], 1, 0)
        vs = jnp.moveaxis(vb[:, :nk_i], 1, 0)
        js = jnp.arange(nk_i)
        step = jax.checkpoint(kv_step) if bwd_remat else kv_step
        (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, qi), (ks, vs, js))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, block_q, K, G, D)

    q_start = 0
    if wedge and causal and nq > 1:
        # python-unrolled prefix lengths: q block i attends kv blocks [0, ...]
        outs = []
        for i in range(nq):
            hi = ((i + 1) * block_q + kv_offset + block_k - 1) // block_k
            hi = max(1, min(nk, hi))
            outs.append(one_q_block(i, qb[:, i], hi))
        out = jnp.stack(outs, axis=1)
    else:
        idx = jnp.arange(nq)
        out = jax.vmap(lambda i, qi: one_q_block(i, qi, nk),
                       in_axes=(0, 1), out_axes=1)(idx, qb)
    return out.reshape(B, Sq, K, G, D)


# ---------------------------------------------------------------------------
# full self-attention layer (training / prefill)
# ---------------------------------------------------------------------------

def attention(params: dict, x: jax.Array, positions: jax.Array, cfg: AttnCfg,
              *, block_q: int = 512, block_k: int = 512, wedge: bool = False,
              return_kv: bool = False, impl: str = "ref",
              bwd_remat: bool = False):
    """x: (B, S, E) → (B, S, E); optionally also the (B, S, K, D) kv tensors.

    ``impl="pallas"``: the score/softmax/value core runs in the Pallas flash
    kernel, fwd AND bwd — the kernel carries a custom VJP whose backward
    recomputes score tiles in VMEM (training-grade since PR 6).
    ``bwd_remat``: flash-style backward residual policy — recompute ``o``
    from (q, k, v, lse) in the backward instead of saving it (pallas path),
    or checkpoint the kv-block scan step (ref path)."""
    B, S, E = x.shape
    K, G, D = cfg.n_kv_heads, cfg.group, cfg.head_dim
    layout = choose_layout(cfg)

    q = jnp.einsum("bse,ehd->bshd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bse,ekd->bskd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bse,ekd->bskd", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q)
        k = layers.rmsnorm(params["k_norm"], k)
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    kv_out = (k, v) if return_kv else None

    if layout == "repeat":
        k = jnp.repeat(k, G, axis=2)          # (B, S, H, D)
        v = jnp.repeat(v, G, axis=2)
        qg = q[:, :, :, None, :]              # (B, S, H, 1, D)
        q_names = ("batch", None, "q_heads", None, None)
        kv_names = ("batch", None, "q_heads", None)
    else:
        qg = q.reshape(B, S, K, G, D)
        q_names = ("batch", None, "kv_heads", None, None)
        kv_names = ("batch", None, "kv_heads", None)
    if layout == "seq":
        q_names = ("batch", "q_seq") + q_names[2:]
        rules = current_rules()
        sz = rules.axis_size(rules.rules.get("q_seq")) if rules else 1
        if sz > 1 and S % sz == 0:
            block_q = min(block_q, S // sz)
            wedge = False  # python-unrolled prefixes break even seq sharding
    qg = constrain(qg, q_names)
    k = constrain(k, kv_names)
    v = constrain(v, kv_names)

    if impl == "pallas":
        from repro.kernels.flash_attention import flash
        Bq, Sq, Kq, Gq, Dq = qg.shape
        out = flash(
            qg.reshape(Bq, Sq, Kq * Gq, Dq), k, v, cfg.causal,
            min(block_q, Sq), min(block_k, S),
            jax.default_backend() != "tpu", bwd_remat,
        ).reshape(Bq, Sq, Kq, Gq, Dq).astype(jnp.float32)
    else:
        out = _blocked_gqa(qg, k, v, causal=cfg.causal,
                           block_q=block_q, block_k=block_k, wedge=wedge,
                           bwd_remat=bwd_remat)
    out = out.astype(x.dtype).reshape(B, S, cfg.n_heads, D)
    out_names = ("batch", "q_seq" if layout == "seq" else None,
                 "q_heads", None)
    out = constrain(out, out_names)
    y = jnp.einsum("bshd,hde->bse", out, params["wo"].astype(x.dtype))
    y = constrain(y, ("batch", None, None))
    return (y, kv_out) if return_kv else y


# ---------------------------------------------------------------------------
# cross-attention (encoder–decoder)
# ---------------------------------------------------------------------------

def cross_attention(params: dict, x: jax.Array, memory: jax.Array,
                    cfg: AttnCfg, *, block_q: int = 512, block_k: int = 512):
    B, S, E = x.shape
    K, G, D = cfg.n_kv_heads, cfg.group, cfg.head_dim
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bse,ekd->bskd", memory, params["wk"].astype(x.dtype))
    v = jnp.einsum("bse,ekd->bskd", memory, params["wv"].astype(x.dtype))
    qg = constrain(q.reshape(B, S, K, G, D), ("batch", None, "kv_heads", None, None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    out = _blocked_gqa(qg, k, v, causal=False, block_q=block_q, block_k=block_k)
    out = out.astype(x.dtype).reshape(B, S, cfg.n_heads, D)
    return jnp.einsum("bshd,hde->bse", out, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode (one token vs a sharded KV cache) — explicit flash-decode combine
# ---------------------------------------------------------------------------

def _decode_qkv(params: dict, x: jax.Array, pos: jax.Array, cfg: AttnCfg):
    """Shared decode-step projections: x (B, E) → q (B, H, D), k/v (B, K, D),
    q/k normed and roped at ``pos``.  Used verbatim by the dense and paged
    decode paths so the two stay numerically identical by construction."""
    B = x.shape[0]
    q = jnp.einsum("be,ehd->bhd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("be,ekd->bkd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("be,ekd->bkd", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q)
        k = layers.rmsnorm(params["k_norm"], k)
    if cfg.use_rope:
        posb = pos[:, None] if cfg.mrope_sections is None else \
            jnp.broadcast_to(pos[:, None, None], (B, 3, 1))
        q = layers.apply_rope(q[:, None], posb, cfg.rope_theta, cfg.mrope_sections)[:, 0]
        k = layers.apply_rope(k[:, None], posb, cfg.rope_theta, cfg.mrope_sections)[:, 0]
    return q, k, v


def decode_attention(params: dict, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, cfg: AttnCfg,
                     k_sc: jax.Array | None = None,
                     v_sc: jax.Array | None = None):
    """x: (B, E) one new token per sequence.

    k_cache/v_cache: (B, Smax, K, D), sharded along Smax per the `kv_seq`
    rule.  pos: (B,) int32 — current length (index where the new KV is
    written).  Returns (y (B, E), k_cache', v_cache'[, k_sc', v_sc']).

    **int8 KV cache** (beyond-paper, halves decode HBM/state bytes vs bf16):
    when ``k_sc``/``v_sc`` are given the caches are int8 with per-(token,
    head) f32 scales; new KV is quantised symmetrically on write and
    dequantised in-register on read — HBM only ever sees int8 KV.
    """
    B, E = x.shape
    K, G, D = cfg.n_kv_heads, cfg.group, cfg.head_dim
    Smax = k_cache.shape[1]
    quant = k_sc is not None

    q, k, v = _decode_qkv(params, x, pos, cfg)

    kv_names = ("batch", "kv_seq", "kv_heads", None)
    sc_names = ("batch", "kv_seq", "kv_heads")
    # scatter new kv at pos (one-hot write keeps the cache sharding intact)
    onehot = jax.nn.one_hot(pos, Smax, dtype=jnp.float32)          # (B, Smax)
    if quant:
        def q8(t):                       # (B, K, D) → int8 + (B, K) scale
            s = jnp.maximum(jnp.abs(t.astype(jnp.float32)).max(-1), 1e-30) \
                / 127.0
            qv = jnp.clip(jnp.round(t.astype(jnp.float32) / s[..., None]),
                          -127, 127).astype(jnp.int8)
            return qv, s

        kq, ks = q8(k)
        vq, vs = q8(v)
        oh8 = onehot.astype(jnp.int8)
        k_cache = k_cache + oh8[:, :, None, None] * kq[:, None]
        v_cache = v_cache + oh8[:, :, None, None] * vq[:, None]
        k_sc = k_sc + onehot[:, :, None] * ks[:, None]
        v_sc = v_sc + onehot[:, :, None] * vs[:, None]
        k_sc = constrain(k_sc, sc_names)
        v_sc = constrain(v_sc, sc_names)
        k_read = k_cache.astype(jnp.float32) * k_sc[..., None]
        v_read = v_cache.astype(jnp.float32) * v_sc[..., None]
    else:
        k_cache = k_cache + onehot.astype(k_cache.dtype)[:, :, None, None] \
            * k[:, None, :, :]
        v_cache = v_cache + onehot.astype(v_cache.dtype)[:, :, None, None] \
            * v[:, None, :, :]
        k_read, v_read = k_cache, v_cache
    k_cache = constrain(k_cache, kv_names)
    v_cache = constrain(v_cache, kv_names)

    qg = q.reshape(B, K, G, D)
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_read,
                   preferred_element_type=jnp.float32) * scale     # (B,K,G,Smax)
    valid = (jnp.arange(Smax)[None, :] <= pos[:, None])            # (B, Smax)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    # explicit max/sumexp so a seq-sharded cache lowers to tiny all-reduces
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_read.dtype), v_read,
                     preferred_element_type=jnp.float32)
    out = (out / jnp.maximum(l, 1e-30)).astype(x.dtype).reshape(B, cfg.n_heads, D)
    y = jnp.einsum("bhd,hde->be", out, params["wo"].astype(x.dtype))
    if quant:
        return y, k_cache, v_cache, k_sc, v_sc
    return y, k_cache, v_cache


# ---------------------------------------------------------------------------
# paged decode (block/paged KV cache — DESIGN.md §9)
# ---------------------------------------------------------------------------

def paged_scatter(pool: jax.Array, block_table: jax.Array, pos: jax.Array,
                  new: jax.Array) -> jax.Array:
    """Write ``new`` (B, K, D) into the page pool cell each slot's ``pos``
    maps to through its block table.

    pool: (P, page_size, K, D); block_table: (B, max_pages) int32 (0 = the
    reserved trash page); pos: (B,).  One-hot outer-product ADD, like the
    dense cache's scatter, so the write is jit-shaped for every slot — but
    writes that resolve to the trash page (inactive slots, unallocated
    entries) are *dropped*, keeping page 0 all-zero forever.  The target
    cell is zero by the allocator invariant (pages are zeroed when
    allocated, each cell written once), so ``0 + new`` stores ``new``
    bit-exactly.
    """
    P, ps = pool.shape[0], pool.shape[1]
    page_idx = pos // ps
    phys = jnp.take_along_axis(block_table, page_idx[:, None], axis=1)[:, 0]
    live = (phys != 0).astype(jnp.float32)
    oh_page = jax.nn.one_hot(phys, P, dtype=jnp.float32) * live[:, None]
    oh_row = jax.nn.one_hot(pos % ps, ps, dtype=jnp.float32)
    delta = jnp.einsum("bp,br,bkd->prkd", oh_page.astype(pool.dtype),
                       oh_row.astype(pool.dtype), new.astype(pool.dtype))
    return pool + delta


def paged_decode_attention(params: dict, x: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_table: jax.Array,
                           pos: jax.Array, cfg: AttnCfg, *,
                           impl: str = "ref", page_interpret: bool | None = None):
    """Decode step against a paged KV cache.

    x: (B, E); k_pool/v_pool: (P, page_size, K, D) physical page pools
    shared by all slots; block_table: (B, max_pages) int32 slot→page map
    (entry 0 = the trash page); pos: (B,).  Returns (y, k_pool', v_pool').

    ``impl="ref"`` is *bit-exact* against :func:`decode_attention` on the
    equivalent dense cache by construction: the pool is gathered through
    the block table into a dense-cache-shaped array (identical values —
    unallocated entries gather the all-zero trash page, exactly what the
    dense cache holds beyond ``pos``) and the SAME ``decode_attention``
    runs on it; the new KV is then extracted from the updated gather and
    persisted into the pool.  ``impl="pallas"`` writes the pool first and
    runs the block-table-indexed flash-decode kernel
    (:func:`repro.kernels.flash_attention.paged_decode`) over it.
    """
    B, E = x.shape
    K, G, D = cfg.n_kv_heads, cfg.group, cfg.head_dim
    P, ps = k_pool.shape[0], k_pool.shape[1]
    max_pages = block_table.shape[1]

    if impl == "ref":
        kd = k_pool[block_table].reshape(B, max_pages * ps, K, D)
        vd = v_pool[block_table].reshape(B, max_pages * ps, K, D)
        y, k_upd, v_upd = decode_attention(params, x, kd, vd, pos, cfg)
        # the pos cell was zero pre-add, so the one-hot row-pick recovers
        # the freshly written post-rope k/v exactly (1·k + Σ 0·finite = k)
        oh = jax.nn.one_hot(pos, max_pages * ps, dtype=k_upd.dtype)
        k_new = jnp.einsum("bs,bskd->bkd", oh, k_upd)
        v_new = jnp.einsum("bs,bskd->bkd", oh, v_upd)
    else:
        q, k_new, v_new = _decode_qkv(params, x, pos, cfg)
    k_pool = paged_scatter(k_pool, block_table, pos, k_new)
    v_pool = paged_scatter(v_pool, block_table, pos, v_new)
    if impl != "ref":
        from repro.kernels.flash_attention import paged_decode
        if page_interpret is None:
            page_interpret = jax.default_backend() != "tpu"
        out = paged_decode(q, k_pool, v_pool, block_table, pos,
                           interpret=page_interpret)
        out = out.astype(x.dtype)
        y = jnp.einsum("bhd,hde->be", out, params["wo"].astype(x.dtype))
    return y, k_pool, v_pool

"""Unified layer-stack for every assigned architecture.

A model backbone is a *pattern* of block configs repeated ``n_rep`` times and
executed with ``jax.lax.scan`` over the repeats (params stacked on a leading
``layers`` dim).  This covers:

- dense transformers          pattern = [attn+dense]           × L
- MoE transformers            pattern = [attn+moe]             × L
- mamba2                      pattern = [ssd+none]             × L
- jamba hybrid                pattern = 8 blocks (1 attn + 7 ssd, MoE on odd
                              positions)                        × L/8

Scanning over repeats is what keeps the lowered HLO (and 512-way SPMD
partitioning time) small and is also Whale's "cluster repeated substructures"
idea applied to compilation: one pattern body is partitioned once, × n_rep.

Each block: pre-norm mixer (attention | SSD) + pre-norm MLP (dense | MoE),
residual connections, optional remat (checkpoint) around the whole repeat.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import layers, mamba2, moe as moe_mod
from repro.models.attention import AttnCfg
from repro.models.mamba2 import SSDCfg
from repro.models.moe import MoECfg


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    d_model: int
    mixer: str = "attn"                  # "attn" | "ssd"
    mlp: str = "dense"                   # "dense" | "moe" | "none"
    attn: AttnCfg | None = None
    ssd: SSDCfg | None = None
    moe: MoECfg | None = None
    d_ff: int = 0
    norm: str = "rms"
    act: str = "silu"
    gated_mlp: bool = True


@dataclasses.dataclass(frozen=True)
class StackCfg:
    pattern: tuple                        # tuple[BlockCfg, ...]
    n_rep: int
    remat: str = "full"                   # "none" | "full" | "dots"
    scan: bool = True
    attn_block_q: int = 512
    attn_block_k: int = 512
    attn_wedge: bool = False              # causal block skipping (perf opt)
    attn_impl: str = "ref"                # "ref" | "pallas" (fwd+bwd fused)
    ssd_impl: str = "ref"                 # "ref" | "pallas"
    attn_bwd_remat: bool = False          # flash-style backward (perf opt)
    kv_cache_dtype: str = "bfloat16"      # "bfloat16" | "int8" (serving opt)

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n_rep


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, cfg: BlockCfg, dtype) -> dict:
    km, kf, kn1, kn2 = jax.random.split(key, 4)
    norm_init, _, _ = layers.make_norm(cfg.norm)
    p: dict[str, Any] = {"norm1": norm_init(cfg.d_model, dtype)}
    if cfg.mixer == "attn":
        p["attn"] = attn_mod.init_attention(km, cfg.attn, dtype)
    else:
        p["ssd"] = mamba2.init_ssd(km, cfg.ssd, dtype)
    if cfg.mlp != "none":
        p["norm2"] = norm_init(cfg.d_model, dtype)
        if cfg.mlp == "moe":
            p["moe"] = moe_mod.init_moe(kf, cfg.moe, dtype)
        else:
            p["mlp"] = layers.init_mlp(kf, cfg.d_model, cfg.d_ff, dtype,
                                       gated=cfg.gated_mlp)
    return p


def axes_block(cfg: BlockCfg) -> dict:
    _, norm_axes, _ = layers.make_norm(cfg.norm)
    a: dict[str, Any] = {"norm1": norm_axes()}
    if cfg.mixer == "attn":
        a["attn"] = attn_mod.axes_attention(cfg.attn)
    else:
        a["ssd"] = mamba2.axes_ssd(cfg.ssd)
    if cfg.mlp != "none":
        a["norm2"] = norm_axes()
        if cfg.mlp == "moe":
            a["moe"] = moe_mod.axes_moe(cfg.moe)
        else:
            a["mlp"] = layers.axes_mlp(gated=cfg.gated_mlp)
    return a


def _zero_aux() -> dict:
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}


def apply_block(params: dict, x: jax.Array, positions: jax.Array,
                cfg: BlockCfg, stack: StackCfg, *, return_kv: bool = False):
    """x: (B, S, E) → (x', aux, kv-or-None)."""
    _, _, norm = layers.make_norm(cfg.norm)
    aux = _zero_aux()
    kv = None
    h = norm(params["norm1"], x)
    if cfg.mixer == "attn":
        out = attn_mod.attention(
            params["attn"], h, positions, cfg.attn,
            block_q=stack.attn_block_q, block_k=stack.attn_block_k,
            wedge=stack.attn_wedge, return_kv=return_kv,
            impl=stack.attn_impl, bwd_remat=stack.attn_bwd_remat)
        if return_kv:
            out, kv = out
    else:
        out = mamba2.ssd_block(params["ssd"], h, cfg.ssd,
                               impl=stack.ssd_impl)
    x = x + out
    if cfg.mlp != "none":
        h = norm(params["norm2"], x)
        if cfg.mlp == "moe":
            out, moe_aux = moe_mod.moe_block(params["moe"], h, cfg.moe)
            aux = {"lb_loss": moe_aux["lb_loss"], "z_loss": moe_aux["z_loss"]}
        else:
            out = layers.mlp(params["mlp"], h, act=cfg.act)
        x = x + out
    x = constrain(x, ("batch", "seq", None))
    return x, aux, kv


def decode_block(params: dict, x: jax.Array, state: dict, pos: jax.Array,
                 cfg: BlockCfg):
    """x: (B, E) one token; state: kv cache or ssd state for this block."""
    _, _, norm = layers.make_norm(cfg.norm)
    h = norm(params["norm1"], x[:, None, :])[:, 0]
    if cfg.mixer == "attn":
        if "k_sc" in state:              # int8 KV cache
            out, k_new, v_new, ks, vs = attn_mod.decode_attention(
                params["attn"], h, state["k"], state["v"], pos, cfg.attn,
                k_sc=state["k_sc"], v_sc=state["v_sc"])
            state = {"k": k_new, "v": v_new, "k_sc": ks, "v_sc": vs}
        else:
            out, k_new, v_new = attn_mod.decode_attention(
                params["attn"], h, state["k"], state["v"], pos, cfg.attn)
            state = {"k": k_new, "v": v_new}
    else:
        out, state = mamba2.ssd_decode_step(params["ssd"], h, state, cfg.ssd)
    x = x + out
    if cfg.mlp != "none":
        h = norm(params["norm2"], x[:, None, :])
        if cfg.mlp == "moe":
            out, _ = moe_mod.moe_block(params["moe"], h, cfg.moe)
        else:
            out = layers.mlp(params["mlp"], h, act=cfg.act)
        x = x + out[:, 0]
    return x, state


def init_block_state(cfg: BlockCfg, batch: int, max_len: int, dtype,
                     kv_dtype: str = "bfloat16") -> dict:
    if cfg.mixer == "attn":
        a = cfg.attn
        shape = (batch, max_len, a.n_kv_heads, a.head_dim)
        if kv_dtype == "int8":
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_sc": jnp.zeros(shape[:3], jnp.float32),
                    "v_sc": jnp.zeros(shape[:3], jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return mamba2.init_ssd_state(batch, cfg.ssd, dtype)


def axes_block_state(cfg: BlockCfg, kv_dtype: str = "bfloat16") -> dict:
    if cfg.mixer == "attn":
        n = ("batch", "kv_seq", "kv_heads", None)
        a = {"k": n, "v": n}
        if kv_dtype == "int8":
            a["k_sc"] = ("batch", "kv_seq", "kv_heads")
            a["v_sc"] = ("batch", "kv_seq", "kv_heads")
        return a
    return mamba2.axes_ssd_state()


# ---------------------------------------------------------------------------
# stack (scan over pattern repeats)
# ---------------------------------------------------------------------------

def init_stack(key, stack: StackCfg, dtype) -> dict:
    params = {}
    for i, bcfg in enumerate(stack.pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), stack.n_rep)
        params[f"p{i}"] = jax.vmap(lambda k: init_block(k, bcfg, dtype))(keys)
    return params


def axes_stack(stack: StackCfg) -> dict:
    axes = {}
    for i, bcfg in enumerate(stack.pattern):
        ax = axes_block(bcfg)
        axes[f"p{i}"] = jax.tree.map(lambda t: ("layers",) + t, ax,
                                     is_leaf=lambda t: isinstance(t, tuple))
    return axes


def _remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full": save nothing


def apply_stack(params: dict, x: jax.Array, positions: jax.Array,
                stack: StackCfg):
    """x: (B, S, E) → (x', summed aux)."""

    def rep_body(x, rep_params):
        aux = _zero_aux()
        for i, bcfg in enumerate(stack.pattern):
            x, a, _ = apply_block(rep_params[f"p{i}"], x, positions, bcfg, stack)
            aux = jax.tree.map(jnp.add, aux, a)
        return x, aux

    body = _remat_wrap(rep_body, stack.remat)
    if stack.scan and stack.n_rep > 1:
        x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, params)
        aux = jax.tree.map(lambda a: a.sum(0), auxs)
    else:
        aux = _zero_aux()
        for r in range(stack.n_rep):
            rep_params = jax.tree.map(lambda p: p[r], params)
            x, a = body(x, rep_params)
            aux = jax.tree.map(jnp.add, aux, a)
    return x, aux


def prefill_stack(params: dict, x: jax.Array, positions: jax.Array,
                  stack: StackCfg):
    """Forward returning per-block KV caches (attn) for subsequent decode."""

    def rep_body(x, rep_params):
        kvs = {}
        for i, bcfg in enumerate(stack.pattern):
            x, _, kv = apply_block(rep_params[f"p{i}"], x, positions, bcfg,
                                   stack, return_kv=(bcfg.mixer == "attn"))
            if bcfg.mixer == "attn":
                kvs[f"p{i}"] = {"k": kv[0], "v": kv[1]}
        return x, kvs

    if stack.scan and stack.n_rep > 1:
        x, caches = jax.lax.scan(rep_body, x, params)
    else:
        caches_list = []
        for r in range(stack.n_rep):
            rep_params = jax.tree.map(lambda p: p[r], params)
            x, kvs = rep_body(x, rep_params)
            caches_list.append(kvs)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_list)
    return x, caches


def init_stack_state(stack: StackCfg, batch: int, max_len: int, dtype) -> dict:
    state = {}
    for i, bcfg in enumerate(stack.pattern):
        s = init_block_state(bcfg, batch, max_len, dtype,
                             kv_dtype=stack.kv_cache_dtype)
        state[f"p{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (stack.n_rep,) + a.shape), s)
    return state


def axes_stack_state(stack: StackCfg) -> dict:
    axes = {}
    for i, bcfg in enumerate(stack.pattern):
        ax = axes_block_state(bcfg, kv_dtype=stack.kv_cache_dtype)
        axes[f"p{i}"] = jax.tree.map(lambda t: ("layers",) + t, ax,
                                     is_leaf=lambda t: isinstance(t, tuple))
    return axes


def decode_stack(params: dict, x: jax.Array, state: dict, pos: jax.Array,
                 stack: StackCfg):
    """x: (B, E) → (x', state').  Scans blocks, threading per-layer state."""

    def rep_body(x, inp):
        rep_params, rep_state = inp
        new_state = {}
        for i, bcfg in enumerate(stack.pattern):
            x, s = decode_block(rep_params[f"p{i}"], x, rep_state[f"p{i}"],
                                pos, bcfg)
            new_state[f"p{i}"] = s
        return x, new_state

    if stack.scan and stack.n_rep > 1:
        x, new_state = jax.lax.scan(rep_body, x, (params, state))
    else:
        outs = []
        for r in range(stack.n_rep):
            rp = jax.tree.map(lambda p: p[r], params)
            rs = jax.tree.map(lambda s: s[r], state)
            x, s = rep_body(x, (rp, rs))
            outs.append(s)
        new_state = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_state


# ---------------------------------------------------------------------------
# paged decode (block/paged KV cache — serving tier, DESIGN.md §9)
# ---------------------------------------------------------------------------

def _check_paged(stack: StackCfg):
    if any(b.mixer != "attn" for b in stack.pattern):
        raise ValueError(
            "paged KV decode requires an all-attention pattern (SSD state "
            "is O(1) per slot and gains nothing from paging); pattern has "
            f"mixers {[b.mixer for b in stack.pattern]}")
    if stack.kv_cache_dtype == "int8":
        raise ValueError(
            "paged KV decode does not support the int8 KV cache yet — "
            "page pools are kept in the activation dtype")


def init_paged_stack_state(stack: StackCfg, n_pages: int, page_size: int,
                           dtype) -> dict:
    """Per-pattern-position page pools ``(n_rep, n_pages, page_size, K, D)``.

    Pools are *slot-free*: every decode slot shares them through its block
    table row, which is what lets short sequences stop reserving
    ``max_len`` KV rows each.
    """
    _check_paged(stack)
    pools = {}
    for i, bcfg in enumerate(stack.pattern):
        a = bcfg.attn
        shape = (n_pages, page_size, a.n_kv_heads, a.head_dim)
        s = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        pools[f"p{i}"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (stack.n_rep,) + t.shape), s)
    return pools


def axes_paged_stack_state(stack: StackCfg) -> dict:
    """Pools shard like the dense cache minus the batch dim: pages and
    rows replicated, kv heads on the model axis."""
    _check_paged(stack)
    n = ("layers", None, None, "kv_heads", None)
    return {f"p{i}": {"k": n, "v": n} for i in range(len(stack.pattern))}


def paged_decode_block(params: dict, x: jax.Array, pools: dict,
                       block_table: jax.Array, pos: jax.Array,
                       cfg: BlockCfg, stack: StackCfg):
    """Paged twin of :func:`decode_block` for one attention block."""
    _, _, norm = layers.make_norm(cfg.norm)
    h = norm(params["norm1"], x[:, None, :])[:, 0]
    out, k_pool, v_pool = attn_mod.paged_decode_attention(
        params["attn"], h, pools["k"], pools["v"], block_table, pos,
        cfg.attn, impl=stack.attn_impl)
    pools = {"k": k_pool, "v": v_pool}
    x = x + out
    if cfg.mlp != "none":
        h = norm(params["norm2"], x[:, None, :])
        if cfg.mlp == "moe":
            out, _ = moe_mod.moe_block(params["moe"], h, cfg.moe)
        else:
            out = layers.mlp(params["mlp"], h, act=cfg.act)
        x = x + out[:, 0]
    return x, pools


def decode_stack_paged(params: dict, x: jax.Array, pools: dict,
                       block_table: jax.Array, pos: jax.Array,
                       stack: StackCfg):
    """x: (B, E) → (x', pools').  :func:`decode_stack` against page pools;
    the block table and positions are shared by every layer."""
    _check_paged(stack)

    def rep_body(x, inp):
        rep_params, rep_pools = inp
        new_pools = {}
        for i, bcfg in enumerate(stack.pattern):
            x, p = paged_decode_block(rep_params[f"p{i}"], x,
                                      rep_pools[f"p{i}"], block_table, pos,
                                      bcfg, stack)
            new_pools[f"p{i}"] = p
        return x, new_pools

    if stack.scan and stack.n_rep > 1:
        x, new_pools = jax.lax.scan(rep_body, x, (params, pools))
    else:
        outs = []
        for r in range(stack.n_rep):
            rp = jax.tree.map(lambda p: p[r], params)
            rs = jax.tree.map(lambda s: s[r], pools)
            x, s = rep_body(x, (rp, rs))
            outs.append(s)
        new_pools = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_pools

"""Unified model builder: one config dataclass → {init, axes, loss_fn,
prefill, serve_step} for every assigned architecture family.

Families: dense / moe / ssm / hybrid (decoder LMs over models.transformer),
vlm (decoder LM + stub vision prefix + M-RoPE), encdec (seamless).

The loss path uses a sequence-chunked, vocab-parallel cross-entropy with an
explicit max/sumexp decomposition so a `vocab`-sharded head lowers to three
tiny all-reduces per chunk instead of gathering (B, S, V) logits — this is
the paper's Fig-4 "split the FC + Softmax" technique as a first-class loss
primitive (the Pallas `xent` kernel is the fused on-chip version).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.cost_model import ModelGraph, SegmentMeta
from repro.core.sharding import constrain
from repro.models import encdec as encdec_mod
from repro.models import frontends, layers
from repro.models import transformer as tfm
from repro.models.attention import AttnCfg
from repro.models.encdec import EncDecCfg
from repro.models.mamba2 import SSDCfg
from repro.models.moe import MoECfg


@dataclasses.dataclass(frozen=True)
class LMCfg:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # flavour
    norm: str = "rms"
    act: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple | None = None
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssd_headdim: int = 64
    ssd_state: int = 128
    d_conv: int = 4
    ssd_chunk: int = 256
    attn_period: int = 0               # hybrid: one attn layer per period
    attn_offset: int = 0
    # encdec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # frontend stub
    frontend: str | None = None        # "vision" | "audio"
    frontend_len: int = 0
    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"
    scan: bool = True
    attn_block_q: int = 512
    attn_block_k: int = 512
    attn_wedge: bool = False
    loss_chunk: int = 512
    vocab_pad_multiple: int = 256
    z_loss_coef: float = 1e-4
    # kernel selection: "ref" (pure jnp — CPU dry-run) or "pallas" (fused
    # kernels, fwd AND bwd via custom VJPs — training-grade since PR 6;
    # interpret-mode on CPU, Mosaic on TPU)
    attn_impl: str = "ref"
    ssd_impl: str = "ref"
    xent_impl: str = "ref"          # loss head: chunked jnp vs fused kernel
    xent_block_t: int = 128         # fused-xent token tile
    xent_block_v: int = 512         # fused-xent vocab tile
    attn_bwd_remat: bool = False    # flash-style attention backward
    kv_cache_dtype: str = "bfloat16"  # "int8": quantised serving KV cache
    # cast f32 master params to the compute dtype ONCE at step entry, so
    # ZeRO-3 all-gathers move (and buffer) bf16, not f32 — halves FSDP
    # gather volume and the per-layer gathered-weight footprint
    cast_params_once: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return layers.pad_vocab(self.vocab, self.vocab_pad_multiple)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def attn_cfg(self, causal: bool = True) -> AttnCfg:
        return AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                       n_kv_heads=self.n_kv_heads, head_dim=self.hd,
                       qk_norm=self.qk_norm, rope_theta=self.rope_theta,
                       mrope_sections=self.mrope_sections, causal=causal)

    def ssd_cfg(self) -> SSDCfg:
        n_heads = (2 * self.d_model) // self.ssd_headdim   # expand = 2
        return SSDCfg(d_model=self.d_model, n_heads=n_heads,
                      headdim=self.ssd_headdim, d_state=self.ssd_state,
                      d_conv=self.d_conv, chunk=self.ssd_chunk)

    def moe_cfg(self) -> MoECfg:
        return MoECfg(d_model=self.d_model, n_experts=self.n_experts,
                      top_k=self.top_k, d_ff_expert=self.d_ff_expert,
                      n_shared=self.n_shared,
                      capacity_factor=self.capacity_factor, act=self.act)

    def encdec_cfg(self) -> EncDecCfg:
        return EncDecCfg(d_model=self.d_model, n_enc_layers=self.n_enc_layers,
                         n_dec_layers=self.n_dec_layers, n_heads=self.n_heads,
                         n_kv_heads=self.n_kv_heads, head_dim=self.hd,
                         d_ff=self.d_ff, norm=self.norm, act=self.act,
                         gated_mlp=self.gated_mlp, remat=self.remat,
                         scan=self.scan, attn_block_q=self.attn_block_q,
                         attn_block_k=self.attn_block_k)


# ---------------------------------------------------------------------------
# pattern construction (scan grouping — repeated-substructure clustering)
# ---------------------------------------------------------------------------

def build_stack_cfg(cfg: LMCfg) -> tfm.StackCfg:
    def block(mixer: str, mlp: str) -> tfm.BlockCfg:
        return tfm.BlockCfg(
            d_model=cfg.d_model, mixer=mixer, mlp=mlp,
            attn=cfg.attn_cfg() if mixer == "attn" else None,
            ssd=cfg.ssd_cfg() if mixer == "ssd" else None,
            moe=cfg.moe_cfg() if mlp == "moe" else None,
            d_ff=cfg.d_ff, norm=cfg.norm, act=cfg.act,
            gated_mlp=cfg.gated_mlp)

    if cfg.family in ("dense", "vlm"):
        pattern, n_rep = (block("attn", "dense"),), cfg.n_layers
    elif cfg.family == "moe":
        if cfg.moe_every == 1:
            pattern, n_rep = (block("attn", "moe"),), cfg.n_layers
        else:
            pat = tuple(
                block("attn", "moe" if i % cfg.moe_every == cfg.moe_offset
                      else "dense")
                for i in range(cfg.moe_every))
            pattern, n_rep = pat, cfg.n_layers // cfg.moe_every
    elif cfg.family == "ssm":
        pattern, n_rep = (block("ssd", "none"),), cfg.n_layers
    elif cfg.family == "hybrid":
        p = cfg.attn_period
        pat = []
        for i in range(p):
            mixer = "attn" if i % p == cfg.attn_offset else "ssd"
            mlp = "moe" if i % 2 == 1 else "dense"
            pat.append(block(mixer, mlp))
        pattern, n_rep = tuple(pat), cfg.n_layers // p
    else:
        raise ValueError(cfg.family)
    return tfm.StackCfg(pattern=pattern, n_rep=n_rep, remat=cfg.remat,
                        scan=cfg.scan, attn_block_q=cfg.attn_block_q,
                        attn_block_k=cfg.attn_block_k,
                        attn_wedge=cfg.attn_wedge, attn_impl=cfg.attn_impl,
                        ssd_impl=cfg.ssd_impl,
                        attn_bwd_remat=cfg.attn_bwd_remat,
                        kv_cache_dtype=cfg.kv_cache_dtype)


# ---------------------------------------------------------------------------
# vocab-parallel chunked cross-entropy (paper Fig-4 split-softmax as a loss)
# ---------------------------------------------------------------------------

def chunked_xent(hidden: jax.Array, head_w: jax.Array, labels: jax.Array,
                 mask: jax.Array, *, vocab: int, chunk: int,
                 z_loss_coef: float = 0.0):
    """hidden: (B, T, E); head_w: (E, Vp) vocab-sharded; labels/mask: (B, T).

    Returns (sum_nll, sum_z_loss, token_count).  Sequence-chunked with remat
    so the (B, chunk, Vp) logits block is the only live logits tensor.
    """
    B, T, E = hidden.shape
    Vp = head_w.shape[1]
    chunk = min(chunk, T)
    n = -(-T // chunk)
    Tc = n * chunk
    if Tc != T:                      # pad (mask 0) so no token is dropped
        pad = Tc - T
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, E), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)
    col = jnp.arange(Vp)

    @jax.checkpoint
    def body(carry, inp):
        h, lab, msk = inp
        logits = jnp.einsum("bce,ev->bcv", h, head_w.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        if Vp > vocab:                       # mask padded vocab columns
            logits = jnp.where(col[None, None, :] < vocab, logits, -1e30)
        m = logits.max(axis=-1)                                   # AR(max) over vocab shards
        se = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)     # AR(sum)
        z = jnp.log(se) + m
        correct = jnp.sum(
            jnp.where(col[None, None, :] == lab[..., None], logits, 0.0),
            axis=-1)                                              # AR(sum)
        nll = (z - correct) * msk
        zl = jnp.square(z) * msk
        s_nll, s_zl, s_n = carry
        return (s_nll + nll.sum(), s_zl + zl.sum(), s_n + msk.sum()), None

    init = (jnp.zeros((), jnp.float32),) * 3
    (s_nll, s_zl, s_n), _ = jax.lax.scan(body, init, (hs, ls, ms))
    return s_nll, z_loss_coef * s_zl, s_n


def fused_xent(hidden: jax.Array, head_w: jax.Array, labels: jax.Array,
               mask: jax.Array, *, vocab: int, block_t: int = 128,
               block_v: int = 512, z_loss_coef: float = 0.0,
               interpret: bool | None = None):
    """Pallas fused-kernel twin of :func:`chunked_xent` (same contract).

    One kernel launch streams (E, Vp) head tiles through VMEM and never
    materialises a logits tensor at all; nll AND lse come back together so
    the z-loss term differentiates through the same recompute-over-vocab
    backward (``kernels.xent.ops.xent_with_lse``).
    """
    from repro.kernels.autotune import fit_block
    from repro.kernels.xent.ops import xent_with_lse
    B, T, E = hidden.shape
    Vp = head_w.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    h2 = hidden.reshape(B * T, E)
    l2 = labels.reshape(B * T)
    m2 = mask.reshape(B * T).astype(jnp.float32)
    bt = fit_block(B * T, block_t)
    bv = fit_block(Vp, block_v)
    nll, lse = xent_with_lse(h2, head_w, l2, vocab, bt, bv, interpret)
    s_nll = jnp.sum(nll * m2)
    s_zl = jnp.sum(jnp.square(lse) * m2)
    return s_nll, z_loss_coef * s_zl, m2.sum()


# ---------------------------------------------------------------------------
# the model object
# ---------------------------------------------------------------------------

class Model:
    """Functional model bundle for one LMCfg."""

    def __init__(self, cfg: LMCfg):
        self.cfg = cfg
        if cfg.family == "encdec":
            self.ecfg = cfg.encdec_cfg()
            self.stack = None
        else:
            self.stack = build_stack_cfg(cfg)
            self.ecfg = None

    # ---- params ----
    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kh, kb, ka, kn = jax.random.split(key, 5)
        dt = cfg.pdtype
        p: dict[str, Any] = {
            "embed": layers.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dt),
            "final_norm": layers.make_norm(cfg.norm)[0](cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            p["head"] = layers.init_lm_head(kh, cfg.d_model, cfg.padded_vocab, dt)
        if cfg.family == "encdec":
            p["encdec"] = encdec_mod.init_encdec(kb, self.ecfg, dt)
        else:
            p["blocks"] = tfm.init_stack(kb, self.stack, dt)
        if cfg.frontend is not None:
            p["adapter"] = frontends.init_adapter(ka, cfg.d_model, dt)
        return p

    def axes(self) -> dict:
        cfg = self.cfg
        a: dict[str, Any] = {
            "embed": layers.axes_embedding(),
            "final_norm": layers.make_norm(cfg.norm)[1](),
        }
        if not cfg.tie_embeddings:
            a["head"] = layers.axes_lm_head()
        if cfg.family == "encdec":
            a["encdec"] = encdec_mod.axes_encdec(self.ecfg)
        else:
            a["blocks"] = tfm.axes_stack(self.stack)
        if cfg.frontend is not None:
            a["adapter"] = frontends.axes_adapter()
        return a

    def param_shapes(self) -> dict:
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    def graph(self, batch: int, seq: int, *, act_dtype_bytes: int = 2,
              param_dtype_bytes: int = 4,
              src_seq: int | None = None) -> "ModelGraph":
        """Segment-aware cost-model view of this model (see
        :func:`model_graph`): ordered SegmentMeta segments — frontends,
        encoder/decoder stacks, MoE block groups — each with its own
        flops/param/activation arithmetic, flattenable to a legacy
        WorkloadMeta via ``.workload_meta()``."""
        return model_graph(self.cfg, batch, seq,
                           act_dtype_bytes=act_dtype_bytes,
                           param_dtype_bytes=param_dtype_bytes,
                           src_seq=src_seq)

    # ---- shared pieces ----
    def _head_w(self, params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"]["table"].T
        return params["head"]["w"]

    def _positions(self, B: int, S: int):
        if self.cfg.mrope_sections is not None:
            return frontends.mrope_positions(B, S, self.cfg.frontend_len)
        return jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def _embed_tokens(self, params, tokens, batch):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens).astype(cfg.adtype)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            P = cfg.frontend_len
            pe = frontends.adapt(params["adapter"],
                                 batch["patch_embeds"].astype(cfg.adtype))
            x = jnp.concatenate([pe, x[:, P:]], axis=1)
        return constrain(x, ("batch", "seq", None))

    def _maybe_cast(self, params):
        if not self.cfg.cast_params_once:
            return params
        adt = self.cfg.adtype
        return jax.tree.map(
            lambda p: p.astype(adt) if p.dtype == jnp.float32 else p, params)

    def _xent(self, hidden, head_w, labels, mask):
        """Loss-head dispatch: chunked jnp scan vs the fused Pallas kernel."""
        cfg = self.cfg
        if cfg.xent_impl == "pallas":
            return fused_xent(hidden, head_w, labels, mask, vocab=cfg.vocab,
                              block_t=cfg.xent_block_t,
                              block_v=cfg.xent_block_v,
                              z_loss_coef=cfg.z_loss_coef)
        return chunked_xent(hidden, head_w, labels, mask, vocab=cfg.vocab,
                            chunk=cfg.loss_chunk,
                            z_loss_coef=cfg.z_loss_coef)

    # ---- training ----
    def loss_fn(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        params = self._maybe_cast(params)
        if cfg.family == "encdec":
            return self._loss_encdec(params, batch)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed_tokens(params, tokens, batch)
        x, aux = tfm.apply_stack(params["blocks"], x, self._positions(B, S),
                                 self.stack)
        x = layers.make_norm(cfg.norm)[2](params["final_norm"], x)
        labels = tokens[:, 1:]
        mask = jnp.ones_like(labels, jnp.float32)
        if "loss_mask" in batch:
            mask = mask * batch["loss_mask"][:, 1:]
        if cfg.family == "vlm":
            tgt_pos = jnp.arange(1, S)[None]
            mask = mask * (tgt_pos >= cfg.frontend_len)
        nll, zl, n = self._xent(
            x[:, :-1], self._head_w(params).astype(cfg.adtype), labels, mask)
        loss = nll / jnp.maximum(n, 1.0) + zl / jnp.maximum(n, 1.0) \
            + aux["lb_loss"] + aux["z_loss"]
        metrics = {"nll": nll / jnp.maximum(n, 1.0), "tokens": n,
                   "moe_lb": aux["lb_loss"], "moe_z": aux["z_loss"]}
        return loss, metrics

    def _loss_encdec(self, params, batch):
        cfg = self.cfg
        frames = batch["frames"].astype(cfg.adtype)
        tokens = batch["tokens"]
        memory = encdec_mod.encode(params["encdec"],
                                   frontends.adapt(params["adapter"], frames)
                                   if cfg.frontend else frames, self.ecfg)
        dec_in = layers.embed(params["embed"], tokens[:, :-1]).astype(cfg.adtype)
        x = encdec_mod.decode_train(params["encdec"], dec_in, memory, self.ecfg)
        x = layers.make_norm(cfg.norm)[2](params["final_norm"], x)
        labels = tokens[:, 1:]
        mask = jnp.ones_like(labels, jnp.float32)
        nll, zl, n = self._xent(
            x, self._head_w(params).astype(cfg.adtype), labels, mask)
        loss = (nll + zl) / jnp.maximum(n, 1.0)
        return loss, {"nll": nll / jnp.maximum(n, 1.0), "tokens": n,
                      "moe_lb": jnp.zeros(()), "moe_z": jnp.zeros(())}

    # ---- serving ----
    def prefill(self, params, batch, gen_budget: int = 64, last_idx=None):
        """→ (last-token logits (B, Vp), decode state).

        ``last_idx`` (B,) int32: index of each prompt's last *real* token
        when prompts are right-padded to a shared (bucketed) length —
        logits are read at ``last_idx`` instead of the final position,
        ``pos`` starts at ``last_idx + 1``, and the KV cache is zeroed
        beyond ``last_idx`` so the pad tokens' KV can never be attended
        to (decode's one-hot ADD write at ``pos`` lands on a zero cell).
        ``last_idx=None`` keeps the original unbucketed behaviour.
        """
        cfg = self.cfg
        if cfg.family == "encdec":
            if last_idx is not None:
                raise ValueError("last_idx is not supported for encdec "
                                 "prefill (frame inputs are not padded)")
            return self._prefill_encdec(params, batch, gen_budget)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed_tokens(params, tokens, batch)
        x, caches = tfm.prefill_stack(params["blocks"], x,
                                      self._positions(B, S), self.stack)
        x = layers.make_norm(cfg.norm)[2](params["final_norm"], x)
        if last_idx is None:
            h_last = x[:, -1]
            pos = jnp.full((B,), S, jnp.int32)
        else:
            h_last = jnp.take_along_axis(
                x, last_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            pos = last_idx.astype(jnp.int32) + 1
        logits = h_last @ self._head_w(params).astype(cfg.adtype)

        keep = None
        if last_idx is not None:
            keep = (jnp.arange(S + gen_budget)[None, :]
                    <= last_idx[:, None])                      # (B, S+gb)

        def pad_kv(a):
            # (L, B, S, K, D) → (L, B, S + budget, K, D)
            a = jnp.pad(a, ((0, 0), (0, 0), (0, gen_budget), (0, 0), (0, 0)))
            if keep is not None:
                a = jnp.where(keep[None, :, :, None, None], a, 0)
            return a

        state = {}
        for key, val in caches.items():
            state[key] = jax.tree.map(pad_kv, val)
        # merge ssd states (prefill_stack only returns attn caches; rebuild full)
        full = tfm.init_stack_state(self.stack, B, S + gen_budget, cfg.adtype)
        for key in full:
            if key in state:
                full[key] = state[key]
        # TODO(ssm prefill): chunked-scan final states; for ssm/hybrid archs
        # prefill re-runs through decode in serve.py when exact states needed.
        return logits, {"cache": full, "pos": pos}

    def _prefill_encdec(self, params, batch, gen_budget: int):
        cfg = self.cfg
        frames = batch["frames"].astype(cfg.adtype)
        memory = encdec_mod.encode(params["encdec"],
                                   frontends.adapt(params["adapter"], frames)
                                   if cfg.frontend else frames, self.ecfg)
        B = frames.shape[0]
        state = encdec_mod.init_dec_state(params["encdec"], memory, self.ecfg,
                                          B, max(gen_budget, 1), cfg.adtype)
        bos = jnp.zeros((B,), jnp.int32)
        logits, state = self._serve_encdec(params, bos, state,
                                           jnp.zeros((B,), jnp.int32))
        return logits, {"cache": state, "pos": jnp.ones((B,), jnp.int32)}

    def serve_step(self, params, tokens: jax.Array, state: dict):
        """tokens: (B,) → (logits (B, Vp), state')."""
        cfg = self.cfg
        pos = state["pos"]
        if cfg.family == "encdec":
            logits, cache = self._serve_encdec(params, tokens, state["cache"], pos)
            return logits, {"cache": cache, "pos": pos + 1}
        x = layers.embed(params["embed"], tokens).astype(cfg.adtype)
        x = constrain(x, ("batch", None))
        x, cache = tfm.decode_stack(params["blocks"], x, state["cache"], pos,
                                    self.stack)
        x = layers.make_norm(cfg.norm)[2](params["final_norm"], x[:, None])[:, 0]
        logits = x @ self._head_w(params).astype(cfg.adtype)
        logits = constrain(logits, ("batch", "vocab"))
        return logits, {"cache": cache, "pos": pos + 1}

    def _serve_encdec(self, params, tokens, cache, pos):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens).astype(cfg.adtype)
        x, cache = encdec_mod.decode_step(params["encdec"], x, cache, pos,
                                          self.ecfg)
        x = layers.make_norm(cfg.norm)[2](params["final_norm"], x[:, None])[:, 0]
        logits = x @ self._head_w(params).astype(cfg.adtype)
        return logits, cache

    # ---- decode-state templates (for dry-run input_specs) ----
    def decode_state_shapes(self, batch: int, cache_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            def f():
                mem = jnp.zeros((batch, cache_len, cfg.d_model), cfg.adtype)
                return encdec_mod.init_dec_state(
                    self.init(jax.random.key(0))["encdec"], mem, self.ecfg,
                    batch, cache_len, cfg.adtype)
            cache = jax.eval_shape(f)
        else:
            cache = jax.eval_shape(
                lambda: tfm.init_stack_state(self.stack, batch, cache_len,
                                             cfg.adtype))
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
        return {"cache": cache, "pos": pos}

    def state_axes(self) -> dict:
        if self.cfg.family == "encdec":
            ax = encdec_mod.axes_dec_state()
        else:
            ax = tfm.axes_stack_state(self.stack)
        return {"cache": ax, "pos": ("batch",)}

    # ---- paged serving (block-table KV cache, DESIGN.md §9) ----
    @property
    def supports_paged(self) -> bool:
        return (self.cfg.family != "encdec" and self.stack is not None
                and all(b.mixer == "attn" for b in self.stack.pattern)
                and self.stack.kv_cache_dtype != "int8")

    def serve_step_paged(self, params, tokens: jax.Array, state: dict):
        """tokens: (B,) → (logits (B, Vp), state').  ``state`` holds the
        shared page pools plus per-slot ``block_table`` (B, max_pages) and
        ``pos`` (B,); pools are updated in place of the dense cache."""
        cfg = self.cfg
        if not self.supports_paged:
            raise ValueError(f"paged decode unsupported for {cfg.family}")
        pos = state["pos"]
        x = layers.embed(params["embed"], tokens).astype(cfg.adtype)
        x = constrain(x, ("batch", None))
        x, pools = tfm.decode_stack_paged(params["blocks"], x, state["pools"],
                                          state["block_table"], pos,
                                          self.stack)
        x = layers.make_norm(cfg.norm)[2](params["final_norm"], x[:, None])[:, 0]
        logits = x @ self._head_w(params).astype(cfg.adtype)
        logits = constrain(logits, ("batch", "vocab"))
        return logits, {"pools": pools, "block_table": state["block_table"],
                        "pos": pos + 1}

    def paged_state_shapes(self, batch: int, n_pages: int, page_size: int,
                           max_pages: int):
        cfg = self.cfg
        pools = jax.eval_shape(
            lambda: tfm.init_paged_stack_state(self.stack, n_pages, page_size,
                                               cfg.adtype))
        return {"pools": pools,
                "block_table": jax.ShapeDtypeStruct((batch, max_pages),
                                                    jnp.int32),
                "pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def paged_state_axes(self) -> dict:
        return {"pools": tfm.axes_paged_stack_state(self.stack),
                "block_table": ("batch", None), "pos": ("batch",)}


def build(cfg: LMCfg) -> Model:
    return Model(cfg)


def param_count(params) -> int:
    return sum(int(math.prod(p.shape)) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# per-family ModelGraph builders (meta-driven: pure arithmetic on the config)
# ---------------------------------------------------------------------------
#
# The segment-aware successor of core.cost_model's retired family
# if-ladder.  Matmul-dominant terms only (the granularity the roofline
# uses).  For the layer-homogeneous families (dense/moe/ssm/hybrid) the
# single "stack" segment computes the EXACT legacy expressions, so
# ``model_graph(cfg, b, s).workload_meta()`` is byte-identical to the
# retired ``lm_workload_meta`` if-ladder — tests/test_model_graph.py
# freezes that formula and guards the identity across every shipped
# config.
#
# The multimodal families get real graphs (and real pricing fixes):
#
# - ``vlm``: an atomic vision-frontend segment prices the patch adapter
#   (flops over the ``frontend_len`` prefix tokens + the d_model² adapter
#   params) that the legacy ladder silently dropped — vlm ≠ dense now.
# - ``encdec``: encoder and decoder become separate segments; encoder
#   self-attention scores are non-causal (no ×0.5), and decoder
#   cross-attention prices its KV projections over the SOURCE tokens plus
#   full (non-causal) q·k scores against the source memory — the
#   cross-attention KV term the flat meta never carried.


def model_graph(cfg: LMCfg, batch: int, seq: int,
                act_dtype_bytes: int = 2, param_dtype_bytes: int = 4,
                src_seq: int | None = None) -> ModelGraph:
    """Segment-aware workload description for one LMCfg.

    ``src_seq`` (encdec only): source-side sequence length fed to the
    encoder; defaults to ``seq`` (the target length).
    """
    E, V, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    T = batch * seq
    hd = cfg.hd
    pdb = param_dtype_bytes

    def attn_flops(t=T, kv=seq, causal=True) -> float:
        H, K = cfg.n_heads, cfg.n_kv_heads
        proj = 2 * t * E * (H * hd) + 2 * 2 * t * E * (K * hd) \
            + 2 * t * (H * hd) * E
        scores = 2 * t * kv * H * hd * 2 * (0.5 if causal else 1.0)
        return proj + scores

    def cross_attn_flops(t_q, t_kv, kv_len) -> float:
        # q/o projections ride the query tokens; k/v projections ride the
        # SOURCE tokens (computed once per layer); scores are full rank —
        # nothing causal about attending to an encoded source
        H, K = cfg.n_heads, cfg.n_kv_heads
        proj = 2 * t_q * E * (H * hd) + 2 * 2 * t_kv * E * (K * hd) \
            + 2 * t_q * (H * hd) * E
        scores = 2 * t_q * kv_len * H * hd * 2
        return proj + scores

    def dense_mlp_flops(t=T) -> float:
        mult = 3 if cfg.gated_mlp else 2
        return 2 * t * E * cfg.d_ff * mult

    def moe_mlp_flops() -> float:
        mult = 3
        routed = 2 * T * E * cfg.d_ff_expert * mult * cfg.top_k
        shared = 2 * T * E * cfg.d_ff_expert * mult * cfg.n_shared
        router = 2 * T * E * cfg.n_experts
        return routed + shared + router

    def ssd_flops() -> float:
        scfg = cfg.ssd_cfg()
        H, P, N, C = scfg.n_heads, scfg.headdim, scfg.d_state, scfg.chunk
        proj = 2 * T * E * (2 * H * P + 2 * N + H) + 2 * T * H * P * E
        intra = 2 * T * C * H * (N + P)
        inter = 2 * T * H * P * N * 2
        return proj + intra + inter

    def attn_params():
        return E * (cfg.n_heads * hd) * 2 + E * (cfg.n_kv_heads * hd) * 2

    def mlp_params():
        return E * cfg.d_ff * (3 if cfg.gated_mlp else 2)

    def moe_params():
        return (cfg.n_experts + cfg.n_shared) * E * cfg.d_ff_expert * 3 \
            + E * cfg.n_experts

    def ssd_params():
        scfg = cfg.ssd_cfg()
        return E * scfg.d_inner * 3 + 2 * E * scfg.d_state + E * scfg.n_heads

    def adapter_segment(name: str, prefix_tokens: int) -> SegmentMeta:
        # frontends.init_adapter: one d_model×d_model projection + bias
        return SegmentMeta(
            name=name, n_layers=1, atomic=True,
            fwd_flops=float(2 * prefix_tokens * E * E),
            param_bytes=float((E * E + E) * pdb),
            act_bytes_per_layer=float(prefix_tokens * E
                                      * act_dtype_bytes * 4))

    act_per_layer = T * E * act_dtype_bytes * 4   # x + 3 intermediates

    def stack_segment(name: str, n_attn: int, n_ssd: int, n_moe: int,
                      n_dense: int, n_layers: int) -> SegmentMeta:
        flops = (n_attn * attn_flops() + n_ssd * ssd_flops()
                 + n_moe * moe_mlp_flops() + n_dense * dense_mlp_flops())
        p_count = (n_attn * attn_params() + n_ssd * ssd_params()
                   + n_moe * moe_params() + n_dense * mlp_params())
        expert_param_bytes = 0.0
        moe_dispatch_bytes = 0.0
        if n_moe:
            expert_param_bytes = (n_moe * cfg.n_experts * E * cfg.d_ff_expert
                                  * 3 * pdb)
            moe_dispatch_bytes = (T * cfg.top_k * cfg.capacity_factor
                                  * E * act_dtype_bytes)
        return SegmentMeta(
            name=name, n_layers=n_layers,
            fwd_flops=float(flops), param_bytes=float(p_count * pdb),
            act_bytes_per_layer=float(act_per_layer),
            n_experts=int(cfg.n_experts if n_moe else 0),
            n_moe_layers=int(n_moe),
            expert_param_bytes=float(expert_param_bytes),
            moe_dispatch_bytes=float(moe_dispatch_bytes))

    if cfg.family == "dense":
        segments = (stack_segment("stack", L, 0, 0, L, max(L, 1)),)
    elif cfg.family == "moe":
        n_moe = L // cfg.moe_every
        segments = (stack_segment("stack", L, 0, n_moe, L - n_moe,
                                  max(L, 1)),)
    elif cfg.family == "ssm":
        segments = (stack_segment("stack", 0, L, 0, 0, max(L, 1)),)
    elif cfg.family == "hybrid":
        n_attn = L // cfg.attn_period
        n_moe = L // 2
        segments = (stack_segment("stack", n_attn, L - n_attn, n_moe,
                                  L - n_moe, max(L, 1)),)
    elif cfg.family == "vlm":
        segments = (adapter_segment("vision-frontend",
                                    batch * cfg.frontend_len),
                    stack_segment("decoder", L, 0, 0, L, max(L, 1)))
    elif cfg.family == "encdec":
        s_src = seq if src_seq is None else src_seq
        t_src = batch * s_src
        n_enc, n_dec = cfg.n_enc_layers, cfg.n_dec_layers
        enc_flops = n_enc * (attn_flops(t_src, s_src, causal=False)
                             + dense_mlp_flops(t_src))
        dec_flops = n_dec * (attn_flops(T, seq, causal=True)
                             + cross_attn_flops(T, t_src, s_src)
                             + dense_mlp_flops(T))
        enc_params = n_enc * (attn_params() + mlp_params())
        dec_params = n_dec * (2 * attn_params() + mlp_params())
        enc_act = t_src * E * act_dtype_bytes * 4
        enc = SegmentMeta(name="encoder", n_layers=max(n_enc, 1),
                          fwd_flops=float(enc_flops),
                          param_bytes=float(enc_params * pdb),
                          act_bytes_per_layer=float(enc_act))
        dec = SegmentMeta(name="decoder", n_layers=max(n_dec, 1),
                          fwd_flops=float(dec_flops),
                          param_bytes=float(dec_params * pdb),
                          act_bytes_per_layer=float(act_per_layer))
        segments = (enc, dec)
        if cfg.frontend:
            segments = (adapter_segment(f"{cfg.frontend}-frontend", t_src),
                        ) + segments
    else:
        raise ValueError(f"unknown model family {cfg.family!r}")

    head = 2 * T * E * V
    embed = V * E * (1 if cfg.tie_embeddings else 2)
    return ModelGraph(
        name=cfg.name, segments=segments, batch=batch,
        extra_fwd_flops=float(head),
        extra_param_bytes=float(embed * pdb),
        logits_bytes=float(T * V * 4),
        head_param_bytes=float(E * V * pdb))

"""Core neural-net layers shared by every assigned architecture.

Functional style: ``init_*`` functions build param pytrees (plain dicts);
``axes_*`` functions build *logical-axis* pytrees with the same treedef whose
leaves are tuples of logical dimension names.  The logical names are Whale's
"Multi-Dimension" abstraction: the planner (``repro.core.sharding``) maps them
onto physical mesh axes per strategy, so models never mention mesh axes.

Logical axis vocabulary
-----------------------
  layers      stacked scan dimension (never sharded)
  embed       d_model
  vocab       vocabulary / class dimension (operator-split target, paper Fig 4)
  q_heads     attention query heads (tensor-parallel target)
  kv_heads    attention kv heads
  head_dim    per-head feature dim
  mlp         feed-forward hidden dim (tensor-parallel target)
  experts     MoE expert dim (expert-parallel target)
  ssm_heads   mamba2 SSD head dim
  conv / state / proj  mamba internals
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree
Axes = Any    # same-treedef pytree of tuples of logical-axis names


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, stddev):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def dense_init(key, in_dim: int, shape: tuple, dtype) -> jax.Array:
    """Fan-in scaled normal init (truncation omitted; irrelevant for systems work)."""
    return _normal(key, shape, dtype, 1.0 / math.sqrt(max(in_dim, 1)))


def embed_init(key, shape: tuple, dtype) -> jax.Array:
    return _normal(key, shape, dtype, 1.0)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def axes_rmsnorm() -> Axes:
    return {"scale": ("embed",)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def axes_layernorm() -> Axes:
    return {"scale": ("embed",), "bias": ("embed",)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(kind: str):
    if kind == "rms":
        return init_rmsnorm, axes_rmsnorm, rmsnorm
    if kind == "ln":
        return init_layernorm, axes_layernorm, layernorm
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               mrope_sections: tuple | None = None) -> jax.Array:
    """Rotate ``x`` (..., S, H, D) by ``positions``.

    positions: (B, S) for standard RoPE, or (B, 3, S) for M-RoPE
    (temporal/height/width sections, qwen2-vl style).  With M-RoPE the
    frequency bands are partitioned into ``mrope_sections`` (summing to D//2)
    and each band uses its own position component.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                    # (d/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv      # (B, S, d/2)
    else:
        assert positions.ndim == 3, "M-RoPE wants (B, 3, S) positions"
        parts = []
        off = 0
        for i, sec in enumerate(mrope_sections):
            p = positions[:, i, :, None].astype(jnp.float32)      # (B, S, 1)
            parts.append(p * inv[off:off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)                     # (B, S, d/2)
    cos = jnp.cos(ang)[..., None, :]                              # (B, S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs: SwiGLU / GeGLU / plain
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, d_model, (d_model, d_ff), dtype),
        "wo": dense_init(k2, d_ff, (d_ff, d_model), dtype),
    }
    if gated:
        p["wg"] = dense_init(k3, d_model, (d_model, d_ff), dtype)
    return p


def axes_mlp(gated: bool = True) -> Axes:
    a = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if gated:
        a["wg"] = ("embed", "mlp")
    return a


_ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    h = x @ params["wi"].astype(x.dtype)
    if "wg" in params:
        h = _ACTS[act](x @ params["wg"].astype(x.dtype)) * h
    else:
        h = _ACTS[act](h)
    return h @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings + LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    # 1/sqrt(d) init keeps tied-head logits O(1); a norm layer follows the
    # embedding in every family, so the small output scale is harmless.
    return {"table": _normal(key, (vocab, d_model), dtype,
                             1.0 / math.sqrt(d_model))}


def axes_embedding() -> Axes:
    return {"table": ("vocab", "embed")}


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Project activations to (padded) vocab logits with the transposed table."""
    return x @ params["table"].T.astype(x.dtype)


def init_lm_head(key, d_model: int, vocab: int, dtype) -> Params:
    return {"w": dense_init(key, d_model, (d_model, vocab), dtype)}


def axes_lm_head() -> Axes:
    return {"w": ("embed", "vocab")}


def lm_head(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad vocab to an MXU/shard-friendly multiple (Megatron-style)."""
    return ((vocab + multiple - 1) // multiple) * multiple

"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.

Single pod: 16×16 = 256 chips, axes (data, model) — the `model` axis is the
mesh minor axis so tensor-parallel collectives ride contiguous ICI links.
Multi-pod: 2×16×16 = 512 chips with the `pod` axis outermost — under the
default hybrid strategy only gradient/FSDP collectives cross the
(lower-bandwidth, DCN) pod boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None, *,
                   stage: int = 1, axes_order=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // (model * stage)
    if stage > 1:
        return jax.make_mesh((stage, data, model), ("stage", "data", "model"))
    return jax.make_mesh((data, model), axes_order)

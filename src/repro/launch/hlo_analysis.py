"""Post-SPMD HLO accounting: trip-count-aware collective byte volumes.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
scanned program (layer scan, micro-batch accumulation, loss chunking — i.e.
every production training step) under-reports FLOPs/bytes/collectives by the
trip count.  This module parses the optimized HLO text, recovers each loop's
trip count from its condition computation (the `constant(N)` bound the
induction variable is compared against), and accumulates per-collective byte
volumes recursively through while/call/conditional bodies.

Used by the dry-run/roofline harness for the *collective* term, which is the
one quantity only the post-SPMD artifact knows (the SPMD partitioner decides
which collectives exist).  FLOPs use the trip-count-exact jaxpr walk
(:func:`repro.core.ir.jaxpr_flops`) instead — see EXPERIMENTS.md §Roofline
for the methodology note.

Ring-collective cost accounting per device (n = replica-group size):
  all-reduce          2·(n−1)/n · result bytes
  all-gather          (n−1)/n   · result bytes   (result = gathered tensor)
  reduce-scatter      (n−1)     · result bytes   (result = one shard)
  all-to-all          (n−1)/n   · result bytes
  collective-permute  1         · result bytes
"""
from __future__ import annotations

import re
from typing import Mapping

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.+-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.+-]+), body=%?([\w.+-]+)")
_CALL_RE = re.compile(r"\bcall\(.*?\), to_apply=%?([\w.+-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\] constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s4": 1, "u4": 1,
                # complex
                "c64": 8, "c128": 16,
                # fp8 family (XLA spells several variants)
                "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1,
                "f8e4m3b11fnz": 1, "f8e4m3b11fnuz": 1,
                "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
                # sub-byte packed types: count 1 byte/elem, the conservative
                # upper bound (XLA pads sub-byte buffers in most layouts)
                "f4e2m1fn": 1, "s2": 1, "u2": 1}

# Types that occupy no HBM: tokens order effects, opaque is a handle.
_ZERO_SIZED = {"token", "opaque"}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def parse_computations(hlo: str) -> dict:
    """HLO module text → {computation name: [body lines]}."""
    comps: dict = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m and "->" in line:
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def dtype_bytes(dt: str) -> int:
    """Bytes per element of an HLO dtype token.

    Raises on anything unrecognised instead of silently assuming 4 bytes —
    a bf16 or f8 buffer mis-sized that way would skew every bandwidth the
    calibrator fits from these byte counts by 2–8×.
    """
    if dt in _ZERO_SIZED:
        return 0
    try:
        return _DTYPE_BYTES[dt]
    except KeyError:
        raise ValueError(
            f"unknown HLO dtype {dt!r}: add it to hlo_analysis._DTYPE_BYTES "
            f"(guessing a width would silently skew calibrated bandwidths)"
        ) from None


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * dtype_bytes(dt)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:                                    # iota [n_groups, group_size]
        return int(m.group(2))
    return default


def trip_count(cond_lines: list) -> int:
    """Loop bound from the condition computation: max s32 constant (the
    induction bound; conservative fallback 1 when nothing is found)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _direct_collectives(lines: list, n_dev: int) -> dict:
    out = dict.fromkeys(COLLECTIVE_KINDS, 0.0)
    counts = dict.fromkeys(COLLECTIVE_KINDS, 0)
    for line in lines:
        m = _COLL_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        kind = m.group(2)
        if kind == "all-gather" and m.group(3):
            # all-gather-start result tuple includes the operand copy; halve
            b = b / 2
        n = _group_size(line, n_dev)
        if n <= 1:
            continue
        if kind == "all-reduce":
            moved = 2.0 * (n - 1) / n * b
        elif kind == "all-gather":
            moved = (n - 1) / n * b
        elif kind == "reduce-scatter":
            moved = float(n - 1) * b
        elif kind == "all-to-all":
            moved = (n - 1) / n * b
        else:
            moved = float(b)
        out[kind] += moved
        counts[kind] += 1
    out["_counts"] = counts
    return out


def collective_bytes(hlo: str, n_dev: int) -> dict:
    """Per-device collective bytes for the whole module, loops unrolled.

    Returns {kind: bytes, 'total': float, 'counts': {kind: static op count}}.
    """
    comps = parse_computations(hlo)
    memo: dict = {}

    def visit(name: str, stack: frozenset) -> Mapping:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return dict.fromkeys(COLLECTIVE_KINDS, 0.0)
        lines = comps[name]
        acc = _direct_collectives(lines, n_dev)
        acc.pop("_counts", None)
        stack = stack | {name}
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = trip_count(comps.get(cond, []))
                sub = visit(body, stack)
                for k in COLLECTIVE_KINDS:
                    acc[k] += trips * sub[k]
                continue
            m = _CALL_RE.search(line)
            if m:
                sub = visit(m.group(1), stack)
                for k in COLLECTIVE_KINDS:
                    acc[k] += sub[k]
            m = _COND_BRANCH_RE.search(line)
            if m:
                branches = [b.strip().lstrip("%") for b in
                            m.group(1).split(",")]
                subs = [visit(b, stack) for b in branches]
                for k in COLLECTIVE_KINDS:
                    acc[k] += max((s[k] for s in subs), default=0.0)
        memo[name] = acc
        return acc

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:                         # fall back: flat accounting
        flat = _direct_collectives(hlo.splitlines(), n_dev)
        counts = flat.pop("_counts")
        flat["total"] = sum(flat.values())
        flat["counts"] = counts
        return flat

    total = visit(entry, frozenset())
    counts = _direct_collectives(
        [l for ls in comps.values() for l in ls], n_dev).pop("_counts")
    result = dict(total)
    result["total"] = sum(total[k] for k in COLLECTIVE_KINDS)
    result["counts"] = counts
    return result


# ---------------------------------------------------------------------------
# HBM traffic: trip-aware materialisation accounting
# ---------------------------------------------------------------------------

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%[\w.+-]+\s*=\s*"
                    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                    r"([\w-]+)")

# ops that produce no real HBM materialisation
_FREE_OPS = {"get-tuple-element", "tuple", "bitcast", "constant", "iota",
             "after-all", "partition-id", "replica-id", "parameter",
             "while", "call", "conditional"}   # counted via their bodies

_FUSION_CALLS_RE = re.compile(r"\bfusion\(.*?calls=%?([\w.+-]+)")


def _fusion_write_bytes(result_bytes: float, fusion_comp: list) -> float:
    """Write volume of one fusion.  In-place dynamic-update-slice fusions
    write only the updated slice: charge the sum of the fusion's *non-
    largest* parameters (≈ the update operands) instead of the aliased
    full-buffer result."""
    has_dus = any("dynamic-update-slice(" in l or "scatter(" in l
                  for l in fusion_comp)
    if not has_dus:
        return result_bytes
    params = sorted((_shape_bytes(m.group(1))
                     for l in fusion_comp
                     if (m := _OP_RE.match(l)) and m.group(2) == "parameter"),
                    reverse=True)
    if len(params) <= 1:
        return result_bytes
    slice_bytes = float(sum(params[1:]))
    return min(result_bytes, slice_bytes)


def hbm_traffic_bytes(hlo: str) -> float:
    """Per-device HBM traffic estimate for the module, loops unrolled.

    Model: each top-level (post-fusion) value is written to HBM once and
    read ~once (×2); fusion internals stay in VMEM/registers; in-place
    update fusions write the slice, not the buffer; ENTRY parameters are
    read once; while-body parameters are the resident carry (no traffic —
    the slices read from them are separate, counted ops)."""
    comps = parse_computations(hlo)
    memo: dict = {}

    def direct(lines: list) -> float:
        total = 0.0
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            shape_str, op = m.group(1), m.group(2)
            if op in _FREE_OPS:
                continue
            b = float(_shape_bytes(shape_str))
            if op == "fusion":
                fm = _FUSION_CALLS_RE.search(line)
                body = comps.get(fm.group(1), []) if fm else []
                b = _fusion_write_bytes(b, body)
            elif op in ("dynamic-update-slice", "scatter"):
                b = 0.0      # unfused DUS: slice operands counted upstream
            total += 2.0 * b
        return total

    def visit(name: str, stack: frozenset) -> float:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0
        lines = comps[name]
        acc = direct(lines)
        stack = stack | {name}
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                trips = trip_count(comps.get(m.group(1), []))
                acc += trips * visit(m.group(2), stack)
                continue
            m = _CALL_RE.search(line)
            if m:
                acc += visit(m.group(1), stack)
            m = _COND_BRANCH_RE.search(line)
            if m:
                branches = [b.strip().lstrip("%") for b in
                            m.group(1).split(",")]
                acc += max((visit(b, stack) for b in branches), default=0.0)
        memo[name] = acc
        return acc

    entry = None
    entry_params = 0.0
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        return direct(hlo.splitlines())
    for line in comps.get(entry, []):
        m = _OP_RE.match(line)
        if m and m.group(2) == "parameter":
            entry_params += _shape_bytes(m.group(1))
    return visit(entry, frozenset()) + entry_params

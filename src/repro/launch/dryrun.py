import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production ExecutionPlan (hybrid strategy:
DP(+ZeRO-3) over data axes × operator split over the model axis — the
paper's Case 2 generalised), lowers the real step function (train step incl.
optimizer update / prefill / serve step) against ShapeDtypeStruct inputs (no
allocation), compiles it for the 16×16 = 256-chip pod or the 2×16×16 =
512-chip multi-pod mesh, and extracts:

- ``memory_analysis()``     → bytes/device (proves the cell fits HBM)
- ``cost_analysis()``       → per-device HLO FLOPs + HBM bytes
- the post-SPMD HLO text    → per-collective byte volumes (the roofline's
                              collective term; see ``collective_bytes``)

Results append to a JSONL file consumed by ``benchmarks/roofline.py`` and
EXPERIMENTS.md.  Any failure here (sharding mismatch, OOM at compile,
unsupported collective) is a bug in the system, not in the harness.

Usage::

    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.configs import shapes as sh
from repro.core.cost_model import TPU_V5E, StrategySpec
from repro.core.ir import jaxpr_flops
from repro.core.planner import compile_plan
from repro.launch.hlo_analysis import collective_bytes, hbm_traffic_bytes
from repro.launch.mesh import make_production_mesh
from repro.models.lm import build, param_count
from repro.optim.optimizer import adamw

DEFAULT_OUT = "bench_out/dryrun.jsonl"


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def production_strategy(mesh, *, micro_batches: int = 8,
                        zero: int = 3,
                        schedule: str = "gpipe") -> StrategySpec:
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    return StrategySpec(dp=dp, tp=mesh.shape.get("model", 1),
                        pp=mesh.shape.get("stage", 1),
                        micro_batches=micro_batches, zero=zero,
                        vocab_split=True, schedule=schedule)


# per-arch production train settings: the ≥50B-param archs need factored
# second moments + deeper micro-batching to fit 16 GB HBM (DESIGN.md §5)
TRAIN_OVERRIDES = {
    "grok-1-314b": dict(optimizer="adafactor", micro_batches=16),
    "jamba-v0.1-52b": dict(optimizer="adafactor", micro_batches=16),
}


def model_flops_for_cell(cfg, model, cell) -> float:
    """MODEL_FLOPS: 6·N·D train (N = active params), 2·N·D decode/prefill."""
    n_active = _active_params(cfg, model)
    if cell.step == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.step == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch          # one token / seq


def _active_params(cfg, model) -> float:
    n = param_count(model.param_shapes())
    if cfg.n_experts and cfg.top_k:
        # subtract the inactive routed-expert fraction
        F, E = cfg.d_ff_expert, cfg.d_model
        per_expert = 3 * E * F
        if cfg.family == "moe":
            n_moe_layers = cfg.n_layers // cfg.moe_every
        else:                                  # hybrid: MoE every other layer
            n_moe_layers = cfg.n_layers // 2
        inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
        n -= inactive
    return float(n)


def model_min_bytes_for_cell(cfg, model, cell, *, micro_batches: int,
                             state_bytes: float = 0.0) -> float:
    """Analytic minimum HBM traffic (global, all devices) — the memory-
    roofline floor the achieved memory term is compared against.

    train:   weights streamed bf16 fwd+bwd+remat per micro-batch, optimizer
             f32 read+write + bf16 moments, activations r+w ×3 passes
    prefill: weights once (bf16), activations r+w, KV write
    decode:  weights once (bf16), full decode state read + write
    """
    P = param_count(model.param_shapes())
    L = max(cfg.n_layers, 1) if cfg.family != "encdec" else (
        cfg.n_enc_layers + cfg.n_dec_layers)
    T = cell.global_batch * cell.seq_len
    E = cfg.d_model
    if cell.step == "train":
        weights = 3.0 * micro_batches * P * 2
        opt = P * (4 + 4 + 4 + 2 * 4)          # f32 r+w, grads, moments
        acts = 6.0 * L * T * E * 2
        return weights + opt + acts
    if cell.step == "prefill":
        return 2.0 * P + 4.0 * L * T * E * 2 + state_bytes
    # decode: one token per sequence
    return 2.0 * P + 2.0 * state_bytes + 4.0 * L * cell.global_batch * E * 2


def _bf16_shapes(tree):
    """Serving-dtype parameter stand-ins (bf16 checkpoints — production)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype), tree)


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             micro_batches: int = 8, overrides: dict | None = None,
             strategy: StrategySpec | None = None,
             optimizer: str | None = None,
             context_parallel: bool = False,
             shard_grads: bool = False,
             mesh_shape: tuple | None = None,
             schedule: str = "gpipe",
             tag: str = "") -> dict:
    t_start = time.time()
    if mesh_shape is not None:               # perf-iteration mesh override
        names = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = jax.make_mesh(mesh_shape, names)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build(cfg)
    cell = sh.SHAPES[shape]
    rec = {"arch": arch, "shape": shape, "mesh": "x".join(
        str(s) for s in mesh.devices.shape), "multi_pod": multi_pod,
        "step": cell.step, "tag": tag}

    ok, reason = sh.applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    tov = TRAIN_OVERRIDES.get(arch, {}) if cell.step == "train" else {}
    micro = micro_batches if micro_batches != 8 else \
        tov.get("micro_batches", micro_batches)
    opt_name = optimizer or tov.get("optimizer", "adamw")
    if cell.step == "train":
        # per-micro-batch global batch must still divide over the dp shards
        dp_sz = 1
        for a in ("pod", "data"):
            dp_sz *= mesh.shape.get(a, 1)
        while micro > 1 and cell.global_batch % (micro * dp_sz):
            micro //= 2
    strat = strategy or production_strategy(mesh, micro_batches=micro,
                                            schedule=schedule)
    rec["schedule"] = strat.schedule
    from repro.core.sharding import hybrid_rules
    rules = hybrid_rules(mesh, fsdp=strat.zero >= 3,
                         context_parallel=context_parallel)
    if not strat.vocab_split:
        rules.rules["vocab"] = None
    plan = compile_plan(model, mesh, strategy=strat, rules=rules)

    state_bytes = 0.0
    with mesh:
        if cell.step == "train":
            if opt_name == "adafactor":
                from repro.optim.optimizer import adafactor
                opt = adafactor(lr=1e-4)
            else:
                opt = adamw(lr=1e-4, moment_dtype="bfloat16")
            bspecs = sh.batch_specs(model, cell)
            fn = plan.jit_train_step(opt, bspecs,
                                     micro_batches=strat.micro_batches,
                                     shard_grads=shard_grads)
            oshapes = jax.eval_shape(opt.init, plan.param_shapes)
            args = (plan.param_shapes, oshapes, bspecs,
                    jax.ShapeDtypeStruct((), jnp.int32))
            flop_fn = plan.train_step_fn(opt,
                                         micro_batches=strat.micro_batches)
        elif cell.step == "prefill":
            bspecs = sh.batch_specs(model, cell)
            fn = plan.jit_prefill(bspecs, gen_budget=0)
            args = (_bf16_shapes(plan.param_shapes), bspecs)
            flop_fn = lambda p, b: model.prefill(p, b, gen_budget=0)
        else:                                   # decode
            specs = sh.decode_specs(model, cell)
            fn = plan.jit_serve_step(cell.global_batch, cell.seq_len,
                                     donate=True)
            args = (_bf16_shapes(plan.param_shapes), specs["tokens"],
                    specs["state"])
            flop_fn = model.serve_step
            state_bytes = sum(
                s.size * s.dtype.itemsize
                for s in jax.tree.leaves(specs["state"]))
        t0 = time.time()
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        # trip-count-exact logical FLOPs (jaxpr walk; global shapes)
        flops_global = float(jaxpr_flops(jax.make_jaxpr(flop_fn)(*args).jaxpr))

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # jax<=0.4 returns [dict] per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, n_dev)
    hbm_dev = hbm_traffic_bytes(hlo)

    hw = TPU_V5E
    flops_dev = flops_global / n_dev
    t_comp = flops_dev / hw.peak_flops
    t_mem = hbm_dev / hw.hbm_bw
    t_coll = coll["total"] / hw.link_bw["fast"]
    mf = model_flops_for_cell(cfg, model, cell)
    min_bytes = model_min_bytes_for_cell(cfg, model, cell,
                                         micro_batches=strat.micro_batches,
                                         state_bytes=state_bytes)
    t_ideal = max(mf / n_dev / hw.peak_flops,
                  min_bytes / n_dev / hw.hbm_bw)

    rec.update(
        status="ok",
        strategy=strat.describe(),
        optimizer=opt_name if cell.step == "train" else None,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        total_s=round(time.time() - t_start, 2),
        mem_args_gib=ma.argument_size_in_bytes / 2**30,
        mem_temp_gib=ma.temp_size_in_bytes / 2**30,
        mem_out_gib=ma.output_size_in_bytes / 2**30,
        flops_per_dev=flops_dev,
        hbm_bytes_per_dev=hbm_dev,
        cost_analysis_flops_raw=float(ca.get("flops", 0.0)),
        cost_analysis_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=coll["total"],
        coll_detail={k: v for k, v in coll.items() if k != "counts"},
        coll_counts=coll["counts"],
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=max([("compute", t_comp), ("memory", t_mem),
                        ("collective", t_coll)], key=lambda kv: kv[1])[0],
        model_flops=mf,
        model_min_bytes=min_bytes,
        model_flops_hlo_ratio=mf / max(flops_global, 1.0),
        t_ideal=t_ideal,
        roofline_frac=t_ideal / max(max(t_comp, t_mem, t_coll), 1e-30),
        hlo_len=len(hlo),
    )
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _append(rec: dict, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def _run_all(args) -> int:
    """Each cell in a fresh subprocess (isolates compile memory/failures)."""
    cells = [(a, s) for a in ARCH_NAMES for s in sh.SHAPES]
    failures = 0
    for arch, shape in cells:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", args.out,
               "--micro-batches", str(args.micro_batches)]
        if args.multi_pod:
            cmd.append("--multi-pod")
        t0 = time.time()
        p = subprocess.run(cmd, capture_output=True, text=True)
        dt = time.time() - t0
        if p.returncode:
            failures += 1
            _append({"arch": arch, "shape": shape,
                     "multi_pod": args.multi_pod, "status": "failed",
                     "error": p.stderr[-2000:]}, args.out)
            print(f"FAIL  {arch:22s} {shape:12s} ({dt:5.1f}s)")
            print(p.stderr[-800:])
        else:
            tail = p.stdout.strip().splitlines()
            print(f"ok    {arch:22s} {shape:12s} ({dt:5.1f}s)  "
                  f"{tail[-1] if tail else ''}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(sh.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--micro-batches", type=int, default=8)
    ap.add_argument("--out", default=DEFAULT_OUT)
    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) ---
    ap.add_argument("--context-parallel", action="store_true",
                    help="shard q-seq over the model axis (heads∤tp archs)")
    ap.add_argument("--shard-grads", action="store_true",
                    help="constrain grads to param shardings (reduce-scatter)")
    ap.add_argument("--set", default="",
                    help="comma k=v LMCfg overrides (attn_bwd_remat=True,...)")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh, e.g. 32x8 (data×model) — perf knob")
    ap.add_argument("--no-vocab-split", action="store_true",
                    help="ablate the paper's Fig-4 split-classifier technique")
    ap.add_argument("--schedule", choices=("gpipe", "1f1b"), default="gpipe",
                    help="pipeline schedule recorded on the strategy and in "
                         "the JSONL (production meshes have no stage axis, "
                         "so it prices nothing until a pp>1 mesh is used; "
                         "repro.core.schedule)")
    ap.add_argument("--tag", default="", help="label for the JSONL record")
    args = ap.parse_args()

    if args.all:
        sys.exit(1 if _run_all(args) else 0)

    overrides = {}
    if args.set:
        from repro.configs import get_config as _gc
        ref = _gc(args.arch)
        for pair in args.set.split(","):
            k, v = pair.split("=")
            cur = getattr(ref, k)
            overrides[k] = (v == "True") if isinstance(cur, bool) else \
                type(cur)(v)

    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x")) \
        if args.mesh_shape else None
    strategy = None
    if args.no_vocab_split:
        base = (jax.make_mesh(mesh_shape,
                              ("pod", "data", "model")[-len(mesh_shape):])
                if mesh_shape else make_production_mesh(
                    multi_pod=args.multi_pod))
        strategy = dataclasses.replace(
            production_strategy(base, micro_batches=args.micro_batches,
                                schedule=args.schedule),
            vocab_split=False)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   micro_batches=args.micro_batches, overrides=overrides,
                   context_parallel=args.context_parallel,
                   shard_grads=args.shard_grads, mesh_shape=mesh_shape,
                   schedule=args.schedule, strategy=strategy, tag=args.tag)
    _append(rec, args.out)
    if rec["status"] == "ok":
        print(f"{rec['arch']} {rec['shape']} mesh={rec['mesh']} "
              f"temp={rec['mem_temp_gib']:.2f}GiB "
              f"args={rec['mem_args_gib']:.2f}GiB "
              f"compute={rec['t_compute']*1e3:.1f}ms "
              f"mem={rec['t_memory']*1e3:.1f}ms "
              f"coll={rec['t_collective']*1e3:.1f}ms "
              f"bott={rec['bottleneck']} rf={rec['roofline_frac']:.3f}")
    else:
        print(f"{rec['arch']} {rec['shape']}: {rec['status']} "
              f"({rec.get('reason', '')})")


if __name__ == "__main__":
    main()

"""Batched serving driver: continuous batching over prefill + decode.

A minimal production-shaped server loop (no network layer — requests come
from a queue/generator): requests are admitted into a fixed-size batch of
decode *slots*; each slot holds one sequence's position + KV/SSD state
column.  Prefill runs per admitted request (right-sized jit cache keyed by
padded length); decode advances all active slots in lock-step with the
planner's sharded ``serve_step``.  Finished slots (EOS or budget) are
recycled — the standard continuous-batching pattern adapted to JAX's static
shapes (state buffers are allocated once at ``max_len``).

Usage (CPU sanity)::

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --requests 8 --batch-slots 4 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.core.planner import compile_plan
from repro.launch.train import parse_mesh


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, model, plan, *, batch_slots: int, max_len: int,
                 eos_id: int = 1):
        self.model = model
        self.plan = plan
        self.mesh = plan.mesh
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        with self.mesh:
            self.serve_step = plan.jit_serve_step(batch_slots, max_len,
                                                  donate=False)
            specs = plan.state_specs(batch_slots, max_len)
            self.state_shardings = jax.tree.map(
                lambda s: jax.NamedSharding(self.mesh, s), specs,
                is_leaf=lambda t: isinstance(t, jax.sharding.PartitionSpec))
            state = jax.tree.map(
                lambda s, sh: jnp.zeros(s.shape, s.dtype, device=sh),
                model.decode_state_shapes(batch_slots, max_len),
                self.state_shardings)
        self.state = state
        self.tokens = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: list = [None] * batch_slots
        self.steps = 0

    # --- admission: run prefill for one request into one slot ---
    def admit(self, params, req: Request, slot: int) -> None:
        """Prefill ``req`` into ``slot``.  A request that finishes at
        admission (EOS from prefill, or a one-token budget) is marked
        ``done`` and never occupies the slot — the caller collects it."""
        prompt = jnp.asarray(req.prompt)[None]           # (1, S)
        with self.mesh:
            logits, st = self.model.prefill(
                params, {"tokens": prompt},
                gen_budget=self.max_len - prompt.shape[1])
        tok = int(jnp.argmax(logits[0, :self.model.cfg.vocab]))
        req.out_tokens.append(tok)
        if tok == self.eos or len(req.out_tokens) >= req.max_new:
            req.done = True
            return
        # batch=1 prefill state → write into slot via dynamic_update_slice,
        # then re-place on the serving shardings (admission is off the
        # decode hot path)
        self.state = jax.device_put(
            _write_slot(self.state, st, slot, self.model.state_axes()),
            self.state_shardings)
        self.tokens = self.tokens.at[slot].set(tok)
        self.slots[slot] = req

    def step(self, params) -> list:
        """Advance every active slot one token; returns the requests that
        finished this step.

        Finished requests must be *returned*, not just freed: the slot is
        recycled in the same pass (``self.slots[b] = None``), so a caller
        scanning ``server.slots`` afterwards can never observe a done
        request — the pre-fix driver collected exactly that way and its
        ``done`` list stayed empty forever.
        """
        with self.mesh:
            logits, self.state = self.serve_step(params, self.tokens,
                                                 self.state)
        nxt = jnp.argmax(logits[:, :self.model.cfg.vocab], axis=-1)
        self.tokens = nxt.astype(jnp.int32)
        self.steps += 1
        finished = []
        for b, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(nxt[b])
            req.out_tokens.append(tok)
            if tok == self.eos or len(req.out_tokens) >= req.max_new:
                req.done = True
                self.slots[b] = None          # recycle the slot …
                finished.append(req)          # … but hand the request back
        return finished

    def free_slot(self) -> int | None:
        for b, s in enumerate(self.slots):
            if s is None:
                return b
        return None


def _write_slot(state, st_one, slot: int, axes) -> dict:
    """Write a batch-1 prefill state into slot ``slot`` of the batch state."""
    def one(big, small, names):
        names = tuple(names)
        if "batch" not in names:
            return big
        b_ax = names.index("batch")
        idx = [0] * big.ndim
        idx[b_ax] = slot
        sl = small
        if small.shape[b_ax] != 1:
            sl = jnp.expand_dims(small, b_ax)
        # pad/crop the kv_seq dim to the slot buffer
        for d, nm in enumerate(names):
            if nm == "kv_seq" and sl.shape[d] != big.shape[d]:
                pad = big.shape[d] - sl.shape[d]
                if pad > 0:
                    cfgpad = [(0, 0)] * sl.ndim
                    cfgpad[d] = (0, pad)
                    sl = jnp.pad(sl, cfgpad)
                else:
                    sl = jax.lax.slice_in_dim(sl, 0, big.shape[d], axis=d)
        return jax.lax.dynamic_update_slice(big, sl.astype(big.dtype), idx)

    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    cache = jax.tree.map(one, state["cache"], st_one["cache"], axes["cache"],
                         is_leaf=is_axes)
    return {"cache": cache,
            "pos": state["pos"].at[slot].set(st_one["pos"][0])}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.models.lm import build
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    mesh = parse_mesh(args.mesh) if args.mesh else jax.make_mesh(
        (len(jax.devices()),), ("data",))
    plan = compile_plan(model, mesh)
    with mesh:
        params = plan.init_params(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    pending = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                       dtype=np.int32), max_new=args.gen)
               for i in range(args.requests)]
    server = Server(model, plan, batch_slots=args.batch_slots,
                    max_len=args.max_len)

    t0 = time.time()
    done: list = []
    while pending or any(s is not None for s in server.slots):
        while pending and (slot := server.free_slot()) is not None:
            req = pending.pop(0)
            server.admit(params, req, slot)
            if req.done:                      # finished at admission
                done.append(req)
        done.extend(server.step(params))
    dt = time.time() - t0
    if len(done) != args.requests:
        raise SystemExit(
            f"[serve] BUG: {len(done)}/{args.requests} requests completed "
            f"— finished requests were dropped")
    total_toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {args.requests} requests completed, {total_toks} tokens "
          f"in {dt:.2f}s ({total_toks / dt:.1f} tok/s, "
          f"{server.steps} decode steps)")
    return {"steps": server.steps, "seconds": dt,
            "completed": len(done), "tokens": total_toks}


if __name__ == "__main__":
    main()

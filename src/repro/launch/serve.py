"""Serving driver CLI: continuous batching with a dense or paged KV cache.

The server core lives in :mod:`repro.serving.server`; this module wires it
to a model/plan and drives it in one of two modes:

- **batch** (default): all requests available at t=0, drain the queue —
  the original CPU sanity loop.
- **--traffic**: open-loop replay of a deterministic heavy-tail arrival
  trace (:mod:`repro.serving.traffic`) against the wall clock, with
  admission control (paged mode holds arrivals when the page pool can't
  cover their prompt) and per-request TTFT/TPOT/e2e accounting
  (:mod:`repro.serving.metrics`).

Usage (CPU sanity)::

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --requests 8 --batch-slots 4 --gen 16

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke --traffic \
        --cache paged --requests 16 --batch-slots 4 --rate 4 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.core.planner import compile_plan
from repro.launch.train import parse_mesh
from repro.serving.metrics import RequestTiming, ServeMetrics
from repro.serving.server import Request, Server
from repro.serving.traffic import TrafficCfg, make_trace

# re-exported for back-compat (tests and older drivers import from here)
__all__ = ["Request", "Server", "main", "run_trace"]


def run_trace(server: Server, params, trace, *, prompt_rng=None,
              vocab: int = 1000) -> ServeMetrics:
    """Open-loop wall-clock replay of ``trace`` against ``server``.

    Arrivals become *ready* at their trace time whether or not the server
    keeps up (queueing shows up in TTFT, as it should).  Ready requests
    admit FIFO while slots are free **and** admission control passes —
    a head-of-line request the page pool can't cover blocks the queue,
    holding its arrival-time ordering.  Preempted requests re-enter at
    the front of the ready queue.
    """
    rng = prompt_rng or np.random.default_rng(1234)
    prompts = {a.rid: rng.integers(0, vocab, a.prompt_len, dtype=np.int32)
               for a in trace}
    arrivals = sorted(trace, key=lambda a: (a.t, a.rid))
    timings = {a.rid: RequestTiming(rid=a.rid, arrival=a.t) for a in trace}
    metrics = ServeMetrics()
    ready: list = []                      # [(Request, arrival_t)]
    t0 = time.time()
    now = lambda: time.time() - t0

    def finish(req, t):
        tm = timings[req.rid]
        tm.finished = t
        tm.n_tokens = len(req.out_tokens)
        tm.preemptions = req.preemptions
        metrics.add(tm)

    while arrivals or ready or server.active:
        t = now()
        while arrivals and arrivals[0].t <= t:
            a = arrivals.pop(0)
            ready.append((Request(a.rid, prompts[a.rid], max_new=a.gen_len),
                          a.t))
        # FIFO admission with head-of-line blocking on the page budget
        while ready and (slot := server.free_slot()) is not None:
            req, _ = ready[0]
            if not server.can_admit(req):
                break
            ready.pop(0)
            server.admit(params, req, slot)
            t = now()
            tm = timings[req.rid]
            if tm.admitted is None:        # preempted re-admits keep TTFT
                tm.admitted = tm.first_token = t
            if req.done:
                finish(req, t)
        if server.active:
            for req in server.step(params):
                finish(req, now())
            for req in server.take_requeued():
                ready.insert(0, (req, timings[req.rid].arrival))
        elif ready:
            # empty server that still can't admit the head → it never will
            raise SystemExit(
                f"[serve] request {ready[0][0].rid} can never be admitted "
                f"(prompt {len(ready[0][0].prompt)} + gen "
                f"{ready[0][0].max_new} vs max_len/page budget)")
        elif arrivals:
            time.sleep(min(max(arrivals[0].t - now(), 0.0), 0.05))
    return metrics


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--cache", choices=("dense", "paged"), default="dense")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV rows per page; 0 = the autotuned page size")
    ap.add_argument("--pages", type=int, default=0,
                    help="physical pages in the pool (incl. the trash "
                         "page); 0 = full residency for every slot")
    ap.add_argument("--traffic", action="store_true",
                    help="open-loop Pareto arrival replay with TTFT/TPOT "
                         "accounting instead of the drain-the-queue loop")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="--traffic mean arrival rate (req/s)")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.models.lm import build
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    mesh = parse_mesh(args.mesh) if args.mesh else jax.make_mesh(
        (len(jax.devices()),), ("data",))
    plan = compile_plan(model, mesh)
    with mesh:
        params = plan.init_params(jax.random.key(args.seed))

    server = Server(model, plan, batch_slots=args.batch_slots,
                    max_len=args.max_len, cache=args.cache,
                    page_size=args.page_size, n_pages=args.pages)

    if args.traffic:
        tc = TrafficCfg(rate=args.rate, n_requests=args.requests,
                        prompt_lens=(args.prompt_len,),
                        gen_lens=(args.gen,))
        trace = make_trace(tc, seed=args.seed)
        t0 = time.time()
        metrics = run_trace(server, params, trace,
                            prompt_rng=np.random.default_rng(args.seed),
                            vocab=cfg.vocab)
        dt = time.time() - t0
        s = metrics.summary()
        if s["completed"] != args.requests:
            raise SystemExit(
                f"[serve] BUG: {s['completed']}/{args.requests} requests "
                f"completed under traffic replay")
        print(f"[serve/{args.cache}] traffic: {s['completed']} requests, "
              f"{s['tokens']} tokens in {dt:.2f}s — "
              f"{s['tokens_per_s']:.1f} tok/s, "
              f"ttft p50/p99 {s['ttft_p50_s'] * 1e3:.0f}/"
              f"{s['ttft_p99_s'] * 1e3:.0f} ms, "
              f"tpot {s['tpot_mean_s'] * 1e3:.1f} ms, "
              f"{s['preemptions']} preemptions, "
              f"{server.prefill_cache_size} prefill buckets")
        s["steps"] = server.steps
        s["seconds"] = dt
        return s

    rng = np.random.default_rng(args.seed)
    pending = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                       dtype=np.int32), max_new=args.gen)
               for i in range(args.requests)]

    t0 = time.time()
    done: list = []
    while pending or server.active:
        while (pending and (slot := server.free_slot()) is not None
               and server.can_admit(pending[0])):
            req = pending.pop(0)
            server.admit(params, req, slot)
            if req.done:                      # finished at admission
                done.append(req)
        if pending and not server.active:
            raise SystemExit(
                f"[serve] request {pending[0].rid} can never be admitted "
                f"(prompt {len(pending[0].prompt)} + gen "
                f"{pending[0].max_new} vs max_len {args.max_len} / page "
                f"budget)")
        done.extend(server.step(params))
        pending[:0] = server.take_requeued()  # preempted restart first
    dt = time.time() - t0
    if len(done) != args.requests:
        raise SystemExit(
            f"[serve] BUG: {len(done)}/{args.requests} requests completed "
            f"— finished requests were dropped")
    total_toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve/{args.cache}] {args.requests} requests completed, "
          f"{total_toks} tokens in {dt:.2f}s ({total_toks / dt:.1f} tok/s, "
          f"{server.steps} decode steps, "
          f"{server.prefill_cache_size} prefill buckets)")
    return {"steps": server.steps, "seconds": dt,
            "completed": len(done), "tokens": total_toks}


if __name__ == "__main__":
    main()

"""Fault-tolerant training driver.

Composes the whole stack: config → model → Whale plan (manual or
auto-parallel) → data pipeline → jitted train step → fault-tolerant loop
with async checkpoints, straggler monitoring, and auto-resume.

Usage (CPU sanity run)::

    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 50 --batch 8 --seq 128 --mesh 1x1

Multi-host TPU: every host runs the same command; ``--distributed`` calls
``jax.distributed.initialize()`` first (single-process here, exercised via
the 512-virtual-device dry-run instead).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.core.auto import auto_parallel
from repro.core.cost_model import StrategySpec, TPU_V5E, lm_workload_meta
from repro.core.planner import compile_plan
from repro.data.pipeline import DataCfg, TokenPipeline
from repro.optim.optimizer import Schedule, adamw, adafactor
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.straggler import StragglerMonitor


def parse_mesh(spec: str, *, stage: int = 1):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 1:
        return jax.make_mesh(dims, ("data",))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    return jax.make_mesh(dims, ("pod", "data", "model"))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 = data4 × model2")
    ap.add_argument("--micro-batches", type=int, default=None,
                    help="default: the plan's choice (1 when unplanned)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (adds a 'stage' mesh axis)")
    ap.add_argument("--schedule", choices=("gpipe", "1f1b"), default=None,
                    help="pipeline schedule (repro.core.schedule); "
                         "default: the plan's choice")
    ap.add_argument("--stage-layers", default="",
                    help="comma layer-repeats per stage (uneven pipelines, "
                         "e.g. 3,2,2,1); default even split")
    ap.add_argument("--optimizer", choices=("adamw", "adafactor"),
                    default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--auto", action="store_true",
                    help="pick the strategy with the Whale cost model")
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--overrides", default="",
                    help="comma k=v LMCfg overrides (e.g. n_layers=4)")
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.overrides:
        kv = {}
        for pair in args.overrides.split(","):
            k, v = pair.split("=")
            cur = getattr(cfg, k)
            kv[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
        cfg = dataclasses.replace(cfg, **kv)
    from repro.models.lm import build, param_count
    model = build(cfg)

    # ---- mesh & strategy ----
    if args.auto:
        meta = lm_workload_meta(cfg, batch=args.batch, seq=args.seq)
        strat = auto_parallel(meta, len(jax.devices()), TPU_V5E)
        print(f"[auto] chose: {strat.describe()}")
        from repro.core.planner import mesh_for_strategy
        mesh = mesh_for_strategy(strat)
    elif args.pp > 1:
        n = len(jax.devices())
        if n < args.pp or n % args.pp:
            raise SystemExit(
                f"--pp {args.pp} needs a device count divisible by the "
                f"stage count; have {n} device(s)")
        strat = StrategySpec(dp=n // args.pp, pp=args.pp,
                             micro_batches=args.micro_batches or 1,
                             schedule=args.schedule or "gpipe")
        from repro.core.planner import mesh_for_strategy
        mesh = mesh_for_strategy(strat)
    else:
        mesh = parse_mesh(args.mesh) if args.mesh else jax.make_mesh(
            (len(jax.devices()),), ("data",))
        strat = None
    plan = compile_plan(model, mesh, strategy=strat)
    pipelined = plan.strategy.pp > 1 and "stage" in mesh.shape
    if pipelined:
        print(f"[pipeline] {plan.strategy.pp} stages, schedule "
              f"{args.schedule or plan.strategy.schedule}, µb="
              f"{args.micro_batches or plan.strategy.micro_batches}, "
              f"stage_layers {args.stage_layers or 'even/plan'}")

    # ---- optimizer / data / checkpoint ----
    sched = Schedule(base_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                     decay_steps=args.steps)
    opt = (adamw(lr=sched) if args.optimizer == "adamw"
           else adafactor(lr=sched))
    data = TokenPipeline(DataCfg(global_batch=args.batch, seq_len=args.seq,
                                 vocab=cfg.vocab, seed=args.seed))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    # ---- init or resume ----
    if pipelined:
        import repro.core.pipeline as pipe
        stage_layers = None
        if args.stage_layers:
            stage_layers = tuple(int(x) for x in args.stage_layers.split(","))
            pipe.check_stage_layers(stage_layers, model.stack.n_rep,
                                    plan.strategy.pp)
        params = plan.init_pipeline_params(jax.random.key(args.seed),
                                           stage_layers=stage_layers)
        with mesh:
            opt_state = jax.jit(opt.init)(params)
    else:
        with mesh:
            params = plan.init_params(jax.random.key(args.seed))
            opt_state = jax.jit(opt.init)(params)
    start_step = 0
    resume = ckpt.restore_latest({"params": params, "opt": opt_state})
    if resume is not None:
        start_step, tree, extra = resume
        params, opt_state = tree["params"], tree["opt"]
        if "data" in extra:
            data.load_state_dict(extra["data"])
        print(f"[resume] from step {start_step}")

    batch0 = data.next_batch()
    with mesh:
        if pipelined:
            step_fn = plan.jit_pipeline_train_step(
                opt, micro_batches=args.micro_batches,
                schedule=args.schedule, stage_layers=stage_layers)
        else:
            step_fn = plan.jit_train_step(
                opt, batch0, micro_batches=args.micro_batches,
                compress_pod=args.compress_pod)

    n_params = param_count(params)
    print(f"[train] {cfg.name}: {n_params:,} params, mesh "
          f"{dict(mesh.shape)}, {args.steps} steps")

    monitor = StragglerMonitor()
    losses = []
    state0 = {"params": params, "opt": opt_state}
    if args.compress_pod and "pod" in mesh.shape:
        from repro.optim import grad_compress
        state0["err"] = grad_compress.init_error_tree(params)

    def one_step(i, st):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        with mesh:
            if pipelined:
                p, o, loss = step_fn(st["params"], st["opt"],
                                     batch["tokens"], jnp.asarray(i))
                new, m = {"params": p, "opt": o}, {"loss": loss}
            elif "err" in st:
                p, o, m, e = step_fn(st["params"], st["opt"], batch,
                                     jnp.asarray(i), st["err"])
                new = {"params": p, "opt": o, "err": e}
            else:
                p, o, m = step_fn(st["params"], st["opt"], batch,
                                  jnp.asarray(i))
                new = {"params": p, "opt": o}
        losses.append(float(m["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"  step {i:5d}  loss {losses[-1]:.4f}")
        return new

    def on_step(i, st, dt):
        if monitor.observe(dt):
            print(f"[straggler] flagged at step {i} "
                  f"(dt={dt:.3f}s vs mean {monitor.mean:.3f}s)")
            monitor.flagged = False   # keep training; eviction is external

    loop = FaultTolerantLoop(ckpt, save_every=args.save_every)
    final_step, state = loop.run(
        state=state0, step_fn=one_step, n_steps=args.steps,
        start_step=start_step,
        extra_fn=lambda st: {"data": data.state_dict()},
        on_step=on_step)

    print(f"[done] step {final_step}, loss {losses[0]:.4f} → {losses[-1]:.4f}")
    return {"final_step": final_step, "losses": losses}


if __name__ == "__main__":
    main()

"""Fault-tolerant, self-healing training driver.

Composes the whole stack: config → model → Whale plan (manual or
auto-parallel) → data pipeline → jitted train step → fault-tolerant loop
with async checkpoints, straggler monitoring, and auto-resume.

:class:`TrainController` closes Whale's resource-adaptability loop
(DESIGN.md §7): per-host step times feed a
:class:`~repro.runtime.straggler.HostStragglerAggregator`; a sustained
straggler is **evicted** (`shrink_devices`), the job **rebalances** onto
the surviving hardware mix (`ElasticContext.rebalance` — the hetero-aware
search picks the new strategy and placement), the committed checkpoint
restores into the new plan, the data pipeline resumes exactly-once, and
training continues.

Usage (CPU sanity run)::

    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 50 --batch 8 --seq 128 --mesh 1x1

Self-healing run with an injected straggler (4 virtual devices = 2
simulated hosts; host 1 goes 4× slower at step 6 and is evicted)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 20 --batch 8 --seq 64 --hosts 2 --inject-slow 1:6:4

Multi-host TPU: every host runs the same command; ``--distributed`` calls
``jax.distributed.initialize()`` first (single-process here, exercised via
the simulated :class:`~repro.runtime.elastic.HostTopology` instead).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.core.auto import auto_parallel
from repro.core.cost_model import (StrategySpec, TPU_V5E, step_cost,
                                   step_cost_features)
from repro.core.planner import compile_plan, mesh_for_strategy
from repro.data.pipeline import DataCfg, MultimodalPipeline, TokenPipeline
from repro.optim.optimizer import Schedule, adamw, adafactor
from repro.runtime.elastic import (ElasticContext, HostTopology,
                                   plan_for_cluster)
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.faults import (FaultInjector, SlowHost, CrashStep,
                                  DriftHost)
from repro.runtime.profiler import Profiler
from repro.runtime.straggler import (HostStragglerAggregator,
                                     StragglerMonitor)


def parse_mesh(spec: str, *, stage: int = 1):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 1:
        return jax.make_mesh(dims, ("data",))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    return jax.make_mesh(dims, ("pod", "data", "model"))


@dataclasses.dataclass
class CalibrationConfig:
    """Knobs for the drift-triggered rebalance loop (DESIGN.md §10).

    The controller anchors the cost model's time scale to the first
    ``min_steps`` measured steps of each plan (median measured / predicted
    — absorbing the simulated clock's arbitrary units and constant
    modelling bias), then watches the *relative* skew
    ``measured / (predicted · anchor)``.  ``patience`` consecutive steps
    above ``1 + skew`` trigger a recalibration: the profiler's windowed
    observations re-fit each group's ``Hardware`` table and
    ``ElasticContext.rebalance(hardware=...)`` re-plans with measured
    rates — no host is evicted.  ``max_rebalances=0`` records
    observations (``--profile``) without ever rebalancing.
    """
    skew: float = 0.25
    patience: int = 5
    min_steps: int = 8
    window: int = 256               # observations per group fed to each fit
    max_rebalances: int = 2


@dataclasses.dataclass
class ElasticConfig:
    """Knobs for the self-healing loop (DESIGN.md §7)."""
    topology: HostTopology
    threshold: float = 2.0          # straggler flag at mean + k·std
    patience: int = 3               # sustained outlier steps before flagging
    warmup: int = 5                 # per-monitor warmup (compile steps)
    min_hosts: int = 1              # never evict below this
    max_rebalances: int = 2         # then ride out the degradation
    overlap: float = 0.5            # comm/compute overlap for the search
    search_kw: dict = dataclasses.field(
        # stay in the checkpoint's non-pipelined parameter layout: a live
        # re-plan into a padded pipeline layout would need a migration
        default_factory=lambda: {"max_pp": 1})
    # predicted-vs-measured drift detection (None = off)
    calibration: CalibrationConfig | None = None


class TrainController:
    """Self-healing elastic training: straggler → evict → rebalance → resume.

    State machine (``.phase``)::

        TRAINING ──straggler flagged──▶ DEGRADED ──stop+ckpt──▶ REBALANCING
           ▲                                                        │
           └────────── restore into the re-planned mesh ◀───────────┘
        terminal: DONE (n_steps reached) | PREEMPTED (SIGTERM, final ckpt
        committed — a relaunch auto-resumes) | FAILED (retry budget
        exhausted and re-raise, after a final checkpoint)

    One :class:`FaultTolerantLoop` segment runs per plan; per-host step
    times (real, or synthesized by a
    :class:`~repro.runtime.faults.FaultInjector` on the simulated
    multi-host clock) feed the aggregator, and a sustained flag stops the
    segment with a final synchronous checkpoint.  Eviction shrinks the
    :class:`~repro.runtime.elastic.HostTopology`, the hetero-aware search
    re-plans over the survivors' :class:`ClusterSpec`, and the committed
    checkpoint restores into the new plan — data-pipeline position
    included, so the global sample stream continues exactly-once.

    Batches are fetched idempotently per step (a retried step replays the
    *same* batch — the bounded-retry path cannot skip samples).
    """

    def __init__(self, model, cfg, optimizer, data: TokenPipeline,
                 ckpt: CheckpointManager, *, elastic: ElasticConfig,
                 batch: int, seq: int, save_every: int = 50,
                 max_retries: int = 3, injector: FaultInjector | None = None,
                 log_every: int = 10, verbose: bool = True):
        self.model = model
        self.cfg = cfg
        self.optimizer = optimizer
        self.data = data
        self.ckpt = ckpt
        self.elastic = elastic
        self.topology = elastic.topology
        # flattened for the elastic search (max_pp=1 default: segment
        # boundaries are irrelevant to a pure DP/TP re-plan)
        self.meta = model.graph(batch, seq).workload_meta()
        self.save_every = save_every
        self.max_retries = max_retries
        self.injector = injector
        self.log_every = log_every
        self.verbose = verbose
        self.phase = "TRAINING"
        self.events: list = []
        self.losses: list = []
        self.calibration = elastic.calibration
        self.profiler = Profiler()
        self.aggregator = HostStragglerAggregator(
            n_hosts=len(self.topology.hosts),
            threshold=elastic.threshold, patience=elastic.patience,
            warmup=elastic.warmup)
        self.aggregator.reset(self.topology.host_ids)
        self._batch_step = -1
        self._batch = None
        self._data_state_before = None

    # ------------------------------------------------------------- logging
    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    def _event(self, kind: str, **kw) -> None:
        self.events.append({"kind": kind, **kw})

    # ------------------------------------------------------------ planning
    def _plan_current(self):
        """Search the surviving cluster and compile the plan + mesh."""
        plan, cand = plan_for_cluster(
            self.model, self.meta, self.topology.cluster_spec(),
            devices=self.topology.devices(jax.devices()),
            overlap=self.elastic.overlap, search_kw=self.elastic.search_kw)
        return plan, float(cand.total)

    def _predicted_total(self, plan) -> float:
        """The cost model's step-time prediction for the current plan."""
        if plan.placement is not None:
            return float(plan.placement.cost.total)
        g = self.topology.cluster_spec().groups[0]
        return float(step_cost(self.meta, plan.strategy, g.hw,
                               overlap=self.elastic.overlap).total)

    def _group_features(self, plan) -> dict:
        """Per device group: (calibration features, predicted s, hosts).

        The features (``cost_model.step_cost_features`` of the group's
        unit of work) are what the profiler attaches to each measured
        group step time, so ``calibrate.fit`` can invert them back into
        ``Hardware`` rates.
        """
        members = self.topology.group_hosts()
        ov = self.elastic.overlap
        out = {}
        if plan.placement is not None:
            for u in plan.placement.units:
                if u.kind != "group":
                    continue
                out[u.group.name] = (
                    step_cost_features(u.meta, u.strategy, u.group.hw,
                                       overlap=ov),
                    float(u.cost.total), members.get(u.group.name, []))
        else:
            g = self.topology.cluster_spec().groups[0]
            out[g.name] = (
                step_cost_features(self.meta, plan.strategy, g.hw,
                                   overlap=ov),
                float(step_cost(self.meta, plan.strategy, g.hw,
                                overlap=ov).total),
                members.get(g.name, list(self.topology.host_ids)))
        return out

    def _retune_model(self, spec) -> None:
        """Re-autotune kernel tiles for ``spec`` and rebuild the model.

        Plans re-run the tile autotuner inside ``compile_plan``, but the
        *executing model* bakes block sizes into its config at startup —
        after a rebalance changes the hardware mix (eviction) or the
        rates (recalibration), those baked tiles are stale.  Tiles don't
        change parameter shapes, so the rebuilt model restores the same
        checkpoint.
        """
        cfg = self.cfg
        if "pallas" not in (cfg.attn_impl, cfg.xent_impl, cfg.ssd_impl):
            return
        if not getattr(cfg, "n_heads", 0):
            return
        from repro.kernels.autotune import DEFAULT_TILES, autotune_cluster
        tiles_by_group = autotune_cluster(
            spec, head_dim=cfg.hd,
            group=cfg.n_heads // max(cfg.n_kv_heads, 1) or 1,
            d_model=cfg.d_model, vocab=cfg.padded_vocab)
        tiles = list(tiles_by_group.values())
        lo = tiles[0] if tiles else DEFAULT_TILES
        for t in tiles[1:]:                 # min over groups: fits everywhere
            lo = dataclasses.replace(lo, **{
                f.name: min(getattr(lo, f.name), getattr(t, f.name))
                for f in dataclasses.fields(t)})
        new_cfg = dataclasses.replace(
            cfg, attn_block_q=lo.block_q, attn_block_k=lo.block_k,
            xent_block_t=lo.xent_block_t, xent_block_v=lo.xent_block_v,
            ssd_chunk=(lo.ssd_chunk if cfg.family in ("ssm", "hybrid")
                       else cfg.ssd_chunk))
        if new_cfg != cfg:
            from repro.models.lm import build
            self.cfg = new_cfg
            self.model = build(new_cfg)
            self._event("retune", tiles=str(lo))
            self._log(f"[retune] kernel tiles re-sized for "
                      f"{'+'.join(g.name for g in spec.groups)}: {lo}")

    # --------------------------------------------- drift detection (§10)
    def _observe_calibration(self, i, times, cal, feats, predicted,
                             loop, pending) -> None:
        """Feed the profiler and watch predicted-vs-measured skew.

        First ``min_steps`` measured steps of a plan anchor the model's
        time scale; afterwards each step records per-group observations
        (in anchored units, so fitted tables stay comparable to the
        priors) and ``patience`` consecutive steps with skew above
        ``1 + skew`` stop the segment for a recalibrating rebalance.
        """
        cfg = self.calibration
        measured = max(times.values())
        cal["n"] += 1
        if cal["n"] <= cfg.min_steps:
            cal["sum"] += measured
            if cal["n"] == cfg.min_steps:
                cal["anchor"] = (cal["sum"] / cfg.min_steps) / predicted
            return
        anchor = cal["anchor"]
        for gname, (f, _p, members) in feats.items():
            t_g = max((times[h] for h in members if h in times), default=0.0)
            if t_g > 0.0:
                self.profiler.record_step(gname, t_g / anchor, f, step=i)
        skew = measured / (predicted * anchor)
        if skew > 1.0 + cfg.skew:
            cal["hot"] += 1
        else:
            cal["hot"] = 0
        if (cal["hot"] >= cfg.patience and not pending
                and cal["trigger"] is None
                and self._recalibrations < cfg.max_rebalances):
            cal["trigger"] = skew
            self.phase = "DEGRADED"
            self._log(f"[drift] measured/predicted skew {skew:.2f} "
                      f"sustained {cfg.patience} steps at step {i}; "
                      f"stopping to recalibrate")
            loop.request_stop()

    def _build_step_fn(self, plan):
        batch0 = {k: jnp.asarray(v) for k, v in self._peek_batch().items()}
        with plan.mesh:
            jfn = plan.jit_train_step(self.optimizer, batch0, donate=False)

        def one_step(i, st):
            if self.injector is not None:
                self.injector.maybe_preempt(i)
            batch = self._batch_for(i)
            if self.injector is not None:
                self.injector.maybe_fail(i)
            with plan.mesh:
                p, o, m = jfn(st["params"], st["opt"], batch,
                              jnp.asarray(i))
            self.losses.append(float(m["loss"]))
            if i % self.log_every == 0:
                self._log(f"  step {i:5d}  loss {self.losses[-1]:.4f}")
            return {"params": p, "opt": o}

        return one_step

    # -------------------------------------------------- exactly-once data
    def _peek_batch(self) -> dict:
        """The next step's batch (cached, so the step replays it)."""
        return self._batch_for(self._batch_step + 1)

    def _batch_for(self, step: int) -> dict:
        """Idempotent per-step batch: a retried step replays the same
        samples instead of silently consuming the next draw."""
        if step != self._batch_step:
            self._data_state_before = self.data.state_dict()
            raw = self.data.next_batch()
            self._batch = {k: jnp.asarray(v) for k, v in raw.items()}
            self._batch_step = step
        return self._batch

    def _data_state_at(self, step: int) -> dict:
        """The pipeline position with exactly ``step`` batches consumed —
        what a checkpoint committed at ``step`` must record.  A save at
        the *failed* step (retry budget exhausted) lands one batch behind
        the cursor, so the pre-fetch snapshot is returned instead."""
        consumed = self._batch_step + 1
        if step == self._batch_step and self._data_state_before is not None:
            return dict(self._data_state_before)
        if step != consumed:
            raise RuntimeError(
                f"data pipeline out of sync: checkpoint at step {step} but "
                f"{consumed} batches consumed")
        return self.data.state_dict()

    # ------------------------------------------------------------ the loop
    def run(self, n_steps: int, seed: int = 0) -> dict:
        plan, predicted = self._plan_current()
        self._log(f"[elastic] initial plan: "
                  f"{plan.strategy.describe()} on "
                  f"{self.topology.n_devices} devices "
                  f"(predicted {predicted*1e3:.1f} ms/step)")
        with plan.mesh:
            params = plan.init_params(jax.random.key(seed))
            opt_state = jax.jit(self.optimizer.init)(params)
        step = 0
        resume = self.ckpt.restore_latest({"params": params,
                                           "opt": opt_state})
        if resume is not None:
            step, tree, extra = resume
            params, opt_state = tree["params"], tree["opt"]
            if "data" in extra:
                self.data.load_state_dict(extra["data"])
                self._batch_step, self._batch = step - 1, None
            self._log(f"[resume] from step {step}")
        state = {"params": params, "opt": opt_state}

        rebalances = 0
        self._recalibrations = 0
        while step < n_steps:
            pending: list = []
            segment_start = step
            # drift detection state for this plan segment: the anchor maps
            # the cost model's time scale onto the measured clock, so the
            # skew watched below is relative to *this plan's* own baseline
            cal = {"n": 0, "sum": 0.0, "anchor": None, "hot": 0,
                   "trigger": None}
            feats = self._group_features(plan) if self.calibration else {}
            predicted = self._predicted_total(plan)
            loop = FaultTolerantLoop(self.ckpt, save_every=self.save_every,
                                     max_retries=self.max_retries)

            def on_step(i, st, dt, _loop=loop, _pending=pending,
                        _start=segment_start, _cal=cal, _feats=feats,
                        _pred=predicted):
                if i == _start:
                    return          # jit-compile step would poison warmup
                hosts = self.topology.host_ids
                if self.injector is not None:
                    times = self.injector.host_times(i, base=dt, hosts=hosts)
                else:
                    # single-process: every host reports the global step
                    # time; a real fleet reports per-host measurements
                    times = {h: dt for h in hosts}
                if self.calibration is not None and _pred > 0.0:
                    self._observe_calibration(i, times, _cal, _feats, _pred,
                                              _loop, _pending)
                for h in self.aggregator.observe(times):
                    self._event("flag", step=i, host=h, dt=times[h],
                                mean=self.aggregator.monitors[h].mean
                                if h in self.aggregator.monitors else None)
                    self._log(f"[straggler] host {h} flagged at step {i} "
                              f"(dt={times[h]:.3f}s)")
                    survivors = len(self.topology.hosts) - len(_pending) - 1
                    if survivors < self.elastic.min_hosts:
                        self._log(f"[straggler] NOT evicting host {h}: "
                                  f"{survivors} survivors < min_hosts="
                                  f"{self.elastic.min_hosts}")
                        continue
                    if rebalances >= self.elastic.max_rebalances:
                        self._log("[straggler] rebalance budget exhausted; "
                                  "riding out the degradation")
                        continue
                    _pending.append(h)
                if _pending:
                    self.phase = "DEGRADED"
                    _loop.request_stop()

            step_fn = self._build_step_fn(plan)
            try:
                step, state = loop.run(
                    state=state, step_fn=step_fn, n_steps=n_steps,
                    start_step=step,
                    extra_fn=lambda st, s: {"data": self._data_state_at(s)},
                    on_step=on_step)
            except Exception:
                self.phase = "FAILED"
                raise
            if loop.preempted:
                self.phase = "PREEMPTED"
                self._event("preempted", step=step,
                            pending_evictions=list(pending))
                self._log(f"[preempt] SIGTERM at step {step}; final "
                          f"checkpoint committed")
                break
            if (not pending and cal["trigger"] is None) or step >= n_steps:
                # n_steps reached — a flag raised on the very last step
                # must not trigger a rebalance whose result is discarded
                break
            self.phase = "REBALANCING"
            hardware = None
            if pending:
                # ---- evict + rebalance + resume ----
                for h in pending:
                    self.aggregator.evict(h)
                self.topology = self.topology.without(set(pending))
                spec = self.topology.cluster_spec()
                self._event("evict", step=step, hosts=list(pending),
                            surviving_devices=self.topology.n_devices)
                self._log(f"[evict] hosts {pending} at step {step}; "
                          f"rebalancing onto {self.topology.n_devices} "
                          f"devices")
            else:
                # ---- drift-triggered recalibration: same fleet, re-fitted
                # Hardware tables — continuous rebalancing (DESIGN.md §10)
                spec = self.topology.cluster_spec()
                cal_spec, hardware = self.profiler.fit_spec(
                    spec, last_n=self.calibration.window)
                spec = cal_spec
                self._event("drift", step=step, skew=cal["trigger"],
                            hardware={
                                n: {"eff_flops":
                                    h.peak_flops * h.mxu_eff,
                                    "n_obs": h.n_observations}
                                for n, h in hardware.items()})
                self._log(f"[drift] recalibrating at step {step} "
                          f"(skew {cal['trigger']:.2f}); re-planning with "
                          f"measured rates")
            # stale-tiles fix: the executing model baked kernel tiles for
            # the old mix/rates — re-autotune before re-meshing
            self._retune_model(spec)
            ectx = ElasticContext(model=self.model, optimizer=self.optimizer)
            t0 = time.monotonic()
            step, plan, params, opt_state, extra = ectx.rebalance(
                self.ckpt, self.topology.cluster_spec(), self.meta,
                devices=self.topology.devices(jax.devices()),
                overlap=self.elastic.overlap,
                search_kw=self.elastic.search_kw,
                hardware=hardware)
            if "data" in extra:
                self.data.load_state_dict(extra["data"])
            self._batch_step, self._batch = step - 1, None
            state = {"params": params, "opt": opt_state}
            kind = "rebalance" if pending else "recalibrate"
            if pending:
                rebalances += 1
                self.profiler.clear()   # old groups' names/shares are stale
            else:
                self._recalibrations += 1
            self.aggregator.reset(self.topology.host_ids)
            self._event(kind, step=step,
                        strategy=plan.strategy.describe(),
                        downtime_s=time.monotonic() - t0,
                        placement=(plan.placement.describe()
                                   if plan.placement else None))
            self._log(f"[{kind}] resumed at step {step} with "
                      f"{plan.strategy.describe()}")
            self.phase = "TRAINING"
        if self.phase not in ("FAILED", "PREEMPTED") and step >= n_steps:
            self.phase = "DONE"
        return {"final_step": step, "state": state, "events": self.events,
                "losses": self.losses, "phase": self.phase,
                "topology": self.topology}


def _parse_injections(slow: list, crash: list, drift: list = ()) -> tuple:
    scenarios = []
    for s in slow or []:
        host, start, factor = s.split(":")
        scenarios.append(SlowHost(host=int(host), start_step=int(start),
                                  factor=float(factor)))
    for c in crash or []:
        bits = c.split(":")
        scenarios.append(CrashStep(step=int(bits[0]),
                                   times=int(bits[1]) if len(bits) > 1
                                   else 1))
    for d in drift or []:
        host, start, end, factor = d.split(":")
        scenarios.append(DriftHost(host=int(host), start_step=int(start),
                                   end_step=int(end), factor=float(factor)))
    return tuple(scenarios)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch", choices=ARCH_NAMES,
                    default="tinyllama-1.1b",
                    help="architecture to train (--model is an alias; "
                         "includes the M6 multimodal workloads, e.g. "
                         "qwen2-vl-2b / seamless-m4t-medium)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--src-seq", type=int, default=None,
                    help="encoder-side source length for encdec archs "
                         "(frames per sample); default: --seq")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 = data4 × model2")
    ap.add_argument("--micro-batches", type=int, default=None,
                    help="default: the plan's choice (1 when unplanned)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (adds a 'stage' mesh axis)")
    ap.add_argument("--schedule", choices=("gpipe", "1f1b"), default=None,
                    help="pipeline schedule (repro.core.schedule); "
                         "default: the plan's choice")
    ap.add_argument("--stage-layers", default="",
                    help="comma layer-repeats per stage (uneven pipelines, "
                         "e.g. 3,2,2,1); default even split")
    ap.add_argument("--optimizer", choices=("adamw", "adafactor"),
                    default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--auto", action="store_true",
                    help="pick the strategy with the Whale cost model")
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--overrides", default="",
                    help="comma k=v LMCfg overrides (e.g. n_layers=4)")
    # ---- fused-kernel selection (PR 6: training-grade pallas paths) ----
    ap.add_argument("--attn", choices=("ref", "pallas"), default=None,
                    help="attention impl: pallas = fused flash fwd+bwd "
                         "(interpret-mode off-TPU); default: config's choice")
    ap.add_argument("--xent", choices=("ref", "pallas"), default=None,
                    help="loss head impl: pallas = fused xent kernel")
    ap.add_argument("--hw", choices=("tpu_v5e", "v100", "p100", "t4"),
                    default="tpu_v5e",
                    help="Hardware table the kernel-tile autotuner targets "
                         "(repro.kernels.autotune)")
    # ---- self-healing elastic runtime (DESIGN.md §7) ----
    ap.add_argument("--hosts", type=int, default=0,
                    help="simulate N hosts over the visible devices and run "
                         "the self-healing TrainController (straggler "
                         "eviction + rebalance + resume)")
    ap.add_argument("--inject-slow", action="append", default=[],
                    metavar="HOST:STEP:FACTOR",
                    help="fault injection: HOST runs FACTOR× slower from "
                         "STEP (repeatable)")
    ap.add_argument("--inject-crash", action="append", default=[],
                    metavar="STEP[:TIMES]",
                    help="fault injection: transient step failure at STEP")
    ap.add_argument("--patience", type=int, default=3)
    ap.add_argument("--straggler-warmup", type=int, default=3)
    ap.add_argument("--max-rebalances", type=int, default=2)
    # ---- profile-calibrated cost model (DESIGN.md §10) ----
    ap.add_argument("--profile", action="store_true",
                    help="record per-group step observations against the "
                         "cost model's features and print the fitted "
                         "calibration report at exit")
    ap.add_argument("--calibrate", action="store_true",
                    help="drift-triggered continuous rebalancing: compare "
                         "predicted vs measured step cost and rebalance "
                         "with the re-fitted ClusterSpec when skew exceeds "
                         "--drift-skew (needs --hosts)")
    ap.add_argument("--drift-skew", type=float, default=0.25,
                    help="relative skew that triggers recalibration")
    ap.add_argument("--drift-patience", type=int, default=5,
                    help="sustained skewed steps before recalibrating")
    ap.add_argument("--inject-drift", action="append", default=[],
                    metavar="HOST:START:END:FACTOR",
                    help="fault injection: HOST ramps linearly to FACTOR× "
                         "slower between START and END (repeatable)")
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.overrides:
        kv = {}
        for pair in args.overrides.split(","):
            k, v = pair.split("=")
            cur = getattr(cfg, k)
            kv[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
        cfg = dataclasses.replace(cfg, **kv)
    if args.attn:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn)
    if args.xent:
        cfg = dataclasses.replace(cfg, xent_impl=args.xent)
    if "pallas" in (cfg.attn_impl, cfg.xent_impl, cfg.ssd_impl):
        # size the kernel tiles for the target part (per-Hardware autotune);
        # mixed clusters get per-group tiles on the plan via compile_plan
        from repro.core import cost_model as _cm
        from repro.kernels.autotune import autotune
        hw = {"tpu_v5e": _cm.TPU_V5E, "v100": _cm.V100_PAPER,
              "p100": _cm.P100_16G, "t4": _cm.T4_16G}[args.hw]
        tiles = autotune(
            hw, head_dim=cfg.hd if cfg.n_heads else cfg.ssd_headdim,
            group=cfg.n_heads // max(cfg.n_kv_heads, 1) or 1,
            d_model=cfg.d_model, vocab=cfg.padded_vocab, seq=args.seq)
        cfg = dataclasses.replace(
            cfg, attn_block_q=tiles.block_q, attn_block_k=tiles.block_k,
            xent_block_t=tiles.xent_block_t, xent_block_v=tiles.xent_block_v,
            ssd_chunk=(tiles.ssd_chunk if cfg.family in ("ssm", "hybrid")
                       else cfg.ssd_chunk))
        print(f"[autotune] {hw.name}: {tiles}")
    from repro.models.lm import build, param_count
    model = build(cfg)

    # ---- optimizer / data / checkpoint (shared by both paths) ----
    sched = Schedule(base_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                     decay_steps=args.steps)
    opt = (adamw(lr=sched) if args.optimizer == "adamw"
           else adafactor(lr=sched))
    dcfg = DataCfg(global_batch=args.batch, seq_len=args.seq,
                   vocab=cfg.vocab, seed=args.seed)
    src_seq = args.src_seq or args.seq
    if cfg.family in ("vlm", "encdec"):
        # multimodal archs consume a modality stream alongside the tokens:
        # patch embeddings for vlm, source frames for encdec
        data = MultimodalPipeline(
            dcfg, modality=cfg.family, d_model=cfg.d_model,
            frontend_len=cfg.frontend_len if cfg.family == "vlm" else 0,
            src_len=src_seq if cfg.family == "encdec" else 0)
    else:
        data = TokenPipeline(dcfg)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    # ---- self-healing controller path (simulated multi-host) ----
    if args.hosts > 1:
        n = len(jax.devices())
        if n % args.hosts:
            raise SystemExit(f"--hosts {args.hosts} must divide the "
                             f"device count ({n})")
        topology = HostTopology.uniform(args.hosts, n // args.hosts, TPU_V5E)
        scenarios = _parse_injections(args.inject_slow, args.inject_crash,
                                      args.inject_drift)
        # nominal clock: injected scenarios play on a fully simulated
        # timeline, so detection is deterministic regardless of machine
        # load (a real deployment feeds measured per-host times instead)
        injector = (FaultInjector(scenarios=scenarios, n_hosts=args.hosts,
                                  seed=args.seed, nominal=0.05)
                    if scenarios else None)
        calibration = None
        if args.calibrate:
            calibration = CalibrationConfig(
                skew=args.drift_skew, patience=args.drift_patience,
                max_rebalances=args.max_rebalances)
        elif args.profile:
            # record + report only: never trigger a rebalance
            calibration = CalibrationConfig(max_rebalances=0)
        ctl = TrainController(
            model, cfg, opt, data, ckpt,
            elastic=ElasticConfig(topology=topology,
                                  patience=args.patience,
                                  warmup=args.straggler_warmup,
                                  max_rebalances=args.max_rebalances,
                                  calibration=calibration),
            batch=args.batch, seq=args.seq, save_every=args.save_every,
            injector=injector, log_every=args.log_every)
        out = ctl.run(args.steps, seed=args.seed)
        if args.profile:
            print(ctl.profiler.report(ctl.topology.cluster_spec()))
        evictions = [e for e in out["events"] if e["kind"] == "evict"]
        recals = [e for e in out["events"] if e["kind"] == "recalibrate"]
        loss_str = (f", loss {out['losses'][0]:.4f} → {out['losses'][-1]:.4f}"
                    if out["losses"] else " (resumed already complete)")
        print(f"[done] step {out['final_step']} phase {out['phase']}, "
              f"{len(evictions)} eviction(s), "
              f"{len(recals)} recalibration(s){loss_str}")
        return {"final_step": out["final_step"], "losses": out["losses"],
                "events": out["events"], "phase": out["phase"]}

    # ---- mesh & strategy ----
    # the cost model can PRICE a pipelined vlm (the planner/fig10 use it),
    # but the executable layer-stack engine is token-only — it has no slot
    # for the vision frontend or the M-RoPE position tensor, so this
    # driver never routes vlm to pp > 1
    if args.auto:
        # the segment-aware graph lets the search respect frontend/encoder/
        # decoder boundaries when it enumerates pipeline splits
        graph = model.graph(args.batch, args.seq, src_seq=src_seq)
        search_kw = {"max_pp": 1} if cfg.family == "vlm" else {}
        strat = auto_parallel(graph, len(jax.devices()), TPU_V5E,
                              **search_kw)
        print(f"[auto] chose: {strat.describe()}")
        mesh = mesh_for_strategy(strat)
    elif args.pp > 1:
        if cfg.family == "vlm":
            raise SystemExit(
                "--pp does not apply to vlm archs yet: the executable "
                "pipeline engine cannot stage the vision frontend "
                "(train non-pipelined, e.g. --dp, instead)")
        n = len(jax.devices())
        if n < args.pp or n % args.pp:
            raise SystemExit(
                f"--pp {args.pp} needs a device count divisible by the "
                f"stage count; have {n} device(s)")
        strat = StrategySpec(dp=n // args.pp, pp=args.pp,
                             micro_batches=args.micro_batches or 1,
                             schedule=args.schedule or "gpipe")
        mesh = mesh_for_strategy(strat)
    else:
        mesh = parse_mesh(args.mesh) if args.mesh else jax.make_mesh(
            (len(jax.devices()),), ("data",))
        strat = None
    plan = compile_plan(model, mesh, strategy=strat)
    pipelined = plan.strategy.pp > 1 and "stage" in mesh.shape
    if pipelined:
        print(f"[pipeline] {plan.strategy.pp} stages, schedule "
              f"{args.schedule or plan.strategy.schedule}, µb="
              f"{args.micro_batches or plan.strategy.micro_batches}, "
              f"stage_layers {args.stage_layers or 'even/plan'}")

    # ---- init or resume ----
    if pipelined:
        import repro.core.pipeline as pipe
        stage_layers = None
        if args.stage_layers:
            if model.stack is None:
                raise SystemExit("--stage-layers does not apply to encdec "
                                 "archs: the pipeline cut is the fixed "
                                 "encoder|decoder tower edge")
            stage_layers = tuple(int(x) for x in args.stage_layers.split(","))
            pipe.check_stage_layers(stage_layers, model.stack.n_rep,
                                    plan.strategy.pp)
        params = plan.init_pipeline_params(jax.random.key(args.seed),
                                           stage_layers=stage_layers)
        with mesh:
            opt_state = jax.jit(opt.init)(params)
    else:
        with mesh:
            params = plan.init_params(jax.random.key(args.seed))
            opt_state = jax.jit(opt.init)(params)
    start_step = 0
    resume = ckpt.restore_latest({"params": params, "opt": opt_state})
    if resume is not None:
        start_step, tree, extra = resume
        params, opt_state = tree["params"], tree["opt"]
        if "data" in extra:
            data.load_state_dict(extra["data"])
        print(f"[resume] from step {start_step}")

    # exactly-once data, same discipline as TrainController: batches are
    # fetched idempotently per step (a retried step replays the SAME batch)
    # and checkpoints record the position of the committed step — the jit
    # warm-up example below is the batch of start_step, not a burned draw
    fetched = {"step": start_step - 1, "batch": None, "before": None}

    def batch_for(i):
        if fetched["step"] != i:
            fetched["before"] = data.state_dict()
            fetched["batch"] = {k: jnp.asarray(v)
                                for k, v in data.next_batch().items()}
            fetched["step"] = i
        return fetched["batch"]

    def data_state_at(s):
        if s == fetched["step"] and fetched["before"] is not None:
            return dict(fetched["before"])     # save at the failed step
        return data.state_dict()

    batch0 = batch_for(start_step)
    with mesh:
        if pipelined:
            step_fn = plan.jit_pipeline_train_step(
                opt, micro_batches=args.micro_batches,
                schedule=args.schedule, stage_layers=stage_layers)
        else:
            step_fn = plan.jit_train_step(
                opt, batch0, micro_batches=args.micro_batches,
                compress_pod=args.compress_pod)

    n_params = param_count(params)
    print(f"[train] {cfg.name}: {n_params:,} params, mesh "
          f"{dict(mesh.shape)}, {args.steps} steps")

    monitor = StragglerMonitor()
    profiler = None
    if args.profile:
        # whole-step observations against the executed strategy's feature
        # vector on the --hw table; the exit report shows how far the
        # hand-written rates are from this machine's measured ones
        from repro.core import cost_model as _cm
        prof_hw = {"tpu_v5e": _cm.TPU_V5E, "v100": _cm.V100_PAPER,
                   "p100": _cm.P100_16G, "t4": _cm.T4_16G}[args.hw]
        prof_meta = model.graph(args.batch, args.seq,
                                src_seq=src_seq).workload_meta()
        prof_feats = step_cost_features(prof_meta, plan.strategy, prof_hw)
        profiler = Profiler()
    losses = []
    state0 = {"params": params, "opt": opt_state}
    if args.compress_pod and "pod" in mesh.shape:
        from repro.optim import grad_compress
        state0["err"] = grad_compress.init_error_tree(params)

    def one_step(i, st):
        batch = batch_for(i)
        with mesh:
            if pipelined and "frames" in batch:
                # encdec two-tower pipeline: encoder memory ships over the
                # stage wire, so the step consumes frames AND tokens
                p, o, loss = step_fn(st["params"], st["opt"],
                                     batch["frames"], batch["tokens"],
                                     jnp.asarray(i))
                new, m = {"params": p, "opt": o}, {"loss": loss}
            elif pipelined:
                p, o, loss = step_fn(st["params"], st["opt"],
                                     batch["tokens"], jnp.asarray(i))
                new, m = {"params": p, "opt": o}, {"loss": loss}
            elif "err" in st:
                p, o, m, e = step_fn(st["params"], st["opt"], batch,
                                     jnp.asarray(i), st["err"])
                new = {"params": p, "opt": o, "err": e}
            else:
                p, o, m = step_fn(st["params"], st["opt"], batch,
                                  jnp.asarray(i))
                new = {"params": p, "opt": o}
        losses.append(float(m["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"  step {i:5d}  loss {losses[-1]:.4f}")
        return new

    def on_step(i, st, dt):
        if profiler is not None and i > start_step:
            profiler.record_step(prof_hw.name, dt, prof_feats, step=i)
        if monitor.observe(dt):       # one-shot: True on the flag transition
            print(f"[straggler] flagged at step {i} "
                  f"(dt={dt:.3f}s vs mean {monitor.mean:.3f}s)")
            monitor.reset()           # keep training; eviction is external

    loop = FaultTolerantLoop(ckpt, save_every=args.save_every)
    final_step, state = loop.run(
        state=state0, step_fn=one_step, n_steps=args.steps,
        start_step=start_step,
        extra_fn=lambda st, s: {"data": data_state_at(s)},
        on_step=on_step)

    if profiler is not None:
        from repro.core.cost_model import ClusterSpec
        print(profiler.report(ClusterSpec.homogeneous(prof_hw,
                                                      len(jax.devices()))))
    loss_str = (f", loss {losses[0]:.4f} → {losses[-1]:.4f}" if losses
                else " (resumed already complete)")
    print(f"[done] step {final_step}{loss_str}")
    return {"final_step": final_step, "losses": losses}


if __name__ == "__main__":
    main()

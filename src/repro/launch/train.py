"""Fault-tolerant, self-healing training driver (CLI).

Composes the whole stack: config → model → Whale plan (manual or
auto-parallel) → data pipeline → jitted train step → fault-tolerant loop
with async checkpoints, straggler monitoring, and auto-resume.

The multi-host control loop lives in
:mod:`repro.runtime.controller` — the event-driven membership runtime
(DESIGN.md §12) that closes Whale's resource-adaptability loop in both
directions: sustained stragglers and spot-reclaimed hosts are **evicted**
and the job rebalances onto the survivors; joining hosts are **admitted**
and the job rebalances onto the grown fleet.  ``TrainController`` is kept
here as a thin alias of
:class:`~repro.runtime.controller.ClusterController` for callers of the
old name.

Usage (CPU sanity run)::

    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 50 --batch 8 --seq 128 --mesh 1x1

Self-healing run with an injected straggler (4 virtual devices = 2
simulated hosts; host 1 goes 4× slower at step 6 and is evicted)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 20 --batch 8 --seq 64 --hosts 2 --inject-slow 1:6:4

Spot fleet: host 1 gets a reclaim warning at step 6 (2-step deadline) and
host 2 re-joins with 2 devices at step 14 (6 visible devices = 2 live
hosts × 2 devices + 2 spare for the join)::

    XLA_FLAGS=--xla_force_host_platform_device_count=6 \
    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 24 --batch 8 --seq 64 --hosts 2 --devices-per-host 2 \
        --inject-preempt 1:6:2 --inject-join 2:14:2

Multi-host TPU: every host runs the same command; ``--distributed`` calls
``jax.distributed.initialize()`` first (single-process here, exercised via
the simulated :class:`~repro.runtime.elastic.HostTopology` instead).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.core.auto import auto_parallel
from repro.core.cost_model import StrategySpec, TPU_V5E, step_cost_features
from repro.core.planner import compile_plan, mesh_for_strategy
from repro.data.pipeline import DataCfg, MultimodalPipeline, TokenPipeline
from repro.optim.optimizer import Schedule, adamw, adafactor
from repro.runtime.controller import (CalibrationConfig, ClusterController,
                                      ElasticConfig)
from repro.runtime.elastic import HostTopology
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.faults import (FaultInjector, JoinHost, SlowHost,
                                  CrashStep, DriftHost, SpotPreemption)
from repro.runtime.profiler import Profiler
from repro.runtime.straggler import StragglerMonitor

# the old name, re-exported for existing callers/tests; the implementation
# moved to repro.runtime.controller
TrainController = ClusterController


def parse_mesh(spec: str, *, stage: int = 1):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 1:
        return jax.make_mesh(dims, ("data",))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    return jax.make_mesh(dims, ("pod", "data", "model"))


def _parse_injections(slow: list, crash: list, drift: list = (),
                      preempt: list = (), join: list = ()) -> tuple:
    scenarios = []
    for s in slow or []:
        host, start, factor = s.split(":")
        scenarios.append(SlowHost(host=int(host), start_step=int(start),
                                  factor=float(factor)))
    for c in crash or []:
        bits = c.split(":")
        scenarios.append(CrashStep(step=int(bits[0]),
                                   times=int(bits[1]) if len(bits) > 1
                                   else 1))
    for d in drift or []:
        host, start, end, factor = d.split(":")
        scenarios.append(DriftHost(host=int(host), start_step=int(start),
                                   end_step=int(end), factor=float(factor)))
    for p in preempt or []:
        bits = p.split(":")
        scenarios.append(SpotPreemption(
            host=int(bits[0]), warn_step=int(bits[1]),
            deadline_steps=int(bits[2]) if len(bits) > 2 else 2))
    for j in join or []:
        host, step, n_dev = j.split(":")
        scenarios.append(JoinHost(host=int(host), step=int(step),
                                  n_devices=int(n_dev)))
    return tuple(scenarios)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch", choices=ARCH_NAMES,
                    default="tinyllama-1.1b",
                    help="architecture to train (--model is an alias; "
                         "includes the M6 multimodal workloads, e.g. "
                         "qwen2-vl-2b / seamless-m4t-medium)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--src-seq", type=int, default=None,
                    help="encoder-side source length for encdec archs "
                         "(frames per sample); default: --seq")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 = data4 × model2")
    ap.add_argument("--micro-batches", type=int, default=None,
                    help="default: the plan's choice (1 when unplanned)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (adds a 'stage' mesh axis)")
    ap.add_argument("--schedule", choices=("gpipe", "1f1b"), default=None,
                    help="pipeline schedule (repro.core.schedule); "
                         "default: the plan's choice")
    ap.add_argument("--stage-layers", default="",
                    help="comma layer-repeats per stage (uneven pipelines, "
                         "e.g. 3,2,2,1); default even split")
    ap.add_argument("--optimizer", choices=("adamw", "adafactor"),
                    default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--auto", action="store_true",
                    help="pick the strategy with the Whale cost model")
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--overrides", default="",
                    help="comma k=v LMCfg overrides (e.g. n_layers=4)")
    # ---- fused-kernel selection (PR 6: training-grade pallas paths) ----
    ap.add_argument("--attn", choices=("ref", "pallas"), default=None,
                    help="attention impl: pallas = fused flash fwd+bwd "
                         "(interpret-mode off-TPU); default: config's choice")
    ap.add_argument("--xent", choices=("ref", "pallas"), default=None,
                    help="loss head impl: pallas = fused xent kernel")
    ap.add_argument("--hw", choices=("tpu_v5e", "v100", "p100", "t4"),
                    default="tpu_v5e",
                    help="Hardware table the kernel-tile autotuner targets "
                         "(repro.kernels.autotune)")
    # ---- self-healing elastic runtime (DESIGN.md §7) ----
    ap.add_argument("--hosts", type=int, default=0,
                    help="simulate N hosts over the visible devices and run "
                         "the self-healing TrainController (straggler "
                         "eviction + rebalance + resume)")
    ap.add_argument("--inject-slow", action="append", default=[],
                    metavar="HOST:STEP:FACTOR",
                    help="fault injection: HOST runs FACTOR× slower from "
                         "STEP (repeatable)")
    ap.add_argument("--inject-crash", action="append", default=[],
                    metavar="STEP[:TIMES]",
                    help="fault injection: transient step failure at STEP")
    # ---- cluster membership (DESIGN.md §12: spot fleets, scale-up) ----
    ap.add_argument("--inject-preempt", action="append", default=[],
                    metavar="HOST:WARN[:DEADLINE]",
                    help="spot reclaim: HOST is warned at step WARN and "
                         "vanishes DEADLINE steps later (default 2; 0 = "
                         "missed notice, falls back to the last committed "
                         "checkpoint) (repeatable)")
    ap.add_argument("--inject-join", action="append", default=[],
                    metavar="HOST:STEP:NDEV",
                    help="scale-up / spot re-admission: HOST offers NDEV "
                         "devices from STEP on (repeatable; needs spare "
                         "visible devices — see --devices-per-host)")
    ap.add_argument("--devices-per-host", type=int, default=0,
                    help="devices each simulated host owns (default: "
                         "device count / --hosts); set it below that to "
                         "leave spare devices for --inject-join")
    ap.add_argument("--patience", type=int, default=3)
    ap.add_argument("--straggler-warmup", type=int, default=3)
    ap.add_argument("--max-rebalances", type=int, default=2)
    # ---- profile-calibrated cost model (DESIGN.md §10) ----
    ap.add_argument("--profile", action="store_true",
                    help="record per-group step observations against the "
                         "cost model's features and print the fitted "
                         "calibration report at exit")
    ap.add_argument("--calibrate", action="store_true",
                    help="drift-triggered continuous rebalancing: compare "
                         "predicted vs measured step cost and rebalance "
                         "with the re-fitted ClusterSpec when skew exceeds "
                         "--drift-skew (needs --hosts)")
    ap.add_argument("--drift-skew", type=float, default=0.25,
                    help="relative skew that triggers recalibration")
    ap.add_argument("--drift-patience", type=int, default=5,
                    help="sustained skewed steps before recalibrating")
    ap.add_argument("--inject-drift", action="append", default=[],
                    metavar="HOST:START:END:FACTOR",
                    help="fault injection: HOST ramps linearly to FACTOR× "
                         "slower between START and END (repeatable)")
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.overrides:
        kv = {}
        for pair in args.overrides.split(","):
            k, v = pair.split("=")
            cur = getattr(cfg, k)
            kv[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
        cfg = dataclasses.replace(cfg, **kv)
    if args.attn:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn)
    if args.xent:
        cfg = dataclasses.replace(cfg, xent_impl=args.xent)
    if "pallas" in (cfg.attn_impl, cfg.xent_impl, cfg.ssd_impl):
        # size the kernel tiles for the target part (per-Hardware autotune);
        # mixed clusters get per-group tiles on the plan via compile_plan
        from repro.core import cost_model as _cm
        from repro.kernels.autotune import autotune
        hw = {"tpu_v5e": _cm.TPU_V5E, "v100": _cm.V100_PAPER,
              "p100": _cm.P100_16G, "t4": _cm.T4_16G}[args.hw]
        tiles = autotune(
            hw, head_dim=cfg.hd if cfg.n_heads else cfg.ssd_headdim,
            group=cfg.n_heads // max(cfg.n_kv_heads, 1) or 1,
            d_model=cfg.d_model, vocab=cfg.padded_vocab, seq=args.seq)
        cfg = dataclasses.replace(
            cfg, attn_block_q=tiles.block_q, attn_block_k=tiles.block_k,
            xent_block_t=tiles.xent_block_t, xent_block_v=tiles.xent_block_v,
            ssd_chunk=(tiles.ssd_chunk if cfg.family in ("ssm", "hybrid")
                       else cfg.ssd_chunk))
        print(f"[autotune] {hw.name}: {tiles}")
    from repro.models.lm import build, param_count
    model = build(cfg)

    # ---- optimizer / data / checkpoint (shared by both paths) ----
    sched = Schedule(base_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                     decay_steps=args.steps)
    opt = (adamw(lr=sched) if args.optimizer == "adamw"
           else adafactor(lr=sched))
    dcfg = DataCfg(global_batch=args.batch, seq_len=args.seq,
                   vocab=cfg.vocab, seed=args.seed)
    src_seq = args.src_seq or args.seq
    if cfg.family in ("vlm", "encdec"):
        # multimodal archs consume a modality stream alongside the tokens:
        # patch embeddings for vlm, source frames for encdec
        data = MultimodalPipeline(
            dcfg, modality=cfg.family, d_model=cfg.d_model,
            frontend_len=cfg.frontend_len if cfg.family == "vlm" else 0,
            src_len=src_seq if cfg.family == "encdec" else 0)
    else:
        data = TokenPipeline(dcfg)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    # ---- self-healing controller path (simulated multi-host) ----
    if args.hosts > 1:
        n = len(jax.devices())
        if args.devices_per_host:
            if args.hosts * args.devices_per_host > n:
                raise SystemExit(
                    f"--hosts {args.hosts} × --devices-per-host "
                    f"{args.devices_per_host} exceeds the device count "
                    f"({n})")
            dph = args.devices_per_host
        else:
            if n % args.hosts:
                raise SystemExit(f"--hosts {args.hosts} must divide the "
                                 f"device count ({n})")
            dph = n // args.hosts
        topology = HostTopology.uniform(args.hosts, dph, TPU_V5E)
        scenarios = _parse_injections(args.inject_slow, args.inject_crash,
                                      args.inject_drift,
                                      args.inject_preempt, args.inject_join)
        # nominal clock: injected scenarios play on a fully simulated
        # timeline, so detection is deterministic regardless of machine
        # load (a real deployment feeds measured per-host times instead)
        injector = (FaultInjector(scenarios=scenarios, n_hosts=args.hosts,
                                  seed=args.seed, nominal=0.05)
                    if scenarios else None)
        calibration = None
        if args.calibrate:
            calibration = CalibrationConfig(
                skew=args.drift_skew, patience=args.drift_patience,
                max_rebalances=args.max_rebalances)
        elif args.profile:
            # record + report only: never trigger a rebalance
            calibration = CalibrationConfig(max_rebalances=0)
        ctl = TrainController(
            model, cfg, opt, data, ckpt,
            elastic=ElasticConfig(topology=topology,
                                  patience=args.patience,
                                  warmup=args.straggler_warmup,
                                  max_rebalances=args.max_rebalances,
                                  calibration=calibration),
            batch=args.batch, seq=args.seq, save_every=args.save_every,
            injector=injector, log_every=args.log_every)
        out = ctl.run(args.steps, seed=args.seed)
        if args.profile:
            print(ctl.profiler.report(ctl.topology.cluster_spec()))
        evictions = [e for e in out["events"] if e["kind"] == "evict"]
        recals = [e for e in out["events"] if e["kind"] == "recalibrate"]
        joins = [e for e in out["events"] if e["kind"] == "join"]
        loss_str = (f", loss {out['losses'][0]:.4f} → {out['losses'][-1]:.4f}"
                    if out["losses"] else " (resumed already complete)")
        print(f"[done] step {out['final_step']} phase {out['phase']}, "
              f"{len(evictions)} eviction(s), "
              f"{len(recals)} recalibration(s), "
              f"{len(joins)} join(s){loss_str}")
        return {"final_step": out["final_step"], "losses": out["losses"],
                "events": out["events"], "phase": out["phase"]}

    # ---- mesh & strategy ----
    # the cost model can PRICE a pipelined vlm (the planner/fig10 use it),
    # but the executable layer-stack engine is token-only — it has no slot
    # for the vision frontend or the M-RoPE position tensor, so this
    # driver never routes vlm to pp > 1
    if args.auto:
        # the segment-aware graph lets the search respect frontend/encoder/
        # decoder boundaries when it enumerates pipeline splits
        graph = model.graph(args.batch, args.seq, src_seq=src_seq)
        search_kw = {"max_pp": 1} if cfg.family == "vlm" else {}
        strat = auto_parallel(graph, len(jax.devices()), TPU_V5E,
                              **search_kw)
        print(f"[auto] chose: {strat.describe()}")
        mesh = mesh_for_strategy(strat)
    elif args.pp > 1:
        if cfg.family == "vlm":
            raise SystemExit(
                "--pp does not apply to vlm archs yet: the executable "
                "pipeline engine cannot stage the vision frontend "
                "(train non-pipelined, e.g. --dp, instead)")
        n = len(jax.devices())
        if n < args.pp or n % args.pp:
            raise SystemExit(
                f"--pp {args.pp} needs a device count divisible by the "
                f"stage count; have {n} device(s)")
        strat = StrategySpec(dp=n // args.pp, pp=args.pp,
                             micro_batches=args.micro_batches or 1,
                             schedule=args.schedule or "gpipe")
        mesh = mesh_for_strategy(strat)
    else:
        mesh = parse_mesh(args.mesh) if args.mesh else jax.make_mesh(
            (len(jax.devices()),), ("data",))
        strat = None
    plan = compile_plan(model, mesh, strategy=strat)
    pipelined = plan.strategy.pp > 1 and "stage" in mesh.shape
    if pipelined:
        print(f"[pipeline] {plan.strategy.pp} stages, schedule "
              f"{args.schedule or plan.strategy.schedule}, µb="
              f"{args.micro_batches or plan.strategy.micro_batches}, "
              f"stage_layers {args.stage_layers or 'even/plan'}")

    # ---- init or resume ----
    if pipelined:
        import repro.core.pipeline as pipe
        stage_layers = None
        if args.stage_layers:
            if model.stack is None:
                raise SystemExit("--stage-layers does not apply to encdec "
                                 "archs: the pipeline cut is the fixed "
                                 "encoder|decoder tower edge")
            stage_layers = tuple(int(x) for x in args.stage_layers.split(","))
            pipe.check_stage_layers(stage_layers, model.stack.n_rep,
                                    plan.strategy.pp)
        params = plan.init_pipeline_params(jax.random.key(args.seed),
                                           stage_layers=stage_layers)
        with mesh:
            opt_state = jax.jit(opt.init)(params)
    else:
        with mesh:
            params = plan.init_params(jax.random.key(args.seed))
            opt_state = jax.jit(opt.init)(params)
    start_step = 0
    resume = ckpt.restore_latest({"params": params, "opt": opt_state})
    if resume is not None:
        start_step, tree, extra = resume
        params, opt_state = tree["params"], tree["opt"]
        if "data" in extra:
            data.load_state_dict(extra["data"])
        print(f"[resume] from step {start_step}")

    # exactly-once data, same discipline as TrainController: batches are
    # fetched idempotently per step (a retried step replays the SAME batch)
    # and checkpoints record the position of the committed step — the jit
    # warm-up example below is the batch of start_step, not a burned draw
    fetched = {"step": start_step - 1, "batch": None, "before": None}

    def batch_for(i):
        if fetched["step"] != i:
            fetched["before"] = data.state_dict()
            fetched["batch"] = {k: jnp.asarray(v)
                                for k, v in data.next_batch().items()}
            fetched["step"] = i
        return fetched["batch"]

    def data_state_at(s):
        if s == fetched["step"] and fetched["before"] is not None:
            return dict(fetched["before"])     # save at the failed step
        return data.state_dict()

    batch0 = batch_for(start_step)
    with mesh:
        if pipelined:
            step_fn = plan.jit_pipeline_train_step(
                opt, micro_batches=args.micro_batches,
                schedule=args.schedule, stage_layers=stage_layers)
        else:
            step_fn = plan.jit_train_step(
                opt, batch0, micro_batches=args.micro_batches,
                compress_pod=args.compress_pod)

    n_params = param_count(params)
    print(f"[train] {cfg.name}: {n_params:,} params, mesh "
          f"{dict(mesh.shape)}, {args.steps} steps")

    monitor = StragglerMonitor()
    profiler = None
    if args.profile:
        # whole-step observations against the executed strategy's feature
        # vector on the --hw table; the exit report shows how far the
        # hand-written rates are from this machine's measured ones
        from repro.core import cost_model as _cm
        prof_hw = {"tpu_v5e": _cm.TPU_V5E, "v100": _cm.V100_PAPER,
                   "p100": _cm.P100_16G, "t4": _cm.T4_16G}[args.hw]
        prof_meta = model.graph(args.batch, args.seq,
                                src_seq=src_seq).workload_meta()
        prof_feats = step_cost_features(prof_meta, plan.strategy, prof_hw)
        profiler = Profiler()
    losses = []
    state0 = {"params": params, "opt": opt_state}
    if args.compress_pod and "pod" in mesh.shape:
        from repro.optim import grad_compress
        state0["err"] = grad_compress.init_error_tree(params)

    def one_step(i, st):
        batch = batch_for(i)
        with mesh:
            if pipelined and "frames" in batch:
                # encdec two-tower pipeline: encoder memory ships over the
                # stage wire, so the step consumes frames AND tokens
                p, o, loss = step_fn(st["params"], st["opt"],
                                     batch["frames"], batch["tokens"],
                                     jnp.asarray(i))
                new, m = {"params": p, "opt": o}, {"loss": loss}
            elif pipelined:
                p, o, loss = step_fn(st["params"], st["opt"],
                                     batch["tokens"], jnp.asarray(i))
                new, m = {"params": p, "opt": o}, {"loss": loss}
            elif "err" in st:
                p, o, m, e = step_fn(st["params"], st["opt"], batch,
                                     jnp.asarray(i), st["err"])
                new = {"params": p, "opt": o, "err": e}
            else:
                p, o, m = step_fn(st["params"], st["opt"], batch,
                                  jnp.asarray(i))
                new = {"params": p, "opt": o}
        losses.append(float(m["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"  step {i:5d}  loss {losses[-1]:.4f}")
        return new

    def on_step(i, st, dt):
        if profiler is not None and i > start_step:
            profiler.record_step(prof_hw.name, dt, prof_feats, step=i)
        if monitor.observe(dt):       # one-shot: True on the flag transition
            print(f"[straggler] flagged at step {i} "
                  f"(dt={dt:.3f}s vs mean {monitor.mean:.3f}s)")
            monitor.reset()           # keep training; eviction is external

    loop = FaultTolerantLoop(ckpt, save_every=args.save_every)
    final_step, state = loop.run(
        state=state0, step_fn=one_step, n_steps=args.steps,
        start_step=start_step,
        extra_fn=lambda st, s: {"data": data_state_at(s)},
        on_step=on_step)

    if profiler is not None:
        from repro.core.cost_model import ClusterSpec
        print(profiler.report(ClusterSpec.homogeneous(prof_hw,
                                                      len(jax.devices()))))
    loss_str = (f", loss {losses[0]:.4f} → {losses[-1]:.4f}" if losses
                else " (resumed already complete)")
    print(f"[done] step {final_step}{loss_str}")
    return {"final_step": final_step, "losses": losses}


if __name__ == "__main__":
    main()

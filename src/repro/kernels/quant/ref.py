"""Pure-jnp oracle for per-block int8 quantize/dequantize."""
from __future__ import annotations

import jax.numpy as jnp


def quant_ref(x: jnp.ndarray, block: int = 256):
    """x: (T,) f32 → (q (T,) int8, scales (T/block,) f32).

    Symmetric per-block scaling: s = max|x_block| / 127, q = round(x/s).
    """
    T = x.shape[0]
    nb = T // block
    xb = x.astype(jnp.float32).reshape(nb, block)
    s = jnp.max(jnp.abs(xb), axis=1) / 127.0
    s = jnp.maximum(s, 1e-30)
    q = jnp.clip(jnp.round(xb / s[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(T), s


def dequant_ref(q: jnp.ndarray, s: jnp.ndarray, block: int = 256):
    nb = s.shape[0]
    return (q.astype(jnp.float32).reshape(nb, block) * s[:, None]).reshape(-1)

"""Jit'd wrappers for the quant kernels."""
from __future__ import annotations

import functools

import jax

from repro.kernels.quant.quant import dequantize, quantize


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quant(x, *, block: int = 256, interpret: bool = False):
    return quantize(x, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant(q, s, *, block: int = 256, interpret: bool = False):
    return dequantize(q, s, block=block, interpret=interpret)

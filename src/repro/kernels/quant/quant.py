"""Pallas TPU per-block int8 quantize / dequantize.

The gradient-compression encode/decode (optim/grad_compress.py) runs once
per step over every gradient byte — on the critical path right before the
DCN all-reduce.  Fusing abs-max → scale → round → clip into one VMEM pass
reads the gradient once and writes q + scales once (the unfused jnp version
makes three HBM passes: abs-max reduce, divide, round/clip).

Grid: 1-D over blocks of ``block`` elements; each program loads its (block,)
tile into VMEM, computes the local abs-max (VPU reduce), scales, rounds and
writes the int8 tile + its fp32 scale.  ``block=256·1024`` keeps each tile
a 1 MiB VMEM resident with 4 live buffers (in, out, scale, iota-free).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-30)
    q_ref[...] = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    s_ref[0] = s


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0]


def quantize(x: jax.Array, *, block: int = 256,
             interpret: bool = False):
    """x: (T,) → (q int8 (T,), scales f32 (T/block,)).  T % block == 0."""
    T = x.shape[0]
    if T % block:
        raise ValueError(f"T={T} must divide block={block}")
    nb = T // block
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((T,), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)),
        interpret=interpret,
    )(x)
    return q, s


def dequantize(q: jax.Array, s: jax.Array, *, block: int = 256,
               interpret: bool = False) -> jax.Array:
    T = q.shape[0]
    nb = T // block
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        interpret=interpret,
    )(q, s)

from repro.kernels.quant.ops import dequant, quant  # noqa: F401
from repro.kernels.quant.quant import dequantize, quantize  # noqa: F401
from repro.kernels.quant.ref import dequant_ref, quant_ref  # noqa: F401

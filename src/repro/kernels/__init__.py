"""Pallas TPU kernels for the perf-critical compute layers.

All kernels are TPU-targeted (pl.pallas_call + BlockSpec VMEM tiling) and
validated in interpret mode on CPU against pure-jnp oracles (ref.py).
"""

"""Pallas TPU fused vocab-tiled softmax cross-entropy.

The paper's Fig-4 hot spot: a 100k-way (here up to 256k-way) classifier
whose logits tensor dwarfs everything else.  The kernel never materialises
(T, V) logits in HBM — it streams vocab tiles through VMEM and maintains the
online max / sum-exp / label-logit reduction per token row:

- Grid ``(nt, nv)``: token-block × vocab-block, vocab as the *minor*
  (fastest-moving) axis so the (block_t, E) hidden tile stays resident in
  VMEM across the whole vocab sweep while weight tiles (E, block_v) stream
  through — one HBM pass over the head weights per token block.
- The partial state (m, l, correct) is carried in the *output* refs across
  grid steps (TPU grids execute sequentially over the minor axis, the
  standard Pallas accumulation idiom) and finalised on the last vocab tile.
- The (block_t, block_v) logits tile is MXU-shaped ((128, 512) by default)
  and exists only in VMEM: HBM traffic drops from O(T·V) to O(T·E + E·V),
  which is what makes the 256k-vocab gemma/seamless heads trainable.
- Composes with the paper's operator-split: under a vocab-sharded head each
  shard runs the kernel on its V/tp slice and the (m, l, correct) triples
  are combined with three tiny all-reduces (see models/lm.chunked_xent).

Backward is analytic (softmax − onehot), recomputing logits tile-by-tile —
same memory profile (custom_vjp in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _xent_kernel(h_ref, w_ref, lab_ref, nll_ref, lse_ref, m_ref, l_ref,
                 c_ref, *, block_t: int, block_v: int, vocab: int):
    """Program (ti, vi): logits tile = h_tile @ w_tile, online reduce."""
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    h = h_ref[...].astype(jnp.float32)                       # (bt, E)
    w = w_ref[...].astype(jnp.float32)                       # (E, bv)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    col = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_t, block_v), 1)
    logits = jnp.where(col < vocab, logits, NEG_INF)         # padded cols

    lab = lab_ref[...]                                       # (bt,)
    hit = (col == lab[:, None])
    corr_tile = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full((block_t,), NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros((block_t,), jnp.float32)
        c_ref[...] = jnp.zeros((block_t,), jnp.float32)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.exp(logits - m_new[:, None]).sum(axis=-1)
    m_ref[...] = m_new
    l_ref[...] = l_new
    c_ref[...] = c_ref[...] + corr_tile

    @pl.when(vi == nv - 1)
    def _finalize():
        lse = jnp.log(jnp.maximum(l_ref[...], 1e-30)) + m_ref[...]
        lse_ref[...] = lse
        nll_ref[...] = lse - c_ref[...]


def xent_fwd(hidden: jax.Array, head_w: jax.Array, labels: jax.Array, *,
             vocab: int | None = None, block_t: int = 128,
             block_v: int = 512, interpret: bool = False):
    """hidden: (T, E)  head_w: (E, V)  labels: (T,) → (nll, lse) each (T,)."""
    T, E = hidden.shape
    V = head_w.shape[1]
    vocab = vocab or V
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    if T % block_t or V % block_v:
        raise ValueError(f"(T={T}, V={V}) must divide blocks "
                         f"({block_t}, {block_v})")
    nt, nv = T // block_t, V // block_v

    out_shapes = (
        jax.ShapeDtypeStruct((T,), jnp.float32),   # nll
        jax.ShapeDtypeStruct((T,), jnp.float32),   # lse
        jax.ShapeDtypeStruct((T,), jnp.float32),   # m (scratch-as-output)
        jax.ShapeDtypeStruct((T,), jnp.float32),   # l
        jax.ShapeDtypeStruct((T,), jnp.float32),   # correct
    )
    row = pl.BlockSpec((block_t,), lambda t, v: (t,))
    nll, lse, _, _, _ = pl.pallas_call(
        functools.partial(_xent_kernel, block_t=block_t, block_v=block_v,
                          vocab=vocab),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((block_t, E), lambda t, v: (t, 0)),
            pl.BlockSpec((E, block_v), lambda t, v: (0, v)),
            row,
        ],
        out_specs=(row, row, row, row, row),
        out_shape=out_shapes,
        interpret=interpret,
    )(hidden, head_w, labels)
    return nll, lse

"""Public wrapper for the fused xent kernel with an analytic custom VJP.

Forward: the Pallas kernel (never materialises (T, V) logits).
Backward: d_logits = softmax − onehot(label); dh = d_logits @ Wᵀ and
dW = hᵀ @ d_logits are computed *tile-by-tile over the vocab* with the saved
(lse) — logits are recomputed per tile, so the backward has the same O(T·E +
E·V) HBM profile as the forward (flash-style recompute-in-backward, here in
plain jnp over vocab chunks since the contraction itself is a plain matmul
XLA already runs at roofline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.xent.xent import xent_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def xent(hidden, head_w, labels, vocab=None, block_t=128, block_v=512,
         interpret=False):
    """hidden (T, E), head_w (E, V), labels (T,) → nll (T,) fp32."""
    nll, _ = xent_fwd(hidden, head_w, labels, vocab=vocab, block_t=block_t,
                      block_v=block_v, interpret=interpret)
    return nll


def _fwd(hidden, head_w, labels, vocab, block_t, block_v, interpret):
    nll, lse = xent_fwd(hidden, head_w, labels, vocab=vocab, block_t=block_t,
                        block_v=block_v, interpret=interpret)
    return nll, (hidden, head_w, labels, lse)


def _bwd(vocab, block_t, block_v, interpret, res, g):
    hidden, head_w, labels, lse = res
    T, E = hidden.shape
    V = head_w.shape[1]
    vocab_ = vocab or V
    nvc = max(V // max(block_v, 1), 1)
    chunk = V // nvc
    hf = hidden.astype(jnp.float32)
    col0 = jnp.arange(chunk)

    def tile(i, carry):
        dh, dw = carry
        w_t = jax.lax.dynamic_slice(head_w, (0, i * chunk), (E, chunk)) \
            .astype(jnp.float32)
        logits = hf @ w_t
        col = col0[None, :] + i * chunk
        p = jnp.where(col < vocab_,
                      jnp.exp(logits - lse[:, None]), 0.0)       # softmax tile
        p = p - jnp.where(col == labels[:, None], 1.0, 0.0)      # − onehot
        p = p * g[:, None]                                       # chain rule
        dh = dh + p @ w_t.T
        dw = jax.lax.dynamic_update_slice(dw, hf.T @ p, (0, i * chunk))
        return dh, dw

    dh0 = jnp.zeros((T, E), jnp.float32)
    dw0 = jnp.zeros((E, V), jnp.float32)
    dh, dw = jax.lax.fori_loop(0, nvc, tile, (dh0, dw0))
    return dh.astype(hidden.dtype), dw.astype(head_w.dtype), None


xent.defvjp(_fwd, _bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def xent_with_lse(hidden, head_w, labels, vocab=None, block_t=128,
                  block_v=512, interpret=False):
    """Like :func:`xent` but also returns lse (T,) — differentiably.

    The LM loss needs lse for the z-loss term (z = lse² regulariser), so
    both outputs carry cotangents.  With g = (g_nll, g_lse):

        d_logits = g_nll·(softmax − onehot) + g_lse·softmax

    computed with the same recompute-over-vocab-tiles loop as :func:`xent`.
    """
    return xent_fwd(hidden, head_w, labels, vocab=vocab, block_t=block_t,
                    block_v=block_v, interpret=interpret)


def _fwd_lse(hidden, head_w, labels, vocab, block_t, block_v, interpret):
    nll, lse = xent_fwd(hidden, head_w, labels, vocab=vocab, block_t=block_t,
                        block_v=block_v, interpret=interpret)
    return (nll, lse), (hidden, head_w, labels, lse)


def _bwd_lse(vocab, block_t, block_v, interpret, res, g):
    hidden, head_w, labels, lse = res
    g_nll, g_lse = g
    T, E = hidden.shape
    V = head_w.shape[1]
    vocab_ = vocab or V
    nvc = max(V // max(block_v, 1), 1)
    chunk = V // nvc
    hf = hidden.astype(jnp.float32)
    col0 = jnp.arange(chunk)
    g_nll = g_nll.astype(jnp.float32)
    g_lse = g_lse.astype(jnp.float32)

    def tile(i, carry):
        dh, dw = carry
        w_t = jax.lax.dynamic_slice(head_w, (0, i * chunk), (E, chunk)) \
            .astype(jnp.float32)
        logits = hf @ w_t
        col = col0[None, :] + i * chunk
        p = jnp.where(col < vocab_,
                      jnp.exp(logits - lse[:, None]), 0.0)       # softmax tile
        onehot = jnp.where(col == labels[:, None], 1.0, 0.0)
        d = g_nll[:, None] * (p - onehot) + g_lse[:, None] * p
        dh = dh + d @ w_t.T
        dw = jax.lax.dynamic_update_slice(dw, hf.T @ d, (0, i * chunk))
        return dh, dw

    dh0 = jnp.zeros((T, E), jnp.float32)
    dw0 = jnp.zeros((E, V), jnp.float32)
    dh, dw = jax.lax.fori_loop(0, nvc, tile, (dh0, dw0))
    return dh.astype(hidden.dtype), dw.astype(head_w.dtype), None


xent_with_lse.defvjp(_fwd_lse, _bwd_lse)

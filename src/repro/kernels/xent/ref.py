"""Pure-jnp oracle for fused vocab-tiled softmax cross-entropy."""
from __future__ import annotations

import jax.numpy as jnp


def xent_ref(hidden: jnp.ndarray, head_w: jnp.ndarray, labels: jnp.ndarray,
             *, vocab: int | None = None):
    """hidden: (T, E)  head_w: (E, V)  labels: (T,) → (nll (T,), lse (T,)).

    Full-materialisation reference: logits = h @ W, nll = lse − logit[label].
    ``vocab``: mask columns ≥ vocab (padded head).
    """
    logits = (hidden.astype(jnp.float32) @ head_w.astype(jnp.float32))
    V = head_w.shape[1]
    if vocab is not None and vocab < V:
        col = jnp.arange(V)
        logits = jnp.where(col[None, :] < vocab, logits, -1e30)
    m = logits.max(axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)) + m
    correct = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - correct, lse

from repro.kernels.xent.ops import xent  # noqa: F401
from repro.kernels.xent.ref import xent_ref  # noqa: F401
from repro.kernels.xent.xent import xent_fwd  # noqa: F401

"""Per-``Hardware`` kernel tile autotuner (the hetero "kernel speed pass").

Whale shapes *work* per hardware tier (its load balancers hand a P100 group
fewer layers/smaller batches than a V100 group); this module applies the
same idea one level down, to *tile geometry*: the same Pallas kernel should
tile differently on a part with 4 MiB of fast on-chip memory and a 10:1
compute/bandwidth ratio than on one with 16 MiB and 130:1.

The choice is analytic (the repo's meta-driven idiom — nothing is run):

- **cap** — roofline arithmetic-intensity target.  A flash tile of side
  ``t`` reuses each loaded K/V byte ~``t`` times, so to keep the MXU fed
  we want ``t ≳ flops_per_hbm_byte``; we aim at 4× the balance point and
  clamp to [64, 512] (the MXU is 128×128 — below 64 the systolic array
  starves, above 512 latency/VMEM pressure dominate).  Computed caps:
  TPU-v5e 512, T4 512, V100 256, P100 64 — so a V100 group and a P100
  group in the same job really do tile differently.
- **fit** — the largest power-of-two tile ≤ cap whose VMEM working set
  (modelled per kernel family below) fits half the part's ``vmem_bytes``
  (half: double-buffered async copies need the other half).

Both criteria are monotone in (``vmem_bytes``, ``flops_per_hbm_byte``), so
a strictly smaller part never gets a larger tile — property-tested in
tests/test_autotune.py.  One deliberate exception: the xent *vocab* tile
shares its budget with the token tile, so when a lower compute ratio
shrinks ``bt`` the freed bytes may widen ``bv`` — the joint working set
still shrinks with the part.

Sequence-fitting: chosen tiles are powers of two, and the model layer pads
sequences/vocab to multiples of the tile anyway; when an actual length is
known, :func:`fit_block` snaps a tile down to the largest divisor.
"""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import Hardware

# today's fixed constants (pre-autotune defaults) — unknown hardware and
# ``autotune(None)`` fall back to exactly these.
DEFAULT_TILES = None  # set below, after KernelTiles is defined

_MIN_TILE, _MAX_TILE = 64, 512


@dataclasses.dataclass(frozen=True)
class KernelTiles:
    """One device group's tile geometry for every fused-kernel family."""
    block_q: int = 128          # flash attention q-tile rows
    block_k: int = 128          # flash attention kv-tile rows
    xent_block_t: int = 128     # fused-xent token tile
    xent_block_v: int = 512     # fused-xent vocab tile
    ssd_chunk: int = 128        # SSD intra-chunk length
    page_size: int = 64         # paged-KV decode page rows (serving)

    def shrink_to(self, seq: int | None = None, vocab: int | None = None
                  ) -> "KernelTiles":
        """Snap tiles down to divisors of actual (padded) lengths."""
        return dataclasses.replace(
            self,
            block_q=fit_block(seq, self.block_q) if seq else self.block_q,
            block_k=fit_block(seq, self.block_k) if seq else self.block_k,
            xent_block_v=(fit_block(vocab, self.xent_block_v) if vocab
                          else self.xent_block_v),
            ssd_chunk=fit_block(seq, self.ssd_chunk) if seq else self.ssd_chunk,
        )


DEFAULT_TILES = KernelTiles()


def fit_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (≥ 1 always exists)."""
    if n <= 0:
        raise ValueError(f"length must be positive, got {n}")
    t = min(target, n)
    while n % t:
        t -= 1
    return t


def _pow2_floor(x: float) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def _pow2_ceil(x: float) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _cap(hw: Hardware) -> int:
    """Roofline tile-side target for this part, clamped to [64, 512]."""
    return max(_MIN_TILE, min(_MAX_TILE,
                              _pow2_ceil(4 * hw.flops_per_hbm_byte)))


def _largest_fitting(budget: float, cap: int, bytes_at) -> int:
    """Largest power-of-two tile ≤ cap with bytes_at(tile) ≤ budget."""
    t = _pow2_floor(cap)
    while t > 8 and bytes_at(t) > budget:
        t //= 2
    return t


def autotune(hw: Hardware | None, *, head_dim: int = 128, group: int = 1,
             d_model: int | None = None, vocab: int | None = None,
             seq: int | None = None) -> KernelTiles:
    """Pick tile sizes for one hardware part.

    ``hw=None`` (unknown/absent hardware table) returns today's defaults.
    ``seq``/``vocab``, when given, snap the result onto actual lengths.
    """
    if hw is None:
        return DEFAULT_TILES.shrink_to(seq=seq, vocab=vocab)

    cap = _cap(hw)
    budget = hw.vmem_bytes / 2          # other half: double buffering
    f32 = 4

    # flash: square-ish tile t×t; resident = q/do/acc rows (3·t·G·D) +
    # k/v tile (2·t·D) + score tile (t·G × t), all f32 in-kernel.
    D, G = head_dim, group
    bq = _largest_fitting(
        budget, cap,
        lambda t: f32 * (3 * t * G * D + 2 * t * D + t * G * t))
    tiles_bk = bq                       # symmetric tiles: one roofline knob

    # fused xent: resident = hidden tile (bt·E) + head tile (E·bv) +
    # logits tile (bt·bv).  Token tile tracks the flash tile; the vocab
    # tile is the wide axis (vocab ≫ seq) and gets up to 4× the cap.
    E = d_model or 8 * head_dim
    bt = bq
    bv = _largest_fitting(
        budget, min(4 * cap, 2048),
        lambda t: f32 * (bt * E + E * t + bt * t))

    # SSD: chunk c holds x/dt/B/C slabs (~4·c·D) + the c×c intra-chunk
    # attention-like matrix per head group.
    chunk = _largest_fitting(
        budget, cap, lambda t: f32 * (4 * t * D + t * t))

    # paged-KV decode page: one grid step holds a (page, D) k and v tile,
    # the (G, page) score strip and the (G, D) q/acc strips.  The page is
    # both the kernel tile AND the allocator granularity, so it is capped
    # at 256 — larger pages waste allocator granularity faster than they
    # buy arithmetic intensity (decode is bandwidth-bound regardless).
    page = _largest_fitting(
        budget, min(cap, 256),
        lambda t: f32 * (2 * t * D + G * t + 2 * G * D))

    return KernelTiles(block_q=bq, block_k=tiles_bk, xent_block_t=bt,
                       xent_block_v=bv, ssd_chunk=chunk, page_size=page
                       ).shrink_to(seq=seq, vocab=vocab)


def autotune_cluster(cluster, *, head_dim: int = 128, group: int = 1,
                     d_model: int | None = None, vocab: int | None = None,
                     seq: int | None = None) -> dict:
    """Tiles for every :class:`DeviceGroup` in a :class:`ClusterSpec`.

    Returns ``{group.name: KernelTiles}``.  In a mixed V100+P100 job each
    group tiles for its own part — the per-group model functions the
    hetero planner builds then carry different static block sizes.
    """
    return {g.name: autotune(g.hw, head_dim=head_dim, group=group,
                             d_model=d_model, vocab=vocab, seq=seq)
            for g in cluster.groups}

"""Pure-jnp oracle for the SSD (state-space dual) chunked scan.

Sequential (per-token) recurrence — the unambiguous ground truth:
    h_t = exp(dt_t · A) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray,
            h0: jnp.ndarray | None = None):
    """x: (B, S, H, P)  dt: (B, S, H)  A: (H,)  Bm/Cm: (B, S, G, N).

    Returns y: (B, S, H, P) f32 and final state (B, H, P, N) f32.
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)    # (B, S, H, N)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, None, :])                    # (B, S, H)

    def step(h, inp):
        xt, dAt, dtt, Bt, Ct = inp
        h = h * dAt[..., None, None] + (
            dtt[..., None, None] * xt[..., None] * Bt[:, :, None, :])
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    init = jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0
    hT, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dA, 1, 0),
         jnp.moveaxis(dtf, 1, 0), jnp.moveaxis(Bh, 1, 0),
         jnp.moveaxis(Ch, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), hT

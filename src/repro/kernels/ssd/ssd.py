"""Pallas TPU SSD (state-space duality) chunked-scan kernel.

Mamba2's SSD decomposes the linear recurrence into (i) an intra-chunk
*quadratic dual form* — dense (Q, Q) decay-masked attention that runs on the
MXU — and (ii) an inter-chunk state recurrence with O(state) carry.  GPU
implementations split this into 4-5 separate kernels + a host-level scan;
on TPU we fuse everything into ONE grid walk:

- Grid ``(B, H, L)`` with L (chunk index) as the *minor* sequential axis:
  TPU grid steps execute in order, so the running state h ∈ (P, N) lives in
  a VMEM scratch buffer across chunk steps — the inter-chunk recurrence
  costs zero HBM traffic (the GPU version round-trips states through HBM).
- Per program: load the chunk's (Q, P) x-tile and (Q, N) B/C tiles, build
  the (Q, Q) decay mask from the dt cumsum, do the three MXU matmuls
  (CBᵀ∘L)·x, state read C·h, and state update Bᵀ·(decay∘x).
- Chunk Q defaults to 128: the (Q, Q) mask matmul and (Q, N)×(N, P)
  contractions are all 128-aligned for the MXU.

Validated in interpret mode against the sequential-scan oracle (ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk: int, headdim: int, d_state: int):
    """Program (b, h, l): one chunk of one head of one batch row.

    x_ref: (Q, P)  dt_ref: (Q,)  a_ref: (1,)  b_ref/c_ref: (Q, N)
    y_ref: (Q, P)  hout_ref: (P, N)  h_scr: (P, N) VMEM carry.
    """
    li = pl.program_id(2)
    nl = pl.num_programs(2)
    Q, P, N = chunk, headdim, d_state

    @pl.when(li == 0)
    def _init():
        h_scr[...] = jnp.zeros((P, N), jnp.float32)

    x = x_ref[...].astype(jnp.float32)              # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)            # (Q,)
    A = a_ref[0]                                    # scalar (negative)
    Bm = b_ref[...].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[...].astype(jnp.float32)             # (Q, N)

    dA = dt * A                                     # (Q,) ≤ 0
    cum = jnp.cumsum(dA)                            # (Q,)
    # intra-chunk decay mask  L[i, j] = exp(cum_i − cum_j) · (i ≥ j)
    seg = cum[:, None] - cum[None, :]
    iota = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jota = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmask = jnp.where(iota >= jota, jnp.exp(seg), 0.0)

    xd = x * dt[:, None]                            # dt-weighted input
    # --- dual quadratic form on the MXU ---
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    y_diag = jax.lax.dot_general(scores * Lmask, xd,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, P)
    # --- carried-state contribution: y_off = (C · h) ∘ exp(cum) ---
    h = h_scr[...]                                  # (P, N)
    y_off = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (Q, P)
    y_ref[...] = (y_diag + y_off * jnp.exp(cum)[:, None]).astype(y_ref.dtype)

    # --- state update: h' = exp(sum dA) · h + Σ_q exp(cum_Q − cum_q) Bq ⊗ xdq
    total = cum[Q - 1]
    decay_to_end = jnp.exp(total - cum)             # (Q,)
    state_upd = jax.lax.dot_general(
        xd * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (P, N)
    h_new = h * jnp.exp(total) + state_upd
    h_scr[...] = h_new

    @pl.when(li == nl - 1)
    def _emit():
        hout_ref[...] = h_new


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128,
                    interpret: bool = False):
    """x: (B, S, H, P)  dt: (B, S, H)  A: (H,)  Bm/Cm: (B, S, G, N).

    → (y (B, S, H, P) f32, final state (B, H, P, N) f32).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} must divide chunk={chunk}")
    L = S // chunk
    rep = H // G
    if rep > 1:   # broadcast groups to heads for uniform BlockSpecs
        Bm = jnp.repeat(Bm, rep, axis=2)
        Cm = jnp.repeat(Cm, rep, axis=2)

    grid = (Bsz, H, L)
    y, hT = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, headdim=P, d_state=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, None, P), lambda b, h, l: (b, l, h, 0)),
            pl.BlockSpec((None, chunk, None), lambda b, h, l: (b, l, h)),
            pl.BlockSpec((1,), lambda b, h, l: (h,)),
            pl.BlockSpec((None, chunk, None, N), lambda b, h, l: (b, l, h, 0)),
            pl.BlockSpec((None, chunk, None, N), lambda b, h, l: (b, l, h, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, chunk, None, P), lambda b, h, l: (b, l, h, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, h, l: (b, h, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Bsz, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, hT

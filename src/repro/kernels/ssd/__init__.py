from repro.kernels.ssd.ops import ssd  # noqa: F401
from repro.kernels.ssd.ref import ssd_ref  # noqa: F401
from repro.kernels.ssd.ssd import ssd_scan_pallas  # noqa: F401

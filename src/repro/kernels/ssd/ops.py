"""Jit'd public wrapper for the Pallas SSD kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.ssd import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,G,N) → (y, final_state)."""
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)

"""Jit'd public wrapper for the Pallas flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash(q, k, v, *, causal: bool = True, block_q: int = 128,
          block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, D), k/v: (B, Sk, K, D) → (B, Sq, H, D)."""
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)

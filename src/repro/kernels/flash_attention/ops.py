"""Differentiable public op for the Pallas flash-attention kernels.

``pallas_call`` has no autodiff rule, so :func:`flash` carries an explicit
``jax.custom_vjp`` that routes the backward through the fused recompute
kernels in ``flash.py``.  Residual policy follows the stack-level
``attn_bwd_remat`` flag:

- ``bwd_remat=True`` (memory-lean, the flash paper's default): save only
  (q, k, v, lse) — O(S) extra — and *re-run the forward kernel* in the
  backward to rebuild ``o`` for the δ = rowsum(do∘o) reduction.
- ``bwd_remat=False``: additionally save ``o`` (O(S·D)) and skip the
  forward recompute — one fewer kernel launch at higher residency, the
  same trade ``models/attention.py`` exposes for the ref path.

Either way no (Sq, Sk) score matrix is ever materialised: both backward
kernels rebuild score tiles in VMEM from (q, k, lse).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import (flash_attention,
                                                 flash_attention_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash(q, k, v, causal: bool = True, block_q: int = 128,
          block_k: int = 128, interpret: bool = False,
          bwd_remat: bool = True):
    """q: (B, Sq, H, D), k/v: (B, Sk, K, D) → (B, Sq, H, D).

    Differentiable: fwd and bwd both run fused Pallas kernels.
    """
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, causal, block_q, block_k, interpret, bwd_remat):
    out, lse = flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret,
                               return_lse=True)
    res = (q, k, v, lse) if bwd_remat else (q, k, v, lse, out)
    return out, res


def _flash_bwd(causal, block_q, block_k, interpret, bwd_remat, res, do):
    if bwd_remat:
        q, k, v, lse = res
        out = flash_attention(q, k, v, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    else:
        q, k, v, lse, out = res
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    # δ_i = Σ_d do_i·o_i — cheap elementwise reduce, laid out like lse
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).reshape(B, Sq, K, G)
    dq, dk, dv = flash_attention_bwd(q, k, v, do, lse, delta, causal=causal,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    return dq, dk, dv


flash.defvjp(_flash_fwd, _flash_bwd)

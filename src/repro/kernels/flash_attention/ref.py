"""Pure-jnp oracle for blocked causal GQA attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, D)  k/v: (B, Sk, K, D), H = K·G → (B, Sq, H, D).

    Naive full-materialisation softmax attention in f32.
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, Sq, K, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / (D ** 0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)

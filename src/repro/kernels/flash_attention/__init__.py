from repro.kernels.flash_attention.flash import (  # noqa: F401
    flash_attention, flash_attention_bwd)
from repro.kernels.flash_attention.ops import flash  # noqa: F401
from repro.kernels.flash_attention.paged import paged_decode  # noqa: F401
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401

"""Pallas paged flash-decode: block-table-indexed attention over page pools.

The serving tier's paged KV cache (DESIGN.md §9) stores KV in fixed-size
physical pages ``(P, page_size, K, D)``; each decode slot owns a row of a
``(B, max_pages)`` block table mapping logical page *j* to a physical page.
This kernel computes one decode step's attention reading KV **through the
block table** — the gap pages a dense cache would stream (slots reserve
``max_len`` but hold ``pos`` tokens) are never touched.

TPU-native shape, following ``flash.py``:

- Grid ``(B, K, max_pages)`` with the page index innermost.  The page loop
  must be a *grid* dimension (not an in-kernel ``fori_loop``) because the
  physical page address is data-dependent: the k/v BlockSpec index_map
  reads the scalar-prefetched block table — ``(bt[b, j], 0, k, 0)`` — and
  the Pallas pipeline DMAs exactly that page into VMEM.  That indirection
  is the whole trick; everything else is flash-decode.
- ``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=2)``: the block table
  and positions arrive in SMEM before the body runs, so index_maps can use
  them.
- The online-softmax carry (m, l, acc) lives in VMEM scratch, initialised
  at ``j == 0`` and flushed to the output at ``j == max_pages − 1`` —
  scratch persists across sequential grid steps exactly like the training
  kernels' fori-loop carry.
- Positions ≥ ``pos[b]`` mask to NEG_INF; unallocated block-table entries
  point at the all-zero trash page 0 and are fully masked anyway, so the
  kernel needs no "is this page live" branch.

Validated in interpret mode on CPU against the gather-based ref path
(``models.attention.paged_decode_attention(impl="ref")``); on TPU the same
code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_sc, l_sc, acc_sc, *, page_size: int, group: int,
                         head_dim: int, max_pages: int):
    """One (batch-slot, kv-head, logical-page) program.

    bt_ref: (B, max_pages) SMEM   pos_ref: (B,) SMEM
    q_ref: (G·D,) VMEM            k_ref/v_ref: (page_size, D) VMEM (the
    physical page the index_map resolved)    o_ref: (G·D,) VMEM
    m_sc/l_sc: (G, 1) f32 scratch   acc_sc: (G, D) f32 scratch
    """
    b, j = pl.program_id(0), pl.program_id(2)
    G, D, ps = group, head_dim, page_size

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full((G, 1), NEG_INF, jnp.float32)
        l_sc[...] = jnp.zeros((G, 1), jnp.float32)
        acc_sc[...] = jnp.zeros((G, D), jnp.float32)

    q = q_ref[...].reshape(G, D).astype(jnp.float32) * (D ** -0.5)
    kj = k_ref[...].astype(jnp.float32)                      # (ps, D)
    vj = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, kj, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (G, ps)
    kpos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (G, ps), 1)
    s = jnp.where(kpos <= pos_ref[b], s, NEG_INF)

    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    m_sc[...] = m_new
    l_sc[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p, vj, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == max_pages - 1)
    def _flush():
        out = acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)
        o_ref[...] = out.reshape(G * D).astype(o_ref.dtype)


def paged_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                 block_table: jax.Array, pos: jax.Array, *,
                 interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_pool/v_pool: (P, page_size, K, D);
    block_table: (B, max_pages) int32; pos: (B,) int32 → (B, H, D).

    The new token's KV must already be written into the pools (the caller
    scatters first, then attends — ``kpos <= pos`` includes the new cell).
    """
    B, H, D = q.shape
    P, ps, K, _ = k_pool.shape
    G = H // K
    max_pages = block_table.shape[1]
    qr = q.reshape(B, K, G * D)

    kernel = functools.partial(
        _paged_decode_kernel, page_size=ps, group=G, head_dim=D,
        max_pages=max_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, max_pages),
        in_specs=[
            pl.BlockSpec((None, None, G * D),
                         lambda b, h, j, bt, ps_: (b, h, 0)),
            pl.BlockSpec((None, ps, None, D),
                         lambda b, h, j, bt, ps_: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((None, ps, None, D),
                         lambda b, h, j, bt, ps_: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G * D),
                               lambda b, h, j, bt, ps_: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G * D), q.dtype),
        interpret=interpret,
    )(block_table, pos, qr, k_pool, v_pool)
    return out.reshape(B, H, D)

"""Pallas TPU flash attention: blocked online-softmax, causal GQA, fwd + bwd.

TPU-native design (DESIGN.md §7):

- Grid ``(B, K, nq)``: one program per (batch, kv-head, q-block).  The
  kv-loop is a ``lax.fori_loop`` *inside* the kernel so the online-softmax
  carry (m, l, acc) lives in VMEM registers/scratch for the whole row of
  blocks — no HBM round-trips for the softmax state (the core flash idea,
  re-blocked for the MXU instead of warps).
- BlockSpecs deliver one ``(block_q, G·D)`` q tile and the *whole* kv rows
  for that (batch, kv head) into VMEM; kv blocks are then sliced inside the
  kernel.  With D=128 and block_k=512 the kv tile is 512×128×2×2 B = 256 KiB
  — comfortably inside the ~16 MiB/core VMEM alongside the q tile and acc.
- GQA: queries arrive pre-grouped as (B, S, K, G·D); the kernel contracts
  (block_q·G, D) × (D, block_k) on the MXU — head-group packing keeps the
  matmul M-dim a multiple of 8×G even for small q blocks.
- Causality: programs where the whole q block precedes a kv block skip that
  kv block entirely (the fori_loop upper bound is computed from the block
  index — the "wedge"), matching the ~2× FLOP saving of the ref ``wedge``
  path.

Backward (training path — PR 6): the standard recompute-style flash
backward.  The forward additionally emits the per-row log-sum-exp; the
backward never sees a stored (Sq, Sk) score matrix — each of its two
kernels *recomputes* the score tile from (q, k, lse) in VMEM:

- ``_flash_bwd_dq_kernel``: grid (B, K, nq), same wedge as the forward.
  Per q block: loop kv blocks, p = exp(s − lse), dp = do·vᵀ,
  ds = p·(dp − δ), dq += τ·ds·k.
- ``_flash_bwd_dkv_kernel``: grid (B, K, nk).  Per kv block: loop the q
  blocks that attend it (causal ⇒ start at ⌊j·bk/bq⌋), accumulate
  dv += pᵀ·do and dk += τ·dsᵀ·q in VMEM and write each tile once.

δ (= rowsum(do∘o)) is a cheap elementwise reduction computed by the
wrapper; the custom VJP that saves/recomputes residuals lives in ops.py.

Validated in ``interpret=True`` mode on CPU against ``ref.attention_ref``
(values AND gradients — tests/kernel_harness.py); on-TPU the same code
lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q: int,
                      block_k: int, causal: bool, sk: int, group: int,
                      head_dim: int):
    """One (batch, kv-head, q-block) program.

    q_ref: (block_q, G·D) VMEM tile
    k_ref/v_ref: (Sk, D) VMEM rows for this (b, kv-head)
    o_ref: (block_q, G·D)   lse_ref: (block_q, G)
    """
    qi = pl.program_id(2)
    G, D = group, head_dim
    q = q_ref[...].reshape(block_q, G, D).astype(jnp.float32)
    q = q * (D ** -0.5)
    # flatten (q, g) → rows so the MXU sees a (block_q·G, D) LHS
    q2 = q.reshape(block_q * G, D)

    nk_total = sk // block_k
    if causal:
        # q rows in this block span [qi·bq, (qi+1)·bq); kv block j is live
        # iff j·bk <= last q row  →  wedge skipping of fully-masked blocks
        nk = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k,
                         nk_total)
    else:
        nk = nk_total

    m0 = jnp.full((block_q * G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q * G,), jnp.float32)
    a0 = jnp.zeros((block_q * G, D), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        kj = k_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        vj = v_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q2, kj, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, G), 0).reshape(block_q * G)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q * G, block_k), 1)
            s = jnp.where(qpos[:, None] >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.reshape(block_q, G * D).astype(o_ref.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    lse_ref[...] = lse.reshape(block_q, G)


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dq_ref,
                         *, block_q: int, block_k: int, causal: bool,
                         sk: int, group: int, head_dim: int):
    """dQ program (batch, kv-head, q-block): recompute score tiles, wedge.

    q_ref/do_ref: (block_q, G·D)  k_ref/v_ref: (Sk, D)
    lse_ref/d_ref: (block_q, G)   dq_ref: (block_q, G·D)
    """
    qi = pl.program_id(2)
    G, D = group, head_dim
    scale = D ** -0.5
    q2 = q_ref[...].reshape(block_q * G, D).astype(jnp.float32)
    do2 = do_ref[...].reshape(block_q * G, D).astype(jnp.float32)
    lse = lse_ref[...].reshape(block_q * G)
    delta = d_ref[...].reshape(block_q * G)

    nk_total = sk // block_k
    if causal:
        nk = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k,
                         nk_total)
    else:
        nk = nk_total

    def body(j, dq):
        kj = k_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        vj = v_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q2, kj, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, G), 0).reshape(block_q * G)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q * G, block_k), 1)
            s = jnp.where(qpos[:, None] >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                 # masked rows → exp(−∞)=0
        dp = jax.lax.dot_general(do2, vj, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return dq + jax.lax.dot_general(ds, kj, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    dq0 = jnp.zeros((block_q * G, D), jnp.float32)
    dq = jax.lax.fori_loop(0, nk, body, dq0) * scale
    dq_ref[...] = dq.reshape(block_q, G * D).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref,
                          dk_ref, dv_ref, *, block_q: int, block_k: int,
                          causal: bool, sq: int, group: int, head_dim: int):
    """dK/dV program (batch, kv-head, kv-block): loop live q blocks.

    q_ref/do_ref: (Sq, G·D)  k_ref/v_ref: (block_k, D)
    lse_ref/d_ref: (Sq, G)   dk_ref/dv_ref: (block_k, D)
    """
    ki = pl.program_id(2)
    G, D = group, head_dim
    scale = D ** -0.5
    kj = k_ref[...].astype(jnp.float32)
    vj = v_ref[...].astype(jnp.float32)
    nq_total = sq // block_q
    # causal: the first q block with any row attending this kv block is
    # ⌊ki·bk/bq⌋ (rows before it all precede the block's first kv position)
    i0 = (ki * block_k) // block_q if causal else 0

    def body(i, carry):
        dk, dv = carry
        qi = q_ref[pl.dslice(i * block_q, block_q), :] \
            .reshape(block_q * G, D).astype(jnp.float32)
        doi = do_ref[pl.dslice(i * block_q, block_q), :] \
            .reshape(block_q * G, D).astype(jnp.float32)
        lse = lse_ref[pl.dslice(i * block_q, block_q), :] \
            .reshape(block_q * G)
        delta = d_ref[pl.dslice(i * block_q, block_q), :] \
            .reshape(block_q * G)
        s = jax.lax.dot_general(qi, kj, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, G), 0).reshape(block_q * G)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q * G, block_k), 1)
            s = jnp.where(qpos[:, None] >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv = dv + jax.lax.dot_general(p, doi, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(doi, vj, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk = dk + jax.lax.dot_general(ds, qi, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        return dk, dv

    z = jnp.zeros((block_k, D), jnp.float32)
    dk, dv = jax.lax.fori_loop(i0, nq_total, body, (z, z))
    dk_ref[...] = (dk * scale).astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _check_blocks(Sq: int, Sk: int, block_q: int, block_k: int):
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(f"seq ({Sq},{Sk}) must divide blocks "
                         f"({block_q},{block_k})")
    return block_q, block_k


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    return_lse: bool = False):
    """q: (B, Sq, H, D)  k/v: (B, Sk, K, D) → (B, Sq, H, D).

    ``return_lse``: additionally return the per-row log-sum-exp
    (B, Sq, K, G) — the residual the fused backward needs.  Training code
    should go through :func:`repro.kernels.flash_attention.ops.flash`,
    whose custom VJP runs the fused backward kernels.
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    block_q, block_k = _check_blocks(Sq, Sk, block_q, block_k)
    nq = Sq // block_q

    # layout: (B, S, K, G·D) so one BlockSpec index_map serves q and o
    qr = q.reshape(B, Sq, K, G * D)

    kernel = functools.partial(
        _flash_fwd_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sk=Sk, group=G, head_dim=D)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, K, nq),
        in_specs=[
            pl.BlockSpec((None, block_q, None, G * D),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, Sk, None, D), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((None, Sk, None, D), lambda b, h, i: (b, 0, h, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, block_q, None, G * D),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, block_q, None, G),
                         lambda b, h, i: (b, i, h, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, Sq, K, G * D), q.dtype),
            jax.ShapeDtypeStruct((B, Sq, K, G), jnp.float32),
        ),
        interpret=interpret,
    )(qr, k, v)
    out = out.reshape(B, Sq, H, D)
    return (out, lse) if return_lse else out


def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        do: jax.Array, lse: jax.Array, delta: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """Fused flash backward: (dq, dk, dv) from saved (q, k, v, lse, δ).

    q/do: (B, Sq, H, D)  k/v: (B, Sk, K, D)  lse/delta: (B, Sq, K, G).
    Score tiles are recomputed in VMEM — no (Sq, Sk) tensor ever exists.
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    block_q, block_k = _check_blocks(Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    qr = q.reshape(B, Sq, K, G * D)
    dor = do.reshape(B, Sq, K, G * D)

    q_tile = pl.BlockSpec((None, block_q, None, G * D),
                          lambda b, h, i: (b, i, h, 0))
    row_tile = pl.BlockSpec((None, block_q, None, G),
                            lambda b, h, i: (b, i, h, 0))
    kv_rows = pl.BlockSpec((None, Sk, None, D), lambda b, h, i: (b, 0, h, 0))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, block_q=block_q,
                          block_k=block_k, causal=causal, sk=Sk, group=G,
                          head_dim=D),
        grid=(B, K, nq),
        in_specs=[q_tile, kv_rows, kv_rows, q_tile, row_tile, row_tile],
        out_specs=q_tile,
        out_shape=jax.ShapeDtypeStruct((B, Sq, K, G * D), q.dtype),
        interpret=interpret,
    )(qr, k, v, dor, lse, delta)

    q_rows = pl.BlockSpec((None, Sq, None, G * D),
                          lambda b, h, j: (b, 0, h, 0))
    rows_full = pl.BlockSpec((None, Sq, None, G), lambda b, h, j: (b, 0, h, 0))
    kv_tile = pl.BlockSpec((None, block_k, None, D),
                           lambda b, h, j: (b, j, h, 0))

    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, block_q=block_q,
                          block_k=block_k, causal=causal, sq=Sq, group=G,
                          head_dim=D),
        grid=(B, K, nk),
        in_specs=[q_rows, kv_tile, kv_tile, q_rows, rows_full, rows_full],
        out_specs=(kv_tile, kv_tile),
        out_shape=(jax.ShapeDtypeStruct((B, Sk, K, D), k.dtype),
                   jax.ShapeDtypeStruct((B, Sk, K, D), v.dtype)),
        interpret=interpret,
    )(qr, k, v, dor, lse, delta)
    return dq.reshape(B, Sq, H, D), dk, dv

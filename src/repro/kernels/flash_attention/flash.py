"""Pallas TPU flash attention (forward): blocked online-softmax, causal GQA.

TPU-native design (DESIGN.md §7):

- Grid ``(B, K, nq)``: one program per (batch, kv-head, q-block).  The
  kv-loop is a ``lax.fori_loop`` *inside* the kernel so the online-softmax
  carry (m, l, acc) lives in VMEM registers/scratch for the whole row of
  blocks — no HBM round-trips for the softmax state (the core flash idea,
  re-blocked for the MXU instead of warps).
- BlockSpecs deliver one ``(block_q, G·D)`` q tile and the *whole* kv rows
  for that (batch, kv head) into VMEM; kv blocks are then sliced inside the
  kernel.  With D=128 and block_k=512 the kv tile is 512×128×2×2 B = 256 KiB
  — comfortably inside the ~16 MiB/core VMEM alongside the q tile and acc.
- GQA: queries arrive pre-grouped as (B, S, K, G·D); the kernel contracts
  (block_q·G, D) × (D, block_k) on the MXU — head-group packing keeps the
  matmul M-dim a multiple of 8×G even for small q blocks.
- Causality: programs where the whole q block precedes a kv block skip that
  kv block entirely (the fori_loop upper bound is computed from the block
  index — the "wedge"), matching the ~2× FLOP saving of the ref ``wedge``
  path.

Validated in ``interpret=True`` mode on CPU against ``ref.attention_ref``
over shape/dtype sweeps (tests/test_kernels.py); on-TPU the same code lowers
to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int,
                      block_k: int, causal: bool, sk: int, group: int,
                      head_dim: int):
    """One (batch, kv-head, q-block) program.

    q_ref: (block_q, G·D) VMEM tile
    k_ref/v_ref: (Sk, D) VMEM rows for this (b, kv-head)
    o_ref: (block_q, G·D)
    """
    qi = pl.program_id(2)
    G, D = group, head_dim
    q = q_ref[...].reshape(block_q, G, D).astype(jnp.float32)
    q = q * (D ** -0.5)
    # flatten (q, g) → rows so the MXU sees a (block_q·G, D) LHS
    q2 = q.reshape(block_q * G, D)

    nk_total = sk // block_k
    if causal:
        # q rows in this block span [qi·bq, (qi+1)·bq); kv block j is live
        # iff j·bk <= last q row  →  wedge skipping of fully-masked blocks
        nk = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k,
                         nk_total)
    else:
        nk = nk_total

    m0 = jnp.full((block_q * G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q * G,), jnp.float32)
    a0 = jnp.zeros((block_q * G, D), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        kj = k_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        vj = v_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q2, kj, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, G), 0).reshape(block_q * G)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q * G, block_k), 1)
            s = jnp.where(qpos[:, None] >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.reshape(block_q, G * D).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D)  k/v: (B, Sk, K, D) → (B, Sq, H, D).

    Forward only (serving prefill / benchmark path; training uses the
    jnp blocked ref whose backward comes from autodiff).
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(f"seq ({Sq},{Sk}) must divide blocks "
                         f"({block_q},{block_k})")
    nq = Sq // block_q

    # layout: (B, S, K, G·D) so one BlockSpec index_map serves q and o
    qr = q.reshape(B, Sq, K, G * D)

    kernel = functools.partial(
        _flash_fwd_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sk=Sk, group=G, head_dim=D)

    out = pl.pallas_call(
        kernel,
        grid=(B, K, nq),
        in_specs=[
            pl.BlockSpec((None, block_q, None, G * D),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, Sk, None, D), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((None, Sk, None, D), lambda b, h, i: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, G * D),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, K, G * D), q.dtype),
        interpret=interpret,
    )(qr, k, v)
    return out.reshape(B, Sq, H, D)

"""Checkpointing: atomic, async, retention-managed, mesh-agnostic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        MANIFEST.json       # treedef paths, shapes, dtypes, extra metadata
        arr_00000.npy ...   # one file per pytree leaf (host-gathered)
    <dir>/step_000123.COMMITTED   # atomicity marker (written last)

- **Atomic**: the payload is written to ``step_N.tmp`` and renamed, then the
  ``COMMITTED`` marker is created; readers only consider committed steps, so
  a crash mid-write can never yield a half checkpoint.
- **Async**: ``save_async`` snapshots to host memory synchronously (cheap:
  device→host copy) and writes in a daemon thread, overlapping disk I/O with
  the next training steps; ``wait()`` joins before the next save or exit.
- **Mesh-agnostic / elastic**: leaves are saved as *full logical arrays*
  with their logical-axis names; ``restore`` re-shards onto any mesh via the
  target shardings (this is what ``runtime/elastic.py`` uses to restart at a
  different device count).  On a real multi-host fleet the save path would
  write per-shard files (Orbax-style); the host-gather here is the
  single-process equivalent and keeps the restore semantics identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> str:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, *,
                   extra: dict | None = None) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> str:
        name = f"step_{step:08d}"
        final = os.path.join(self.directory, name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_tree)
        manifest = {
            "step": step,
            "paths": _leaf_paths(host_tree),
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extra": extra,
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(final + ".COMMITTED", "w") as f:
            f.write(name)
        self._retain()
        return final

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            name = f"step_{s:08d}"
            shutil.rmtree(os.path.join(self.directory, name),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.directory, name + ".COMMITTED"))
            except FileNotFoundError:
                pass

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list:
        out = []
        for fn in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.COMMITTED", fn)
            if m and os.path.isdir(os.path.join(self.directory,
                                                f"step_{int(m.group(1)):08d}")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None
                ) -> tuple:
        """Restore into the structure of ``target`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        ``NamedSharding`` — leaves are placed (re-sharded) accordingly,
        which is all elastic re-meshing needs."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(target)
        if len(leaves) != len(manifest["paths"]):
            raise ValueError(
                f"checkpoint has {len(manifest['paths'])} leaves, "
                f"target wants {len(leaves)}")
        # leaf *identity* must match too: an elastic re-plan that changed
        # the tree structure (different optimizer, pipelined layout) would
        # otherwise silently restore arrays into the wrong leaves whenever
        # shapes happen to coincide
        tgt_paths = _leaf_paths(target)
        mismatch = [(a, b) for a, b in zip(manifest["paths"], tgt_paths)
                    if a != b]
        if mismatch:
            a, b = mismatch[0]
            raise ValueError(
                f"checkpoint tree does not match restore target "
                f"({len(mismatch)} leaves differ; first: ckpt {a!r} vs "
                f"target {b!r}) — the new plan's parameter layout is "
                f"incompatible with this checkpoint")
        sh_leaves = (treedef.flatten_up_to(shardings)
                     if shardings is not None else [None] * len(leaves))
        out = []
        for i, (tgt, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"leaf {manifest['paths'][i]}: ckpt shape {arr.shape} "
                    f"!= target {tgt.shape}")
            arr = arr.astype(tgt.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else
                       jax.device_put(arr))
        return treedef.unflatten(out), manifest["extra"]

    def restore_latest(self, target: Any, *, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target, shardings=shardings)
        return step, tree, extra

"""GPipe pipeline parallelism over a ``stage`` mesh axis (paper Cases 3–4).

TPU adaptation (DESIGN.md §5): Whale pipelines TF graph partitions with
host-side queues; on TPU the native mechanism is a collective pipeline —
stage parameters are sharded over a ``stage`` mesh axis inside a
``shard_map`` (manual over ``stage``, GSPMD-auto over ``data``/``model`` so
pipeline composes with DP and operator sharding, the paper's Case 4), and
micro-batch activations move stage-to-stage with ``jax.lax.ppermute``.

Schedule: classic GPipe.  With S stages and M micro-batches the forward runs
T = M + S − 1 ticks; tick t has stage s working on micro-batch t − s (masked
when out of range — that masking *is* the pipeline bubble).  ``jax.grad``
differentiates straight through the schedule (the transpose of ``ppermute``
is the reverse ``ppermute``), yielding the symmetric backward schedule;
stage-replicated embed/head parameters get their cross-stage gradient
``psum`` from the shard_map transpose automatically.

The layer stack must divide evenly: ``n_rep % S == 0``; each stage owns
``n_rep / S`` consecutive pattern repeats (Whale's "evenly partition the
model into stages", §3.1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sharding import ShardingRules, use_rules
from repro.models import layers, transformer as tfm
from repro.models.lm import Model, chunked_xent


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in t)


def staged_specs(rules: ShardingRules, axes_tree, shapes_tree):
    """Specs from the rules, with the leading ``layers`` dim of stacked
    params additionally sharded over the ``stage`` axis."""
    def one(names, sds):
        spec = rules.spec_for(names, sds.shape)
        if names and names[0] == "layers":
            return P(*(("stage",) + tuple(spec)[1:]))
        return spec

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes)


def stage_only_specs(axes_tree):
    """shard_map in_specs: partial-manual mode may only name manual axes, so
    these specs carry *just* the stage dim; data/model sharding stays GSPMD-
    auto (applied at the jit level via :func:`staged_specs`)."""
    def one(names):
        if names and names[0] == "layers":
            return P("stage")
        return P()

    return jax.tree.map(one, axes_tree, is_leaf=_is_axes)


def make_gpipe_loss(model: Model, mesh: Mesh, rules: ShardingRules, *,
                    micro_batches: int):
    """→ (loss_fn(params, tokens), param PartitionSpecs).

    ``params["blocks"]`` leaves are stage-sharded on their leading (layers)
    dim; embed/head/norms are stage-replicated.  Differentiable; composes
    with DP/TP because data/model axes stay GSPMD-auto inside the shard_map.
    """
    cfg = model.cfg
    stack = model.stack
    if stack is None:
        raise ValueError("pipeline supports decoder-LM families only")
    S = mesh.shape["stage"]
    M = micro_batches
    if stack.n_rep % S:
        raise ValueError(f"n_rep={stack.n_rep} not divisible by {S} stages")
    local_stack = dataclasses.replace(stack, n_rep=stack.n_rep // S)
    norm = layers.make_norm(cfg.norm)[2]
    perm = [(i, i + 1) for i in range(S - 1)]

    def inner(params, tokens):
        sid = jax.lax.axis_index("stage")
        B, T = tokens.shape
        mb = B // M
        toks_mb = tokens.reshape(M, mb, T)
        positions = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))
        head_w = model._head_w(params).astype(cfg.adtype)

        def tick(carry, t):
            recv, loss_acc, n_acc, aux_acc = carry
            # ---- stage 0 ingests micro-batch t; others take the wire ----
            tok_in = jax.lax.dynamic_index_in_dim(
                toks_mb, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            x0 = layers.embed(params["embed"], tok_in).astype(cfg.adtype)
            x_in = jnp.where(sid == 0, x0, recv)
            # ---- my slice of the stack ----
            y, aux = tfm.apply_stack(params["blocks"], x_in, positions,
                                     local_stack)
            mb_here = t - sid                      # micro-batch at this stage
            w_here = ((mb_here >= 0) & (mb_here < M)).astype(jnp.float32)
            aux_acc = jax.tree.map(lambda a, d: a + w_here * d, aux_acc, aux)
            # ---- last stage computes the loss for micro-batch t-(S-1) ----
            out_mb = t - (S - 1)
            lab_tok = jax.lax.dynamic_index_in_dim(
                toks_mb, jnp.clip(out_mb, 0, M - 1), axis=0, keepdims=False)
            xf = norm(params["final_norm"], y)
            mask = jnp.ones((mb, T - 1), jnp.float32)
            nll, zl, n = chunked_xent(
                xf[:, :-1], head_w, lab_tok[:, 1:], mask, vocab=cfg.vocab,
                chunk=cfg.loss_chunk, z_loss_coef=cfg.z_loss_coef)
            w_out = (((out_mb >= 0) & (out_mb < M)) & (sid == S - 1)
                     ).astype(jnp.float32)
            loss_acc = loss_acc + w_out * (nll + zl)
            n_acc = n_acc + w_out * n
            # ---- ship activations down the pipe ----
            recv_next = jax.lax.ppermute(y, "stage", perm)
            return (recv_next, loss_acc, n_acc, aux_acc), None

        recv0 = jnp.zeros((mb, T, cfg.d_model), cfg.adtype)
        zero = jnp.zeros((), jnp.float32)
        aux0 = {"lb_loss": zero, "z_loss": zero}
        (_, loss_sum, n_sum, aux), _ = jax.lax.scan(
            tick, (recv0, zero, zero, aux0), jnp.arange(M + S - 1))
        # per-stage partial totals → global
        loss_sum = jax.lax.psum(loss_sum, "stage")
        n_sum = jax.lax.psum(n_sum, "stage")
        aux = jax.tree.map(lambda a: jax.lax.psum(a, "stage") / M, aux)
        return (loss_sum / jnp.maximum(n_sum, 1.0)
                + aux["lb_loss"] + aux["z_loss"])

    pspecs = staged_specs(rules, model.axes(), model.param_shapes())
    sm_specs = stage_only_specs(model.axes())

    def loss_fn(params, tokens):
        from repro.core.jax_compat import shard_map
        with use_rules(rules):
            return shard_map(
                inner, mesh=mesh, in_specs=(sm_specs, P()), out_specs=P(),
                axis_names=frozenset({"stage"}), check_vma=False,
            )(params, tokens)

    return loss_fn, pspecs


def make_gpipe_train_step(model: Model, mesh: Mesh, rules: ShardingRules,
                          optimizer, *, micro_batches: int, donate=True):
    """Jitted (params, opt_state, tokens, step) → (params, opt_state, loss)."""
    loss_fn, pspecs = make_gpipe_loss(model, mesh, rules,
                                      micro_batches=micro_batches)

    def step_fn(params, opt_state, tokens, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        params, opt_state = optimizer.apply(grads, opt_state, params, step)
        return params, opt_state, loss

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda t: isinstance(t, P))
    psh = ns(pspecs)
    ospecs = staged_specs(rules, optimizer.state_axes(model.axes()),
                          jax.eval_shape(optimizer.init, model.param_shapes()))
    data_ax = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tok_sh = NamedSharding(mesh, P(data_ax if len(data_ax) > 1 else
                                   (data_ax[0] if data_ax else None)))
    rep = NamedSharding(mesh, P())
    return jax.jit(step_fn,
                   in_shardings=(psh, ns(ospecs), tok_sh, rep),
                   out_shardings=(psh, ns(ospecs), rep),
                   donate_argnums=(0, 1) if donate else ())

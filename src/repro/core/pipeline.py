"""Pipeline parallelism over a ``stage`` mesh axis (paper Cases 3–4).

TPU adaptation (DESIGN.md §5): Whale pipelines TF graph partitions with
host-side queues; on TPU the native mechanism is a collective pipeline —
stage parameters are sharded over a ``stage`` mesh axis inside a
``shard_map`` (manual over ``stage``, GSPMD-auto over ``data``/``model`` so
pipeline composes with DP and operator sharding, the paper's Case 4), and
micro-batch activations move stage-to-stage with ``jax.lax.ppermute``.

Two executors, one schedule subsystem (:mod:`repro.core.schedule`):

1. **Fused SPMD engine** (:func:`make_pipeline_loss` /
   :func:`make_pipeline_train_step`) — the forward walks GPipe's forward
   wave as a ``lax.scan`` over ticks; ``jax.grad`` differentiates straight
   through it (the transpose of ``ppermute`` is the reverse ``ppermute``),
   yielding the mirrored backward — i.e. exactly the ``gpipe`` tick table.
   Stages may hold **uneven** layer counts: params live in a padded
   ``(S·Lmax, …)`` stage-sharded layout and each stage applies only its
   first ``stage_layers[s]`` repeats (gated scan; pad slots contribute
   nothing and receive zero gradients).  This is what executes the
   heterogeneity planner's latency-equalizing ``HeteroPlacement``
   (DESIGN.md §2) end to end.

2. **Schedule interpreter** (:func:`schedule_grads`) — the order-faithful
   reference engine: walks any :class:`~repro.core.schedule.Schedule`
   tick table on one device, running each fwd slot and each bwd slot (via
   ``jax.vjp`` with stage-input recompute, i.e. remat at stage
   granularity) in exactly the scheduled order, with an audited
   activation buffer whose high-water mark must match
   ``Schedule.peak_in_flight`` — the harness the schedule-equivalence
   tests drive.

Encoder–decoder models pipeline over their natural two-tower cut instead
of a layer-count split: :func:`make_encdec_pipeline_loss` runs the
(frontend +) encoder tower on stage 0 and the decoder tower + loss head
on stage 1, shipping the ``(micro_batch, S_src, d_model)`` encoder
memory across the wire each tick.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import schedule as sched_mod
from repro.core.sharding import ShardingRules, use_rules
from repro.models import encdec as encdec_mod
from repro.models import frontends
from repro.models import layers, transformer as tfm
from repro.models.lm import Model, chunked_xent


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in t)


def staged_specs(rules: ShardingRules, axes_tree, shapes_tree):
    """Specs from the rules, with the leading ``layers`` dim of stacked
    params additionally sharded over the ``stage`` axis."""
    def one(names, sds):
        spec = rules.spec_for(names, sds.shape)
        if names and names[0] == "layers":
            return P(*(("stage",) + tuple(spec)[1:]))
        return spec

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes)


def stage_only_specs(axes_tree):
    """shard_map in_specs: partial-manual mode may only name manual axes, so
    these specs carry *just* the stage dim; data/model sharding stays GSPMD-
    auto (applied at the jit level via :func:`staged_specs`)."""
    def one(names):
        if names and names[0] == "layers":
            return P("stage")
        return P()

    return jax.tree.map(one, axes_tree, is_leaf=_is_axes)


# ---------------------------------------------------------------------------
# uneven stages: layer allocation + padded stage-sharded layout
# ---------------------------------------------------------------------------


def even_stage_layers(n_rep: int, n_stages: int) -> tuple:
    """The classic even split; raises unless ``n_stages`` divides."""
    if n_rep % n_stages:
        raise ValueError(
            f"n_rep={n_rep} not divisible by {n_stages} stages; pass an "
            f"explicit stage_layers vector (e.g. from the hetero planner's "
            f"HeteroPlacement.layer_alloc) for uneven pipelines")
    return (n_rep // n_stages,) * n_stages


def check_stage_layers(stage_layers, n_rep: int, n_stages: int) -> tuple:
    sl = tuple(int(x) for x in stage_layers)
    if len(sl) != n_stages:
        raise ValueError(f"stage_layers {sl} has {len(sl)} entries for "
                         f"{n_stages} stages")
    if any(x < 1 for x in sl):
        raise ValueError(f"every stage needs >= 1 layer repeat, got {sl}")
    if sum(sl) != n_rep:
        raise ValueError(f"stage_layers {sl} sums to {sum(sl)}, "
                         f"expected n_rep={n_rep}")
    return sl


def stage_layers_from_alloc(stack: tfm.StackCfg, layer_alloc) -> tuple:
    """HeteroPlacement.layer_alloc (model *layers* per stage, the planner's
    unit) → per-stage pattern-*repeat* counts (the executor's unit).

    A stage's layer share must be a whole number of pattern repeats (a
    repeat is the scan/remat unit and cannot straddle a stage boundary);
    the planner's even/proportional splits satisfy this for single-block
    patterns (dense/moe-every-1/ssm) where repeats == layers."""
    plen = len(stack.pattern)
    bad = [a for a in layer_alloc if a % plen]
    if bad:
        raise ValueError(
            f"stage layer allocation {tuple(layer_alloc)} is not a multiple "
            f"of the {plen}-block scan pattern; re-plan with pp dividing "
            f"n_rep or a pattern-aligned allocation")
    out = tuple(a // plen for a in layer_alloc)
    if sum(out) != stack.n_rep:
        raise ValueError(f"layer_alloc {tuple(layer_alloc)} covers "
                         f"{sum(out)} repeats, model has {stack.n_rep}")
    return out


def pad_stage_stack(blocks, stage_layers):
    """(n_rep, …) stacked block params → padded ``(S·Lmax, …)`` layout.

    Stage ``s`` owns rows ``[s·Lmax, s·Lmax + stage_layers[s])``; pad rows
    are zero (the gated scan never reads their output, so they also
    receive exactly-zero gradients).  An even split is the identity."""
    sl = tuple(stage_layers)
    lmax = max(sl)
    if sl == (lmax,) * len(sl):
        return blocks                      # even: padded layout == stacked

    def one(p):
        out = jnp.zeros((len(sl) * lmax,) + p.shape[1:], p.dtype)
        off = 0
        for s, n in enumerate(sl):
            out = jax.lax.dynamic_update_slice_in_dim(
                out, p[off:off + n], s * lmax, axis=0)
            off += n
        return out

    return jax.tree.map(one, blocks)


def unpad_stage_stack(blocks, stage_layers):
    """Inverse of :func:`pad_stage_stack` (drops the pad rows) — for
    exporting a pipeline-trained checkpoint back to the canonical
    ``(n_rep, …)`` layout."""
    sl = tuple(stage_layers)
    lmax = max(sl)
    if sl == (lmax,) * len(sl):
        return blocks

    def one(p):
        return jnp.concatenate(
            [p[s * lmax:s * lmax + n] for s, n in enumerate(sl)], axis=0)

    return jax.tree.map(one, blocks)


def pipeline_params(model: Model, params: dict, stage_layers) -> dict:
    """Re-lay a standard param tree for the uneven pipeline executor."""
    out = dict(params)
    out["blocks"] = pad_stage_stack(params["blocks"], stage_layers)
    return out


def _padded_model_shapes(model: Model, stage_layers):
    shapes = model.param_shapes()
    return dict(shapes, blocks=jax.eval_shape(
        lambda b: pad_stage_stack(b, stage_layers), shapes["blocks"]))


def _apply_stack_gated(params, x, positions, stack: tfm.StackCfg, n_active):
    """:func:`repro.models.transformer.apply_stack` with the first
    ``n_active`` of ``stack.n_rep`` repeats live — repeat ``k >=
    n_active`` passes ``x`` through untouched and contributes no aux (and,
    via the ``where`` transpose, no gradient)."""

    def rep_body(x, inp):
        rep_params, k = inp
        aux = {"lb_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
        y = x
        for i, bcfg in enumerate(stack.pattern):
            y, a, _ = tfm.apply_block(rep_params[f"p{i}"], y, positions,
                                      bcfg, stack)
            aux = jax.tree.map(jnp.add, aux, a)
        keep = k < n_active
        x = jnp.where(keep, y, x)
        aux = jax.tree.map(lambda a: jnp.where(keep, a, 0.0), aux)
        return x, aux

    body = tfm._remat_wrap(rep_body, stack.remat)
    ks = jnp.arange(stack.n_rep)
    if stack.scan and stack.n_rep > 1:
        x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, (params, ks))
        aux = jax.tree.map(lambda a: a.sum(0), auxs)
    else:
        aux = {"lb_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
        for r in range(stack.n_rep):
            rep_params = jax.tree.map(lambda p: p[r], params)
            x, a = body(x, (rep_params, ks[r]))
            aux = jax.tree.map(jnp.add, aux, a)
    return x, aux


def check_micro_divides(batch: int, micro_batches: int) -> int:
    """The ``B % M != 0`` guard: a truncated ``reshape(M, B // M, …)``
    would silently drop the trailing ``B % M`` sequences from the loss."""
    if micro_batches < 1:
        raise ValueError(f"micro_batches must be >= 1, got {micro_batches}")
    if batch % micro_batches:
        raise ValueError(
            f"global batch {batch} is not divisible by micro_batches="
            f"{micro_batches}; the truncated reshape would silently drop "
            f"{batch % micro_batches} sequence(s) from the loss — pick M "
            f"dividing B (or pad the batch)")
    return batch // micro_batches


# ---------------------------------------------------------------------------
# fused SPMD engine (shard_map + ppermute; autodiff = mirrored gpipe order)
# ---------------------------------------------------------------------------


def make_pipeline_loss(model: Model, mesh: Mesh, rules: ShardingRules, *,
                       micro_batches: int, stage_layers=None,
                       schedule: str = "gpipe"):
    """→ (loss_fn(params, tokens), param PartitionSpecs).

    ``params["blocks"]`` leaves
    live in the (possibly padded) stage-sharded layout of
    :func:`pipeline_params`; embed/head/norms are stage-replicated.
    ``stage_layers`` (default even) sets each stage's repeat count —
    uneven vectors come from the hetero planner's
    ``HeteroPlacement.layer_alloc``.  ``schedule`` is carried for
    planning (bubble/memory pricing, ``scan`` length is schedule-
    independent); on the fused engine autodiff always materializes the
    gpipe order — :func:`schedule_grads` is the order-faithful engine.

    Differentiable; composes with DP/TP because data/model axes stay
    GSPMD-auto inside the shard_map.
    """
    cfg = model.cfg
    stack = model.stack
    if stack is None:
        raise ValueError(
            "make_pipeline_loss pipelines decoder-LM stacks; encoder–"
            "decoder models pipeline over the two-tower cut instead — "
            "use make_encdec_pipeline_loss / make_encdec_pipeline_train_step")
    sched_mod.make_schedule(schedule, 2, 2)   # validate the name eagerly
    if schedule != "gpipe" and micro_batches > mesh.shape["stage"]:
        import warnings
        warnings.warn(
            f"schedule={schedule!r}: the fused SPMD engine materializes the "
            f"gpipe order under autodiff, so its real peak activation "
            f"memory is M={micro_batches} in-flight micro-batches, not the "
            f"schedule's min(M, S) — judge HBM feasibility at gpipe "
            f"pricing on this engine (schedule_grads is the order-faithful "
            f"executor)", stacklevel=2)
    S = mesh.shape["stage"]
    M = micro_batches
    if stage_layers is None:
        stage_layers = even_stage_layers(stack.n_rep, S)
    stage_layers = check_stage_layers(stage_layers, stack.n_rep, S)
    lmax = max(stage_layers)
    local_stack = dataclasses.replace(stack, n_rep=lmax)
    sl_arr = jnp.asarray(stage_layers, jnp.int32)
    norm = layers.make_norm(cfg.norm)[2]
    perm = [(i, i + 1) for i in range(S - 1)]

    def inner(params, tokens):
        sid = jax.lax.axis_index("stage")
        B, T = tokens.shape
        mb = check_micro_divides(B, M)
        toks_mb = tokens.reshape(M, mb, T)
        positions = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))
        head_w = model._head_w(params).astype(cfg.adtype)
        n_active = sl_arr[sid]

        def tick(carry, t):
            recv, loss_acc, n_acc, aux_acc = carry
            # ---- stage 0 ingests micro-batch t; others take the wire ----
            tok_in = jax.lax.dynamic_index_in_dim(
                toks_mb, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            x0 = layers.embed(params["embed"], tok_in).astype(cfg.adtype)
            x_in = jnp.where(sid == 0, x0, recv)
            # ---- my (gated, possibly padded) slice of the stack ----
            y, aux = _apply_stack_gated(params["blocks"], x_in, positions,
                                        local_stack, n_active)
            mb_here = t - sid                      # micro-batch at this stage
            w_here = ((mb_here >= 0) & (mb_here < M)).astype(jnp.float32)
            aux_acc = jax.tree.map(lambda a, d: a + w_here * d, aux_acc, aux)
            # ---- last stage computes the loss for micro-batch t-(S-1) ----
            out_mb = t - (S - 1)
            lab_tok = jax.lax.dynamic_index_in_dim(
                toks_mb, jnp.clip(out_mb, 0, M - 1), axis=0, keepdims=False)
            xf = norm(params["final_norm"], y)
            mask = jnp.ones((mb, T - 1), jnp.float32)
            nll, zl, n = chunked_xent(
                xf[:, :-1], head_w, lab_tok[:, 1:], mask, vocab=cfg.vocab,
                chunk=cfg.loss_chunk, z_loss_coef=cfg.z_loss_coef)
            w_out = (((out_mb >= 0) & (out_mb < M)) & (sid == S - 1)
                     ).astype(jnp.float32)
            loss_acc = loss_acc + w_out * (nll + zl)
            n_acc = n_acc + w_out * n
            # ---- ship activations down the pipe ----
            recv_next = jax.lax.ppermute(y, "stage", perm)
            return (recv_next, loss_acc, n_acc, aux_acc), None

        recv0 = jnp.zeros((mb, T, cfg.d_model), cfg.adtype)
        zero = jnp.zeros((), jnp.float32)
        aux0 = {"lb_loss": zero, "z_loss": zero}
        (_, loss_sum, n_sum, aux), _ = jax.lax.scan(
            tick, (recv0, zero, zero, aux0), jnp.arange(M + S - 1))
        # per-stage partial totals → global
        loss_sum = jax.lax.psum(loss_sum, "stage")
        n_sum = jax.lax.psum(n_sum, "stage")
        aux = jax.tree.map(lambda a: jax.lax.psum(a, "stage") / M, aux)
        return (loss_sum / jnp.maximum(n_sum, 1.0)
                + aux["lb_loss"] + aux["z_loss"])

    pspecs = staged_specs(rules, model.axes(),
                          _padded_model_shapes(model, stage_layers))
    sm_specs = stage_only_specs(model.axes())

    def loss_fn(params, tokens):
        from repro.core.jax_compat import shard_map
        with use_rules(rules):
            return shard_map(
                inner, mesh=mesh, in_specs=(sm_specs, P()), out_specs=P(),
                axis_names=frozenset({"stage"}), check_vma=False,
            )(params, tokens)

    return loss_fn, pspecs


def make_pipeline_train_step(model: Model, mesh: Mesh, rules: ShardingRules,
                             optimizer, *, micro_batches: int,
                             stage_layers=None, schedule: str = "gpipe",
                             donate=True):
    """Jitted (params, opt_state, tokens, step) → (params, opt_state, loss).

    Accepts uneven
    ``stage_layers`` (params/optimizer state in the padded layout of
    :func:`pipeline_params`) and a schedule choice from the plan.
    """
    if stage_layers is None:
        stage_layers = even_stage_layers(model.stack.n_rep,
                                         mesh.shape["stage"])
    loss_fn, pspecs = make_pipeline_loss(
        model, mesh, rules, micro_batches=micro_batches,
        stage_layers=stage_layers, schedule=schedule)

    def step_fn(params, opt_state, tokens, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        params, opt_state = optimizer.apply(grads, opt_state, params, step)
        return params, opt_state, loss

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda t: isinstance(t, P))
    psh = ns(pspecs)
    pshapes = _padded_model_shapes(model, stage_layers)
    ospecs = staged_specs(rules, optimizer.state_axes(model.axes()),
                          jax.eval_shape(optimizer.init, pshapes))
    data_ax = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tok_sh = NamedSharding(mesh, P(data_ax if len(data_ax) > 1 else
                                   (data_ax[0] if data_ax else None)))
    rep = NamedSharding(mesh, P())
    return jax.jit(step_fn,
                   in_shardings=(psh, ns(ospecs), tok_sh, rep),
                   out_shardings=(psh, ns(ospecs), rep),
                   donate_argnums=(0, 1) if donate else ())


# ---------------------------------------------------------------------------
# encoder–decoder two-tower pipeline (the M6/seamless multimodal cut)
# ---------------------------------------------------------------------------


def make_encdec_pipeline_loss(model: Model, mesh: Mesh, rules: ShardingRules,
                              *, micro_batches: int):
    """→ (loss_fn(params, frames, tokens), param PartitionSpecs).

    Encoder–decoder models have no interchangeable layer stack to split
    evenly — their natural pipeline cut is the segment edge between the
    towers (exactly the boundary the segment-aware planner refuses to
    move).  Stage 0 runs the (optional frontend adapter +) encoder on each
    micro-batch's frames and ships the ``(mb, S_src, d_model)`` memory
    down the wire; stage 1 embeds the target tokens, runs the decoder
    (self-attention + cross-attention over the received memory), and takes
    the loss.  M micro-batches drain in M + 1 ticks.

    Params are stage-*replicated* (each tower's weights are only touched
    on its own ``lax.cond`` branch; the shard_map transpose psums the
    per-stage cotangents, so gradients are exact).  Loss aggregation
    matches ``Model._loss_encdec``: ``Σ(nll+zl) / Σ n`` over micro-batches
    equals the full-batch value up to float reassociation.
    """
    cfg = model.cfg
    if cfg.family != "encdec" or model.ecfg is None:
        raise ValueError(
            f"make_encdec_pipeline_loss is the encoder–decoder engine; "
            f"family={cfg.family!r} pipelines via make_pipeline_loss")
    ecfg = model.ecfg
    S = mesh.shape["stage"]
    if S != 2:
        raise ValueError(
            f"the encdec pipeline is a strict 2-stage engine (encoder tower "
            f"| decoder tower), got a stage axis of size {S}")
    M = micro_batches
    norm = layers.make_norm(cfg.norm)[2]
    perm = [(0, 1)]

    def inner(params, frames, tokens):
        sid = jax.lax.axis_index("stage")
        B, S_src, _ = frames.shape
        T = tokens.shape[1]
        mb = check_micro_divides(B, M)
        frames_mb = frames.reshape(M, mb, S_src, cfg.d_model)
        toks_mb = tokens.reshape(M, mb, T)
        head_w = model._head_w(params).astype(cfg.adtype)

        def tick(carry, t):
            recv, loss_acc, n_acc = carry
            fr = jax.lax.dynamic_index_in_dim(
                frames_mb, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            out_mb = t - 1
            tok = jax.lax.dynamic_index_in_dim(
                toks_mb, jnp.clip(out_mb, 0, M - 1), axis=0, keepdims=False)

            def enc_stage(op):
                fr, _recv, _tok = op
                x = fr.astype(cfg.adtype)
                if cfg.frontend is not None:
                    x = frontends.adapt(params["adapter"], x)
                mem = encdec_mod.encode(params["encdec"], x, ecfg)
                zero = jnp.zeros((), jnp.float32)
                return mem.astype(cfg.adtype), zero, zero

            def dec_stage(op):
                _fr, recv, tok = op
                dec_in = layers.embed(params["embed"],
                                      tok[:, :-1]).astype(cfg.adtype)
                x = encdec_mod.decode_train(params["encdec"], dec_in, recv,
                                            ecfg)
                xf = norm(params["final_norm"], x)
                mask = jnp.ones((mb, T - 1), jnp.float32)
                nll, zl, n = chunked_xent(
                    xf, head_w, tok[:, 1:], mask, vocab=cfg.vocab,
                    chunk=cfg.loss_chunk, z_loss_coef=cfg.z_loss_coef)
                return recv, nll + zl, n

            y, l_mb, n_mb = jax.lax.cond(sid == 0, enc_stage, dec_stage,
                                         (fr, recv, tok))
            w_out = (((out_mb >= 0) & (out_mb < M)) & (sid == S - 1)
                     ).astype(jnp.float32)
            loss_acc = loss_acc + w_out * l_mb
            n_acc = n_acc + w_out * n_mb
            recv_next = jax.lax.ppermute(y, "stage", perm)
            return (recv_next, loss_acc, n_acc), None

        recv0 = jnp.zeros((mb, S_src, cfg.d_model), cfg.adtype)
        zero = jnp.zeros((), jnp.float32)
        (_, loss_sum, n_sum), _ = jax.lax.scan(
            tick, (recv0, zero, zero), jnp.arange(M + 1))
        loss_sum = jax.lax.psum(loss_sum, "stage")
        n_sum = jax.lax.psum(n_sum, "stage")
        return loss_sum / jnp.maximum(n_sum, 1.0)

    pspecs = rules.param_specs_tree(model.axes(), model.param_shapes(),
                                    fsdp=False)
    sm_specs = jax.tree.map(lambda names: P(), model.axes(), is_leaf=_is_axes)

    def loss_fn(params, frames, tokens):
        from repro.core.jax_compat import shard_map
        with use_rules(rules):
            return shard_map(
                inner, mesh=mesh, in_specs=(sm_specs, P(), P()),
                out_specs=P(), axis_names=frozenset({"stage"}),
                check_vma=False,
            )(params, frames, tokens)

    return loss_fn, pspecs


def make_encdec_pipeline_train_step(model: Model, mesh: Mesh,
                                    rules: ShardingRules, optimizer, *,
                                    micro_batches: int, donate=True):
    """Jitted (params, opt_state, frames, tokens, step) → (params,
    opt_state, loss) through the two-tower encdec pipeline."""
    loss_fn, pspecs = make_encdec_pipeline_loss(
        model, mesh, rules, micro_batches=micro_batches)

    def step_fn(params, opt_state, frames, tokens, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, frames, tokens)
        params, opt_state = optimizer.apply(grads, opt_state, params, step)
        return params, opt_state, loss

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda t: isinstance(t, P))
    psh = ns(pspecs)
    ospecs = rules.param_specs_tree(
        optimizer.state_axes(model.axes()),
        jax.eval_shape(optimizer.init, model.param_shapes()), fsdp=False)
    data_ax = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dspec = P(data_ax if len(data_ax) > 1 else
              (data_ax[0] if data_ax else None))
    batch_sh = NamedSharding(mesh, dspec)
    rep = NamedSharding(mesh, P())
    return jax.jit(step_fn,
                   in_shardings=(psh, ns(ospecs), batch_sh, batch_sh, rep),
                   out_shardings=(psh, ns(ospecs), rep),
                   donate_argnums=(0, 1) if donate else ())


# ---------------------------------------------------------------------------
# schedule interpreter (order-faithful reference engine, single device)
# ---------------------------------------------------------------------------


def _stage_slices(blocks, stage_layers):
    """Standard (n_rep, …) stacked params → per-stage python-sliced trees."""
    out, off = [], 0
    for n in stage_layers:
        out.append(jax.tree.map(lambda p, a=off, b=off + n: p[a:b], blocks))
        off += n
    return out


def schedule_grads(model: Model, params: dict, tokens, *,
                   micro_batches: int, schedule="1f1b", stage_layers=None,
                   n_stages: int | None = None):
    """Execute one train step's fwd+bwd work in *exactly* the order of a
    :class:`~repro.core.schedule.Schedule` tick table.

    The reference engine behind the schedule-equivalence tests: stages are
    python-level slices of the standard ``(n_rep, …)`` param tree (uneven
    ``stage_layers`` welcome, no padding needed at this level); each fwd
    slot runs the stage and saves only the stage *input* activation; each
    bwd slot recomputes the stage under ``jax.vjp`` (stage-granular remat)
    and routes the cotangent up the pipe.  Because the math per
    (stage, micro-batch) is fixed, every valid schedule yields the same
    loss and gradients — only the activation-buffer profile differs, and
    it is audited: the returned ``stats["peak_in_flight"]`` /
    ``stats["per_stage_in_flight"]`` are measured from the live buffer
    and must equal the schedule's own accounting.

    Returns ``(loss, grads, stats)`` with ``grads`` in the standard param
    layout.  Wrap in ``jax.jit`` for speed; the table is unrolled.
    """
    cfg = model.cfg
    stack = model.stack
    if stack is None:
        raise ValueError(
            "schedule_grads interprets decoder-LM stacks; encoder–decoder "
            "models use the two-tower make_encdec_pipeline_* engine")
    M = micro_batches
    if isinstance(schedule, sched_mod.Schedule):
        sc = schedule
        if sc.n_micro != M:
            raise ValueError(f"schedule has n_micro={sc.n_micro}, "
                             f"micro_batches={M}")
    else:
        if n_stages is None:
            n_stages = len(stage_layers) if stage_layers is not None else 1
        sc = sched_mod.make_schedule(schedule, n_stages, M)
    S = sc.n_stages
    if stage_layers is None:
        stage_layers = even_stage_layers(stack.n_rep, S)
    stage_layers = check_stage_layers(stage_layers, stack.n_rep, S)

    B, T = tokens.shape
    mb_size = check_micro_divides(B, M)
    toks_mb = tokens.reshape(M, mb_size, T)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (mb_size, T))
    n_total = float(M * mb_size * (T - 1))     # all-ones loss mask
    norm = layers.make_norm(cfg.norm)[2]
    tied = cfg.tie_embeddings
    shared_keys = ["embed", "final_norm"] + ([] if tied else ["head"])
    shared = {k: params[k] for k in shared_keys}
    stage_blocks = _stage_slices(params["blocks"], stage_layers)
    stage_stacks = [dataclasses.replace(stack, n_rep=n)
                    for n in stage_layers]

    def stage_call(s, blocks_s, sh, x, tok):
        """One stage's work on one micro-batch → (y, scalar loss contrib)."""
        if s == 0:
            x = layers.embed(sh["embed"], tok).astype(cfg.adtype)
        y, aux = tfm.apply_stack(blocks_s, x, positions, stage_stacks[s])
        contrib = (aux["lb_loss"] + aux["z_loss"]) / M
        if s == S - 1:
            xf = norm(sh["final_norm"], y)
            head_w = (sh["embed"]["table"].T if tied
                      else sh["head"]["w"]).astype(cfg.adtype)
            mask = jnp.ones((mb_size, T - 1), jnp.float32)
            nll, zl, _ = chunked_xent(
                xf[:, :-1], head_w, tok[:, 1:], mask, vocab=cfg.vocab,
                chunk=cfg.loss_chunk, z_loss_coef=cfg.z_loss_coef)
            contrib = contrib + (nll + zl) / n_total
        return y, contrib

    # one jitted fwd and one jitted bwd per stage — micro-batches reuse the
    # compiled program, so trace cost is O(S), not O(ticks)
    def make_fwd(s):
        return jax.jit(lambda b, sh, x, tok: stage_call(s, b, sh, x, tok))

    def make_bwd(s):
        def bwd(b, sh, x, tok, dy):
            (_, _), vjp = jax.vjp(
                lambda bb, ss, xx: stage_call(s, bb, ss, xx, tok), b, sh, x)
            return vjp((dy, jnp.ones((), jnp.float32)))
        return jax.jit(bwd)

    fwd_jit = [make_fwd(s) for s in range(S)]
    bwd_jit = [make_bwd(s) for s in range(S)]
    x_dummy = jnp.zeros((mb_size, T, cfg.d_model), cfg.adtype)

    zerot = lambda tree: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), tree)
    g_blocks = [zerot(b) for b in stage_blocks]
    g_shared = zerot(shared)
    loss = jnp.zeros((), jnp.float32)
    saved = {}                       # (s, mb) -> stage input activation
    cot = {}                         # (s, mb) -> cotangent of stage output
    peaks = [0] * S
    live = [0] * S
    for t, s, mb, phase in sc.slots():
        if phase == sched_mod.FWD:
            x_in = x_dummy if s == 0 else saved.pop(("wire", s, mb))
            y, c = fwd_jit[s](stage_blocks[s], shared, x_in, toks_mb[mb])
            loss = loss + c
            saved[(s, mb)] = x_in     # stage-granular remat: keep input only
            live[s] += 1
            peaks[s] = max(peaks[s], live[s])
            if s < S - 1:
                saved[("wire", s + 1, mb)] = y
        else:
            x_in = saved.pop((s, mb))
            live[s] -= 1
            dy = cot.pop((s, mb), jnp.zeros((mb_size, T, cfg.d_model),
                                            cfg.adtype))
            db, dsh, dx = bwd_jit[s](stage_blocks[s], shared, x_in,
                                     toks_mb[mb], dy)
            g_blocks[s] = jax.tree.map(
                lambda a, d: a + d.astype(jnp.float32), g_blocks[s], db)
            g_shared = jax.tree.map(
                lambda a, d: a + d.astype(jnp.float32), g_shared, dsh)
            if s > 0:
                cot[(s - 1, mb)] = dx
    assert not saved and not cot, "schedule left dangling buffers"
    if peaks != sc.per_stage_in_flight():
        raise AssertionError(
            f"buffer audit: measured in-flight peaks {peaks} != schedule's "
            f"accounting {sc.per_stage_in_flight()}")

    grads = dict(g_shared)
    grads["blocks"] = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *g_blocks)
    stats = {"n_ticks": sc.n_ticks,
             "bubble_fraction": sc.bubble_fraction(),
             "peak_in_flight": max(peaks),
             "per_stage_in_flight": peaks,
             "stage_layers": stage_layers}
    return loss, grads, stats

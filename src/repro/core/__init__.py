"""Whale core: strategy primitives, IR, engine, cost model, auto-parallel.

The user-facing surface mirrors the paper's API (``import repro as wh``):

    with wh.cluster(mesh_shape=(2, 4), axis_names=("data", "model")):
        with wh.replica():
            h = wh.sub("backbone", net)(params, x)
        with wh.split(dim=-1):
            logits = wh.sub("fc", head)(head_params, h)
"""
from repro.core.auto import (auto_parallel, graph_from_taskgraph,  # noqa: F401
                             search)
from repro.core.cost_model import (ClusterSpec, DeviceGroup, Hardware,  # noqa: F401
                                   ModelGraph, P100_16G, SegmentMeta,
                                   StrategySpec, T4_16G, TPU_V5E,
                                   V100_PAPER, WorkloadMeta,
                                   step_cost, throughput)
from repro.core.graph_opt import (GradAgg, LoweredGraph,  # noqa: F401
                                  StrategyNestingError, bridge_cost,
                                  compile_nested_plan, insert_bridges,
                                  lower, place_grad_aggregation, plan_bridge,
                                  validate_nesting)
from repro.core.hetero import (HeteroPlacement, balance_batch,  # noqa: F401
                               balance_stages, hetero_step_cost,
                               plan_placement)
from repro.core.ir import (Bridge, Edge, Subgraph, TaskGraph,  # noqa: F401
                           TensorMeta, capture_meta)
from repro.core.planner import (ExecutionPlan, compile_plan,  # noqa: F401
                                compile_plan_from_cluster, mesh_for_strategy,
                                rules_for_strategy, strategy_from_taskgraph)
from repro.core.sharding import (ShardingRules, constrain, hybrid_rules,  # noqa: F401
                                 use_rules)
from repro.core.strategies import (cluster, pipeline, replica, split,  # noqa: F401
                                   stage, sub)
from repro.core.strategies import auto_parallel as auto_scope  # noqa: F401
from repro.core.vdevice import Cluster, VirtualDevice  # noqa: F401

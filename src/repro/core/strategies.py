"""Whale strategy primitives (paper §2, Cases 1–5).

Scopes are context managers that (a) record strategy annotations into the
active Cluster's TaskGraph (the Whale IR) and (b) — for `replica` and
`split` — immediately apply the corresponding GSPMD sharding constraints to
tensors flowing through ``wh.sub``-wrapped subgraph calls.  `stage` /
`pipeline` scopes record stage boundaries; the executable pipeline schedule
is built by :mod:`repro.core.pipeline` from the recorded TaskGraph (JAX has
no TF-style graph editing, so pipelining is a *construction*, not a rewrite —
see DESIGN.md §5).

    with wh.cluster(mesh_shape=(2, 4), axis_names=("data", "model")):
        with wh.replica():                      # Case 1: data parallel
            h = wh.sub("backbone", net)(p1, x)
        with wh.split(dim=-1):                  # Case 2: + operator sharding
            logits = wh.sub("fc", head)(p2, h)

`auto_parallel` (Case 5) marks the graph for strategy search by
:mod:`repro.core.auto`.
"""
from __future__ import annotations

import functools
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.ir import StrategyAnnotation, Subgraph, TaskGraph, capture_meta
from repro.core.vdevice import Cluster

_tls = threading.local()


def _stack() -> list:
    if not hasattr(_tls, "scopes"):
        _tls.scopes = []
    return _tls.scopes


class _Scope:
    kind = "?"

    def __init__(self, **options):
        self.options = options

    def __enter__(self):
        # loud nesting errors at the offending `with` line: graph_opt owns
        # the legality rules (split innermost, stage needs pipeline, no
        # self-nesting, parallel scopes need an active cluster)
        from repro.core.graph_opt import validate_nesting
        stack = _stack()
        validate_nesting([a.kind for a in stack], entering=self.kind,
                         in_cluster=Cluster.current() is not None)
        stack.append(StrategyAnnotation(self.kind, dict(self.options),
                                        depth=len(stack)))
        return self

    def __exit__(self, *exc):
        _stack().pop()
        return False


class replica(_Scope):
    """Data parallelism: batch dim replicated model, sharded data."""
    kind = "replica"


class split(_Scope):
    """Operator sharding along `dim` of the subgraph output (paper Fig 4).

    ``experts=True`` marks the split as *expert parallelism* over the MoE
    ``experts`` dimension — nested inside ``replica`` this is the paper's
    ``replicate{split}`` M6 hybrid, lowered by :mod:`repro.core.graph_opt`
    with all-to-all dispatch/combine bridges instead of the
    all-gather/reduce-scatter of a tensor split.
    """
    kind = "split"

    def __init__(self, dim: int = -1, experts: bool = False):
        super().__init__(dim=dim, experts=experts)


class stage(_Scope):
    """Model-parallel stage boundary (paper Case 3)."""
    kind = "stage"
    _counter = 0

    def __enter__(self):
        self.options["index"] = stage._counter
        stage._counter += 1
        return super().__enter__()


class pipeline(_Scope):
    """GPipe-style pipelining of enclosed stages (paper Case 4)."""
    kind = "pipeline"

    def __init__(self, micro_batch: int = 4):
        super().__init__(micro_batch=micro_batch)
        stage._counter = 0


class auto_parallel(_Scope):
    """Case 5: let the engine pick the strategy via the cost model."""
    kind = "auto"


def cluster(*args, **kwargs) -> Cluster:
    return Cluster(*args, **kwargs)


def current_annotations() -> list:
    return list(_stack())


# ---------------------------------------------------------------------------
# wh.sub — subgraph capture + strategy application
# ---------------------------------------------------------------------------

def _data_axes(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes or (mesh.axis_names[0],)


def _model_axis(mesh):
    return "model" if "model" in mesh.shape else mesh.axis_names[-1]


def _constrain_tree(tree, spec_fn, mesh):
    def f(x):
        if not hasattr(x, "ndim") or x.ndim == 0:
            return x
        spec = spec_fn(x)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.tree.map(f, tree)


def sub(name: str, fn):
    """Wrap `fn` as a named Whale Subgraph.  Under an active cluster, calling
    the wrapper records IR metadata (abstract — eval_shape + jaxpr FLOPs) and
    applies the enclosing strategy's sharding constraints."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        cl = Cluster.current()
        if cl is None:
            return fn(*args, **kwargs)
        anns = current_annotations()
        inputs, outputs, flops, _ = capture_meta(
            lambda *a: fn(*a, **kwargs), *args)
        # convention: a leading dict positional arg is the param pytree —
        # record its leaves as Subgraph.params (used by the auto-parallel
        # cost path), the rest as data inputs.
        params_meta, data_meta = [], inputs
        if args and isinstance(args[0], dict):
            n_param_leaves = len(jax.tree.leaves(args[0]))
            params_meta = inputs[:n_param_leaves]
            data_meta = inputs[n_param_leaves:]
        sg = Subgraph(name=name, fn=fn, strategy=anns,
                      inputs=data_meta, outputs=outputs, flops=flops,
                      params=params_meta)
        kinds = sg.strategy_kinds()
        split_opts = sg.split_options() or {}
        expert_split = bool(split_opts.get("experts"))
        mesh = cl.mesh
        if "stage" in kinds:
            idx = next(a.options["index"] for a in anns if a.kind == "stage")
            sg.vdevice = cl.stage_vd(idx)
        elif "split" in kinds and "replica" in kinds:
            # nested replica{split}: the subgraph spans data AND model axes
            sg.vdevice = cl.hybrid_vd()
        elif "split" in kinds:
            sg.vdevice = cl.split_vd()
        elif "replica" in kinds:
            sg.vdevice = cl.replica_vd()
        cl.taskgraph.add(sg)

        out = fn(*args, **kwargs)
        if "split" in kinds and not expert_split:
            dim = next(a.options["dim"] for a in anns if a.kind == "split")
            ax = _model_axis(mesh)
            da = _data_axes(mesh)

            def spec(x):
                parts = [None] * x.ndim
                d = dim % x.ndim
                if x.shape[d] % mesh.shape[ax] == 0:
                    parts[d] = ax
                if d != 0 and x.shape[0] % _axsize(mesh, da) == 0:
                    parts[0] = da if len(da) > 1 else da[0]
                return P(*parts)

            out = _constrain_tree(out, spec, mesh)
        elif "replica" in kinds or expert_split:
            # expert splits combine back to a batch-sharded layout — the
            # all-to-all dispatch/combine lives inside the subgraph (see
            # models/moe.py moe_block_ep); the boundary layout is replica's
            da = _data_axes(mesh)

            def spec(x):
                if x.shape[0] % _axsize(mesh, da) != 0:
                    return None
                return P(da if len(da) > 1 else da[0],
                         *([None] * (x.ndim - 1)))

            out = _constrain_tree(out, spec, mesh)
        return out

    return wrapper


def _axsize(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n

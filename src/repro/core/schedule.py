"""Pipeline schedules as explicit per-tick tables (paper §3.1, Cases 3–4).

Whale's pipeline primitive fixes *what* runs on each stage; this module
fixes *when*.  A :class:`Schedule` is a table of ticks — one row per unit
of pipeline time, one column per stage, each cell either idle or a
``(micro_batch, phase)`` work item with ``phase ∈ {fwd, bwd}`` — plus the
derived quantities the rest of the system consumes:

- the **executor** (:mod:`repro.core.pipeline`) walks the table to run
  forward/backward work in exactly the scheduled order, sizing its
  activation buffers to :meth:`Schedule.peak_in_flight`;
- the **cost model** (:mod:`repro.core.cost_model`) prices the bubble via
  :func:`bubble_fraction` and peak activation memory via
  :func:`in_flight_micro_batches`.

Two schedules ship:

``gpipe``
    All forwards, then all backwards (the mirror image).  With S stages
    and M micro-batches the forward wave takes M + S − 1 ticks and the
    backward wave the same, so the span is 2·(M + S − 1) ticks and each
    stage idles (S − 1)/(M + S − 1) of them — the classic bubble.  Every
    stage must hold activations for all M micro-batches at its peak.

``1f1b``
    PipeDream-flush / memory-frugal one-forward-one-backward: each stage
    warms up with at most S − s − 1 forwards, then strictly alternates
    forward and backward, then drains.  Same span and same bubble
    fraction as GPipe (order changes, work does not) but a stage never
    holds more than min(S − s, M) ≤ S in-flight micro-batches — the
    property that lets uneven heterogeneous pipelines fit HBM (HetPipe,
    arXiv:2005.14038).

The module is pure Python (no jax) so schedule properties are testable
anywhere, including the CI's fast job.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FWD = "fwd"
BWD = "bwd"

#: tick-table cell: (micro_batch, phase) or None for an idle slot
Slot = Optional[Tuple[int, str]]

SCHEDULE_NAMES = ("gpipe", "1f1b")


def bubble_fraction_closed_form(n_stages: int, n_micro: int) -> float:
    """(S − 1)/(M + S − 1) — the fraction of a stage's span spent idle.

    Both shipped schedules realize exactly this (1F1B reorders work, it
    does not remove the ramp); schedules are validated against it.
    """
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_micro + n_stages - 1)


def in_flight_micro_batches(n_stages: int, n_micro: int,
                            schedule: str = "gpipe") -> int:
    """Peak number of micro-batches whose activations a stage must hold.

    The closed forms the cost model prices activation memory with; the
    tick tables are validated to match (`Schedule.peak_in_flight`).
    """
    if schedule == "1f1b":
        return min(n_stages, n_micro)
    if schedule == "gpipe":
        return n_micro
    raise ValueError(f"unknown schedule {schedule!r}; "
                     f"expected one of {SCHEDULE_NAMES}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A complete pipeline schedule: ``ticks[t][s]`` is stage ``s``'s work
    item at tick ``t`` (or None).  Built by :func:`make_schedule`."""
    name: str
    n_stages: int
    n_micro: int
    ticks: tuple                 # tuple[tuple[Slot, ...], ...]

    @property
    def n_ticks(self) -> int:
        return len(self.ticks)

    def slots(self):
        """Iterate (tick, stage, micro_batch, phase) over busy cells."""
        for t, row in enumerate(self.ticks):
            for s, cell in enumerate(row):
                if cell is not None:
                    yield t, s, cell[0], cell[1]

    # ---- derived properties --------------------------------------------

    def bubble_fraction(self) -> float:
        """Idle fraction of the busiest-possible span, from the table
        itself: each stage owes 2·M work units over ``n_ticks`` ticks."""
        busy_per_stage = 2 * self.n_micro
        return 1.0 - busy_per_stage / self.n_ticks

    def peak_in_flight(self) -> int:
        """max over stages of :meth:`per_stage_in_flight` — the activation
        buffer depth the executor must provision."""
        return max(self.per_stage_in_flight())

    def per_stage_in_flight(self) -> list:
        """Per stage: peak #{micro-batches forwarded but not yet
        backwarded} over the span."""
        peaks = [0] * self.n_stages
        live = [0] * self.n_stages
        for _, s, _, phase in self.slots():
            if phase == FWD:
                live[s] += 1
                peaks[s] = max(peaks[s], live[s])
            else:
                live[s] -= 1
        return peaks

    # ---- validation -----------------------------------------------------

    def validate(self) -> "Schedule":
        """Raise ValueError unless the table is a legal pipeline schedule:

        - every (stage, micro-batch) runs fwd exactly once and bwd exactly
          once;
        - fwd of stage s waits for fwd of stage s−1 on the same micro-batch
          (activations flow down), and bwd of stage s waits for bwd of
          stage s+1 (cotangents flow up) and for its own fwd.
        """
        S, M = self.n_stages, self.n_micro
        done = {}                       # (s, mb, phase) -> tick
        for t, s, mb, phase in self.slots():
            if not (0 <= s < S and 0 <= mb < M):
                raise ValueError(f"tick {t}: slot ({s}, {mb}) out of range")
            if phase not in (FWD, BWD):
                raise ValueError(f"tick {t}: bad phase {phase!r}")
            key = (s, mb, phase)
            if key in done:
                raise ValueError(f"{phase} of stage {s} mb {mb} scheduled "
                                 f"twice (ticks {done[key]} and {t})")
            if phase == FWD and s > 0:
                dep = (s - 1, mb, FWD)
                if done.get(dep, t) >= t:
                    raise ValueError(
                        f"tick {t}: fwd({s},{mb}) before fwd({s - 1},{mb})")
            if phase == BWD:
                if done.get((s, mb, FWD), t) >= t:
                    raise ValueError(
                        f"tick {t}: bwd({s},{mb}) before its own fwd")
                if s < S - 1:
                    dep = (s + 1, mb, BWD)
                    if done.get(dep, t) >= t:
                        raise ValueError(
                            f"tick {t}: bwd({s},{mb}) before "
                            f"bwd({s + 1},{mb})")
            done[(s, mb, phase)] = t
        missing = [(s, mb, ph) for s in range(S) for mb in range(M)
                   for ph in (FWD, BWD) if (s, mb, ph) not in done]
        if missing:
            raise ValueError(f"schedule never runs {missing[:4]}"
                             f"{'…' if len(missing) > 4 else ''}")
        return self

    # ---- executor view --------------------------------------------------

    def as_arrays(self):
        """→ (kind, mb): two (n_ticks, n_stages) int lists for the
        executor's scan — kind 0 = idle, 1 = fwd, 2 = bwd; mb the
        micro-batch index (0 where idle)."""
        kind = [[0] * self.n_stages for _ in range(self.n_ticks)]
        mb = [[0] * self.n_stages for _ in range(self.n_ticks)]
        for t, s, m, phase in self.slots():
            kind[t][s] = 1 if phase == FWD else 2
            mb[t][s] = m
        return kind, mb


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def gpipe_schedule(n_stages: int, n_micro: int) -> Schedule:
    """All forwards (M + S − 1 tick wave), then the mirrored backwards —
    exactly the order ``jax.grad`` of the fused forward scan induces."""
    S, M = n_stages, n_micro
    _check(S, M)
    span = M + S - 1
    ticks = []
    for t in range(span):                       # forward wave
        ticks.append(tuple(
            (t - s, FWD) if 0 <= t - s < M else None for s in range(S)))
    for t in range(span):                       # mirrored backward wave
        ticks.append(tuple(
            (t - (S - 1 - s), BWD) if 0 <= t - (S - 1 - s) < M else None
            for s in range(S)))
    return Schedule("gpipe", S, M, tuple(ticks)).validate()


def one_f_one_b_schedule(n_stages: int, n_micro: int) -> Schedule:
    """PipeDream-flush 1F1B via greedy simulation under the in-flight cap.

    Per stage: the in-flight window is capped at min(S − s, M); whenever a
    backward is ready it runs (that *is* the 1F1B policy — the cap forces
    the warmup, readiness forces the alternation), otherwise the next
    forward runs if the cap allows, otherwise the stage idles.
    """
    S, M = n_stages, n_micro
    _check(S, M)
    n_fwd = [0] * S
    n_bwd = [0] * S
    fwd_tick = {}                  # (s, mb) -> completion tick
    bwd_tick = {}
    ticks = []
    limit = [min(S - s, M) for s in range(S)]
    while min(n_bwd) < M:
        t = len(ticks)
        if t > 4 * (M + S):        # safety: a legal schedule is far shorter
            raise RuntimeError(f"1f1b simulation diverged (S={S}, M={M})")
        row = []
        for s in range(S):
            b, f = n_bwd[s], n_fwd[s]
            can_bwd = b < f and (
                bwd_tick.get((s + 1, b), t) < t if s < S - 1
                else fwd_tick.get((s, b), t) < t)
            can_fwd = f < M and (f - b) < limit[s] and (
                s == 0 or fwd_tick.get((s - 1, f), t) < t)
            if can_bwd:
                row.append((b, BWD))
                bwd_tick[(s, b)] = t
                n_bwd[s] += 1
            elif can_fwd:
                row.append((f, FWD))
                fwd_tick[(s, f)] = t
                n_fwd[s] += 1
            else:
                row.append(None)
        ticks.append(tuple(row))
    return Schedule("1f1b", S, M, tuple(ticks)).validate()


_GENERATORS = {"gpipe": gpipe_schedule, "1f1b": one_f_one_b_schedule}


def make_schedule(name, n_stages: int, n_micro: int) -> Schedule:
    """Name (or an already-built Schedule, passed through) → Schedule."""
    if isinstance(name, Schedule):
        return name
    try:
        gen = _GENERATORS[name]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; "
                         f"expected one of {SCHEDULE_NAMES}") from None
    return gen(n_stages, n_micro)


def _check(S: int, M: int) -> None:
    if S < 1 or M < 1:
        raise ValueError(f"need n_stages >= 1 and n_micro >= 1, "
                         f"got S={S}, M={M}")

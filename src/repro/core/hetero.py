"""Hardware-aware balancing over heterogeneous device groups (Whale §5).

The paper's headline mechanism: when a cluster mixes GPU generations
(V100 pods next to P100/T4 pods), an even split of work makes every step
wait for the slowest card.  Whale restores balance with two mechanisms,
both implemented here against the meta-driven cost model (DESIGN.md §2):

1. **Intra-stage batch balancing** (:func:`balance_batch`): replicas of
   the same (sub)graph placed on different hardware receive micro-batch
   shares proportional to their group's *effective* FLOP/s
   (peak × achievable efficiency), subject to each group's HBM cap.  The
   shares always sum to the global batch.
2. **Inter-stage layer balancing** (:func:`balance_stages`): pipeline
   stages hosted on unequal devices are sized so per-stage latency
   equalizes — layers allocated ∝ stage FLOP/s, repaired against each
   stage's memory budget.

:func:`plan_placement` combines the two into a :class:`HeteroPlacement`
and :func:`hetero_step_cost` evaluates the four-term step cost *per
group* with the slowest group dominating (a synchronous step can go no
faster than its stragglers).  Every function reduces **exactly** to the
homogeneous behaviour on a single-group / uniform :class:`ClusterSpec` —
tests/test_heterogeneous.py guards this byte-for-byte.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.cost_model import (ClusterSpec, CostBreakdown, DeviceGroup,
                                   ModelGraph, StrategySpec, WorkloadMeta,
                                   all_reduce_time, as_workload_meta,
                                   step_cost)


# ---------------------------------------------------------------------------
# integer proportional allocation (largest-remainder)
# ---------------------------------------------------------------------------


def proportional_split(total: int, weights: Sequence[float], *,
                       minimum: int = 0) -> list:
    """Split ``total`` integer units ∝ ``weights`` (largest-remainder).

    Guarantees ``sum(out) == total`` and ``out[i] >= minimum``; equal
    weights with a divisible total produce an exactly even split (the
    homogeneous-reduction requirement).
    """
    n = len(weights)
    if total < minimum * n:
        raise ValueError(f"cannot give {n} parts ≥{minimum} from {total}")
    spare = total - minimum * n
    wsum = sum(weights)
    if wsum <= 0:
        weights = [1.0] * n
        wsum = float(n)
    ideal = [spare * w / wsum for w in weights]
    out = [int(math.floor(x)) for x in ideal]
    rem = spare - sum(out)
    # hand the leftover units to the largest fractional parts (stable order)
    order = sorted(range(n), key=lambda i: (ideal[i] - out[i], -i),
                   reverse=True)
    for i in order[:rem]:
        out[i] += 1
    return [minimum + x for x in out]


# ---------------------------------------------------------------------------
# meta re-scaling: view the workload through one group's / stage's share
# ---------------------------------------------------------------------------


def scale_meta_batch(meta: WorkloadMeta, batch: int) -> WorkloadMeta:
    """The workload as seen by a replica group that owns ``batch`` samples.

    FLOPs, activations, and logits scale with the batch share; parameters
    are fully replicated into every DP group, so they do not.
    """
    f = batch / meta.batch if meta.batch else 0.0
    return dataclasses.replace(
        meta, fwd_flops=meta.fwd_flops * f,
        act_bytes_per_layer=meta.act_bytes_per_layer * f,
        logits_bytes=meta.logits_bytes * f, batch=batch)


def scale_meta_stage(meta: WorkloadMeta, layers: int, pp: int) -> WorkloadMeta:
    """The workload as seen by ONE pipeline stage holding ``layers`` layers.

    ``step_cost`` divides compute/params by ``pp`` internally, so the
    per-stage view multiplies the stage's layer share back by ``pp``:
    a stage holding L_s of L layers sees ``fwd_flops · (L_s/L) · pp`` so
    that its share after the internal ``/pp`` is exactly ``L_s/L``.  With
    the even split ``L_s = L/pp`` this is the identity — the homogeneous
    reduction is byte-exact.
    """
    f = layers / meta.n_layers
    return dataclasses.replace(
        meta,
        fwd_flops=meta.fwd_flops * f * pp,
        param_bytes=meta.param_bytes * f * pp,
        tp_shardable_param_bytes=meta.tp_shardable_param_bytes * f * pp,
        n_layers=layers * pp)


# ---------------------------------------------------------------------------
# strategy ↔ cluster compatibility
# ---------------------------------------------------------------------------


def strategy_fits_cluster(strat: StrategySpec, spec: ClusterSpec) -> bool:
    """Can ``strat`` be laid out on ``spec`` without splitting a shard
    across a hardware boundary?

    - ``pp == 1``: each group hosts whole replicas → ``tp·pp`` must divide
      every group's device count.
    - ``pp > 1``: each group hosts whole stages → ``dp·tp`` (one stage's
      devices) must divide every group's device count.
    """
    if strat.devices != spec.n_devices:
        return False
    mp = strat.model_parallel
    unit = mp * strat.pp if strat.pp == 1 else strat.dp * mp
    return all(g.n_devices % unit == 0 for g in spec.groups)


def shrink_cluster(spec: ClusterSpec, removed: dict) -> ClusterSpec:
    """The surviving cluster after eviction: ``removed`` maps group name →
    number of devices leaving that group (a flagged host's devices).

    This is the group-keyed counterpart of
    ``runtime.elastic.HostTopology.without`` for deployments that track a
    plain :class:`ClusterSpec` (real multi-process fleets keyed by
    ``process_index``) rather than the simulated host topology.

    Groups that lose all their devices are dropped; removing more devices
    than a group has, or naming an unknown group, is a loud error — the
    eviction machinery must never silently shrink the wrong pool.
    """
    by_name = {g.name: g for g in spec.groups}
    for name, k in removed.items():
        if name not in by_name:
            raise ValueError(f"unknown device group {name!r}; have "
                             f"{sorted(by_name)}")
        if k > by_name[name].n_devices:
            raise ValueError(
                f"cannot remove {k} devices from group {name!r} "
                f"({by_name[name].n_devices} present)")
    groups = []
    for g in spec.groups:
        n = g.n_devices - removed.get(g.name, 0)
        if n > 0:
            groups.append(dataclasses.replace(g, n_devices=n))
    if not groups:
        raise ValueError("eviction would remove the whole cluster")
    return ClusterSpec(groups=tuple(groups))


def grow_cluster(spec: ClusterSpec, added: dict,
                 new_groups: Sequence = ()) -> ClusterSpec:
    """The grown cluster after admission: ``added`` maps existing group
    name → number of devices joining that group (a re-admitted host's
    devices); ``new_groups`` appends whole :class:`DeviceGroup` entries
    for hardware the cluster has never seen (a spot pool of a new kind).

    Group-keyed counterpart of ``runtime.elastic.HostTopology.with_host``
    and the symmetric inverse of :func:`shrink_cluster`.  Unknown group
    names, non-positive device counts, and name collisions between
    ``new_groups`` and live groups are loud errors — the admission
    machinery must never silently grow the wrong pool.
    """
    by_name = {g.name: g for g in spec.groups}
    for name, k in added.items():
        if name not in by_name:
            raise ValueError(f"unknown device group {name!r}; have "
                             f"{sorted(by_name)} (new hardware goes in "
                             "new_groups)")
        if k <= 0:
            raise ValueError(
                f"cannot add {k} devices to group {name!r}; a joining "
                "host must bring at least one device")
    seen = set(by_name)
    for g in new_groups:
        if g.name in seen:
            raise ValueError(
                f"new group {g.name!r} collides with an existing group; "
                "grow it via added= instead")
        if g.n_devices <= 0:
            raise ValueError(
                f"new group {g.name!r} offers n_devices={g.n_devices}")
        seen.add(g.name)
    groups = [dataclasses.replace(g, n_devices=g.n_devices
                                  + added.get(g.name, 0))
              for g in spec.groups]
    groups.extend(new_groups)
    return ClusterSpec(groups=tuple(groups))


def partition_cluster(spec: ClusterSpec, names: Sequence[str]
                      ) -> tuple:
    """Split ``spec`` into (named groups, the rest) — two ClusterSpecs.

    The prefill/decode router (repro.serving.router) carves a mixed
    cluster into a prefill pool and a decode pool along *group*
    boundaries; this is the loud-error partition primitive it uses (the
    same idiom as :func:`shrink_cluster`): unknown names, duplicate
    names, taking every group, or taking none are all errors — a router
    must never silently serve from an empty pool.
    """
    by_name = {g.name: g for g in spec.groups}
    picked = list(names)
    if not picked:
        raise ValueError("partition needs at least one group name")
    if len(set(picked)) != len(picked):
        raise ValueError(f"duplicate group names in partition: {picked}")
    unknown = [n for n in picked if n not in by_name]
    if unknown:
        raise ValueError(f"unknown device groups {unknown}; have "
                         f"{sorted(by_name)}")
    if len(picked) == len(spec.groups):
        raise ValueError(
            "partition takes every group — the complement pool would be "
            "empty; a disaggregated deployment needs both pools populated")
    taken = tuple(g for g in spec.groups if g.name in set(picked))
    rest = tuple(g for g in spec.groups if g.name not in set(picked))
    return ClusterSpec(groups=taken), ClusterSpec(groups=rest)


def stage_groups_for(spec: ClusterSpec, strat: StrategySpec) -> tuple:
    """Map each of the ``pp`` stages to its hosting DeviceGroup.

    Stages are dealt to groups in declaration order, each group hosting
    ``n_g / (dp·tp)`` consecutive stages (whole stages never straddle a
    hardware boundary).
    """
    per_stage = strat.dp * strat.model_parallel
    out = []
    for g in spec.groups:
        out.extend([g] * (g.n_devices // per_stage))
    if len(out) != strat.pp:
        raise ValueError(
            f"{spec.n_devices} devices in groups {[g.name for g in spec.groups]}"
            f" do not tile {strat.pp} stages of {per_stage} devices")
    return tuple(out)


# ---------------------------------------------------------------------------
# mechanism 1: intra-stage throughput-proportional batch balancing
# ---------------------------------------------------------------------------


def _max_feasible_batch(meta: WorkloadMeta, strat: StrategySpec,
                        group: DeviceGroup) -> int:
    """Largest batch share whose peak memory fits the group's HBM
    (memory is monotone in batch via the activation/logits terms)."""
    def fits(b: int) -> bool:
        return step_cost(scale_meta_batch(meta, b), strat, group.hw).feasible

    if fits(meta.batch):
        return meta.batch
    if not fits(0):
        return -1           # params alone overflow — group unusable
    lo, hi = 0, meta.batch   # invariant: fits(lo), not fits(hi)
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid
    return lo


def balance_batch(meta: WorkloadMeta, strat: StrategySpec,
                  spec: ClusterSpec) -> tuple:
    """Per-group batch shares ∝ effective group FLOP/s, HBM-capped.

    Returns one integer share per group, summing to ``meta.batch``; a
    uniform cluster gets an exactly even split.  Raises ``ValueError``
    when no assignment fits (the caller prunes such strategies).
    """
    per_replica = strat.model_parallel * strat.pp
    dp_g = [g.n_devices // per_replica for g in spec.groups]
    strat_g = [dataclasses.replace(strat, dp=max(d, 1)) for d in dp_g]
    caps = [_max_feasible_batch(meta, s, g)
            for s, g in zip(strat_g, spec.groups)]
    if any(c < 0 for c in caps):
        bad = [g.name for g, c in zip(spec.groups, caps) if c < 0]
        raise ValueError(f"groups {bad} cannot hold the model at all")

    weights = [d * g.device_flops for d, g in zip(dp_g, spec.groups)]
    n = len(spec.groups)
    shares = [0] * n
    free = list(range(n))
    remaining = meta.batch
    # clamp-and-redistribute: overweight groups pin at their HBM cap, the
    # excess re-splits proportionally among the rest
    while True:
        split = proportional_split(remaining, [weights[i] for i in free])
        over = [i for i, s in zip(free, split) if s > caps[i]]
        for i, s in zip(free, split):
            shares[i] = s
        if not over:
            break
        for i in over:
            shares[i] = caps[i]
            remaining -= caps[i]
            free.remove(i)
        if not free:
            if remaining > 0:
                raise ValueError(
                    f"global batch {meta.batch} exceeds the cluster's "
                    f"combined HBM capacity under {strat.describe()}")
            break
    assert sum(shares) == meta.batch
    return tuple(shares)


# ---------------------------------------------------------------------------
# mechanism 2: inter-stage latency-equalizing layer balancing
# ---------------------------------------------------------------------------


def graph_stage_partition(graph: ModelGraph, pp: int,
                          weights: Sequence[float]) -> list | None:
    """Min-max segment-respecting partition of ``graph`` into ``pp`` stages.

    Dynamic program over cut positions: stage ``s`` hosting layers
    ``[j, i)`` costs ``Σ layer_costs[j:i] / weights[s]`` (weights are the
    hosting groups' effective FLOP/s), spans restricted to
    ``graph.valid_span`` (subdivide one segment XOR union whole segments;
    atomic segments stay whole).  Returns per-stage layer counts, or
    ``None`` when no valid partition exists — the auto-search prunes such
    ``pp`` values.  On a single-segment graph with uniform weights this
    reduces to the even split.
    """
    L = graph.n_layers
    if pp < 1 or pp > L:
        return None
    lc = graph.layer_costs()
    pre = [0.0]
    for c in lc:
        pre.append(pre[-1] + c)
    return partition_min_max(
        graph, pp, lambda s, j, i: (pre[i] - pre[j]) / weights[s])


def partition_min_max(graph: ModelGraph, pp: int, span_cost) -> list | None:
    """Min-max DP over valid spans with an arbitrary per-span cost.

    ``span_cost(stage_idx, lo, hi) -> float`` (``inf`` = infeasible).
    The max-over-stages objective decomposes stage by stage because each
    span's cost depends only on its own layers and its own stage index —
    so this is exact, not a heuristic, for whatever pricing the caller
    plugs in.  Returns per-stage layer counts or ``None``.
    """
    L = graph.n_layers
    if pp < 1 or pp > L:
        return None
    inf = math.inf
    ok = graph.valid_span

    # best[s][i]: minimal max stage-cost covering layers [0, i) with s stages
    best = [[inf] * (L + 1) for _ in range(pp + 1)]
    cut = [[-1] * (L + 1) for _ in range(pp + 1)]
    best[0][0] = 0.0
    for s in range(1, pp + 1):
        for i in range(s, L - (pp - s) + 1):
            for j in range(s - 1, i):
                if best[s - 1][j] == inf or not ok(j, i):
                    continue
                c = max(best[s - 1][j], span_cost(s - 1, j, i))
                if c < best[s][i]:
                    best[s][i] = c
                    cut[s][i] = j
    if best[pp][L] == inf:
        return None
    counts, i = [], L
    for s in range(pp, 0, -1):
        j = cut[s][i]
        counts.append(i - j)
        i = j
    counts.reverse()
    return counts


def _balance_stages_graph(graph: ModelGraph, strat: StrategySpec,
                          spec: ClusterSpec) -> tuple:
    """Segment-aware stage balancing under FULL four-term pricing.

    The flat balancer's two-phase heuristic (flops-proportional split +
    memory repair) is unnecessary here: per-stage cost depends only on
    the stage's own span and hosting group, so the exact min-max
    partition under the complete ``step_cost`` (compute + comm + bubble,
    inf when HBM overflows) comes straight out of the span DP.  The
    flops/weight DP objective alone would misplace cuts on clusters whose
    binding term is the param-proportional gradient traffic, not compute.
    """
    sgroups = stage_groups_for(spec, strat)
    pp = strat.pp

    def span_cost(s: int, lo: int, hi: int) -> float:
        return step_cost(graph.stage_meta(lo, hi, pp), strat,
                         sgroups[s].hw).total        # inf when infeasible

    counts = partition_min_max(graph, pp, span_cost)
    if counts is None:
        if not graph.feasible_pp(pp):
            raise ValueError(
                f"no segment-respecting partition of {graph.describe()} "
                f"into {pp} stages")
        raise ValueError(f"no layer allocation over {pp} stages fits HBM")
    return sgroups, tuple(counts)


def balance_stages(meta, strat: StrategySpec,
                   spec: ClusterSpec) -> tuple:
    """(stage→group mapping, per-stage layer counts).

    Per-stage latency is ``layers_s / flops_s``; equalizing it means
    ``layers_s ∝ flops_s`` of the hosting group.  The integer allocation
    (≥1 layer per stage, summing to ``n_layers``) is then repaired
    against each stage's HBM: overweight stages shed layers one at a time
    to the feasible stage with the most compute headroom.

    ``meta`` may be a segment-aware :class:`ModelGraph`: multi-segment
    graphs route to the min-max DP allocator (stage spans respect segment
    edges, per-layer costs come from each segment's own arithmetic);
    single-segment graphs flatten and take the proportional path below
    byte-identically.
    """
    if isinstance(meta, ModelGraph):
        if len(meta.segments) > 1:
            return _balance_stages_graph(meta, strat, spec)
        meta = meta.workload_meta()
    sgroups = stage_groups_for(spec, strat)
    weights = [g.device_flops for g in sgroups]
    layers = proportional_split(meta.n_layers, weights, minimum=1)

    def cost_with(i: int, n: int) -> CostBreakdown:
        return step_cost(scale_meta_stage(meta, n, strat.pp),
                         strat, sgroups[i].hw)

    # memory repair: migrate layers off stages whose slice overflows HBM.
    # Takers are checked at their post-transfer layer count, so a move
    # never creates a new overflow (no donor/taker ping-pong).
    for _ in range(meta.n_layers):
        costs = [cost_with(i, layers[i]) for i in range(strat.pp)]
        over = [i for i, c in enumerate(costs) if not c.feasible]
        if not over:
            break
        donors = [i for i in over if layers[i] > 1]
        takers = [i for i, c in enumerate(costs)
                  if c.feasible and cost_with(i, layers[i] + 1).feasible]
        if not donors or not takers:
            raise ValueError(
                f"no layer allocation over {strat.pp} stages fits HBM")
        src = max(donors, key=lambda i: costs[i].mem_bytes
                  - sgroups[i].hw.hbm_bytes)
        dst = max(takers, key=lambda i: sgroups[i].hw.hbm_bytes
                  - costs[i].mem_bytes)
        layers[src] -= 1
        layers[dst] += 1
    if any(not cost_with(i, layers[i]).feasible for i in range(strat.pp)):
        raise ValueError(
            f"no layer allocation over {strat.pp} stages fits HBM")
    return sgroups, tuple(layers)


# ---------------------------------------------------------------------------
# combined placement + per-group cost (slowest group dominates)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class UnitPlan:
    """One balanced unit of the placement: a replica group (``pp == 1``)
    or a pipeline stage (``pp > 1``)."""
    kind: str                  # "group" | "stage"
    group: DeviceGroup
    strategy: StrategySpec     # per-unit view (dp narrowed for groups)
    meta: WorkloadMeta         # workload re-scaled to this unit's share
    batch: int                 # batch share owned by this unit
    layers: int                # layers held (n_layers/pp when kind=group)
    cost: CostBreakdown


@dataclasses.dataclass(frozen=True)
class HeteroPlacement:
    """A hardware-aware assignment of work to a heterogeneous cluster."""
    spec: ClusterSpec
    strategy: StrategySpec
    units: tuple               # one UnitPlan per group (pp==1) / stage (pp>1)
    batch_shares: tuple        # per group, sums to the global batch
    layer_alloc: tuple         # per stage, sums to n_layers
    cost: CostBreakdown        # combined: max over units + cross-group comm

    @property
    def step_time(self) -> float:
        return self.cost.total

    def batch_slices(self) -> tuple:
        """Per-group ``(start, stop)`` offsets into the global batch —
        what a data loader uses to feed each hardware pool its share."""
        out, off = [], 0
        for b in self.batch_shares:
            out.append((off, off + b))
            off += b
        return tuple(out)

    def describe(self) -> str:
        bits = [f"{self.strategy.describe()} on "
                + "+".join(f"{g.n_devices}×{g.hw.name}"
                           for g in self.spec.groups)]
        if len(self.batch_shares) > 1:
            bits.append("batch=" + "/".join(str(b) for b in self.batch_shares))
        if self.strategy.pp > 1:
            bits.append("layers=" + "/".join(str(x) for x in self.layer_alloc))
        return " ".join(bits)


def _combine(units: Sequence[UnitPlan], extra_comm: float,
             detail: dict) -> CostBreakdown:
    """Max-reduce unit costs: the step is as slow as its slowest unit."""
    feasible = all(u.cost.feasible for u in units)
    worst = max(units, key=lambda u: (u.cost.total
                                      if u.cost.feasible else math.inf))
    detail = dict(detail)
    detail["units"] = {f"{u.kind}:{u.group.name}[{i}]": u.cost.detail
                      for i, u in enumerate(units)}
    return CostBreakdown(
        compute=worst.cost.compute,
        comm=worst.cost.comm + extra_comm,
        bubble=worst.cost.bubble,
        mem_bytes=max(u.cost.mem_bytes for u in units),
        feasible=feasible, detail=detail)


def price_batch_shares(meta: WorkloadMeta, strat: StrategySpec,
                       spec: ClusterSpec, shares, *,
                       overlap: float = 0.0) -> tuple:
    """Price an explicit per-group batch assignment (``pp == 1``).

    Returns ``(units, extra)``: one :class:`UnitPlan` per group with its
    share of the batch priced on its own hardware table, plus the
    cross-group gradient all-reduce on the cluster's bottleneck data link.
    This is the pricing kernel of :func:`plan_placement`, exposed so the
    calibration loop (profiler / fig_calibration / the drift controller)
    can re-price *stale* shares on a re-fitted ``ClusterSpec`` without
    re-running the balancer.
    """
    per_replica = strat.model_parallel
    dp_g = [g.n_devices // per_replica for g in spec.groups]
    us = []
    for g, d, b in zip(spec.groups, dp_g, shares):
        s_g = dataclasses.replace(strat, dp=max(d, 1))
        m_g = scale_meta_batch(meta, b)
        us.append(UnitPlan(
            kind="group", group=g, strategy=s_g, meta=m_g, batch=b,
            layers=meta.n_layers,
            cost=step_cost(m_g, s_g, g.hw, overlap=overlap)))
    ex = 0.0
    if len(spec.groups) > 1:
        # hierarchical DP reduction: in-group ring (already in each
        # unit's cost) + one cross-group ring on the bottleneck link
        # (nested ep: expert grads are ep-sharded → 1/ep the
        # volume; dense grads stay tp-sharded as in the flat path)
        if strat.ep > 1 and meta.expert_param_bytes:
            grad = ((meta.param_bytes - meta.expert_param_bytes)
                    / strat.tp
                    + meta.expert_param_bytes / strat.ep
                    ) * meta.grad_factor
        else:
            grad = meta.param_bytes * meta.grad_factor / strat.tp
        ex = all_reduce_time(grad, len(spec.groups),
                             spec.min_bw("data")) * (1.0 - overlap)
    return us, ex


def _plan_placement_graph(graph: ModelGraph, strat: StrategySpec,
                          spec: ClusterSpec, *, overlap: float = 0.0,
                          balanced: bool = True) -> HeteroPlacement:
    """Pipelined placement of a multi-segment graph: each stage priced
    from its own segments' arithmetic (modality-aware uneven stages)."""
    if not strategy_fits_cluster(strat, spec):
        raise ValueError(f"{strat.describe()} does not tile "
                         f"{[g.n_devices for g in spec.groups]} devices")
    detail: dict = {"placement": "balanced" if balanced else "naive",
                    "graph": graph.describe()}
    sgroups = stage_groups_for(spec, strat)
    pp = strat.pp

    def price_stages(layer_counts):
        units, off = [], 0
        for g, ls in zip(sgroups, layer_counts):
            m = graph.stage_meta(off, off + ls, pp)
            units.append(UnitPlan(
                kind="stage", group=g, strategy=strat, meta=m,
                batch=graph.batch, layers=ls,
                cost=step_cost(m, strat, g.hw, overlap=overlap)))
            off += ls
        return units

    even = tuple(proportional_split(graph.n_layers, [1.0] * pp, minimum=1))
    layers = even
    if balanced:
        try:
            sgroups, layers = _balance_stages_graph(graph, strat, spec)
        except ValueError:
            layers = even        # priced infeasible below, not raised
    units = price_stages(layers)
    if balanced and tuple(layers) != even and graph.valid_partition(even):
        # never-worse guard vs the even split, but only when the even
        # split is itself a legal (segment-respecting) partition
        u2 = price_stages(even)
        c1 = _combine(units, 0.0, detail)
        c2 = _combine(u2, 0.0, detail)
        if c2.feasible and (not c1.feasible or c2.total < c1.total):
            layers, units = even, u2
    cost = _combine(units, 0.0, detail)
    return HeteroPlacement(spec=spec, strategy=strat, units=tuple(units),
                           batch_shares=tuple([graph.batch]),
                           layer_alloc=tuple(layers), cost=cost)


def plan_placement(meta, strat: StrategySpec,
                   spec: ClusterSpec, *, overlap: float = 0.0,
                   balanced: bool = True) -> HeteroPlacement:
    """Balance ``meta`` under ``strat`` across ``spec`` and price it.

    ``balanced=False`` computes the *naive* placement (even batch shares /
    even layer split regardless of hardware) — the baseline that
    benchmarks/fig7_heterogeneous.py and fig10_multimodal.py compare
    against.

    ``meta`` may be a segment-aware :class:`ModelGraph`: unpipelined
    strategies and single-segment graphs flatten to the legacy meta (the
    pricing is byte-identical by construction); multi-segment graphs under
    ``pp > 1`` price each stage from its OWN segments' arithmetic
    (``ModelGraph.stage_meta``) and balance with the segment-respecting
    DP allocator.

    On a homogeneous spec the balanced and naive placements coincide and
    the combined cost equals ``step_cost`` on the single hardware table.
    """
    graph = meta if isinstance(meta, ModelGraph) else None
    meta = as_workload_meta(meta)
    if graph is not None and (len(graph.segments) == 1 or strat.pp == 1):
        graph = None            # flat pricing is exact for these
    if graph is not None:
        return _plan_placement_graph(graph, strat, spec,
                                     overlap=overlap, balanced=balanced)
    if not strategy_fits_cluster(strat, spec):
        raise ValueError(f"{strat.describe()} does not tile "
                         f"{[g.n_devices for g in spec.groups]} devices")
    detail: dict = {"placement": "balanced" if balanced else "naive"}
    units = []
    if strat.pp == 1:
        per_replica = strat.model_parallel
        dp_g = [g.n_devices // per_replica for g in spec.groups]

        def price(shares):
            return price_batch_shares(meta, strat, spec, shares,
                                      overlap=overlap)

        even = tuple(proportional_split(meta.batch, dp_g))
        shares = even
        if balanced:
            try:
                shares = balance_batch(meta, strat, spec)
            except ValueError:
                # no HBM-feasible assignment exists — price the even split
                # so callers see an infeasible CostBreakdown (mirroring
                # step_cost's semantics) instead of an exception
                shares = even
        units, extra = price(shares)
        if balanced and shares != even:
            # the even split is one point of the feasible share space — the
            # proportional heuristic (HBM-clamped, integerized) must never
            # return something worse than it
            u2, e2 = price(even)
            c1 = _combine(units, extra, detail)
            c2 = _combine(u2, e2, detail)
            if c2.feasible and (not c1.feasible or c2.total < c1.total):
                shares, units, extra = even, u2, e2
        if extra:
            detail["cross_group_allreduce"] = extra
        batch_shares = shares
        layer_alloc = tuple([meta.n_layers])
    else:
        sgroups = stage_groups_for(spec, strat)

        def price_stages(layer_counts):
            return [UnitPlan(
                kind="stage", group=g, strategy=strat,
                meta=scale_meta_stage(meta, ls, strat.pp),
                batch=meta.batch, layers=ls,
                cost=step_cost(scale_meta_stage(meta, ls, strat.pp), strat,
                               g.hw, overlap=overlap))
                for g, ls in zip(sgroups, layer_counts)]

        even = tuple(proportional_split(
            meta.n_layers, [1.0] * strat.pp, minimum=1))
        layers = even
        if balanced:
            try:
                sgroups, layers = balance_stages(meta, strat, spec)
            except ValueError:
                layers = even        # priced infeasible below, not raised
        units = price_stages(layers)
        if balanced and tuple(layers) != even:
            # same guard as the batch split: proportional-with-repair must
            # never lose to the even allocation it generalizes
            u2 = price_stages(even)
            c1 = _combine(units, 0.0, detail)
            c2 = _combine(u2, 0.0, detail)
            if c2.feasible and (not c1.feasible or c2.total < c1.total):
                layers, units = even, u2
        extra = 0.0
        batch_shares = tuple([meta.batch])
        layer_alloc = tuple(layers)
    cost = _combine(units, extra, detail)
    return HeteroPlacement(spec=spec, strategy=strat, units=tuple(units),
                           batch_shares=batch_shares,
                           layer_alloc=layer_alloc, cost=cost)


def hetero_step_cost(meta: WorkloadMeta, strat: StrategySpec,
                     spec: ClusterSpec, *, overlap: float = 0.0,
                     balanced: bool = True) -> CostBreakdown:
    """Four-term step cost on a heterogeneous cluster (slowest group wins).

    Single-group specs return **exactly** ``step_cost(meta, strat, hw)``
    up to the extra placement detail (regression-guarded).
    """
    return plan_placement(meta, strat, spec, overlap=overlap,
                          balanced=balanced).cost

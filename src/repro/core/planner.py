"""Whale Engine: strategy → execution plan → jitted step functions.

The engine is the paper's third layer (Fig 1): it consumes either (a) a
TaskGraph recorded by strategy scopes (Cases 1–5) or (b) an explicit
:class:`StrategySpec`, and produces an :class:`ExecutionPlan` whose methods
build the jitted training / serving step functions with full GSPMD
shardings.  The three planner steps from the paper map as:

  1. "Partition the model to Subgraphs"       → the TaskGraph / LMCfg stack
  2. "Map operator placements from the virtual device into the physical
     device"                                  → ShardingRules (logical axis →
                                                mesh axis) + PartitionSpecs
  3. "Add collective communication primitives among different subgraphs"
                                              → delegated to the XLA SPMD
                                                partitioner; verified post-hoc
                                                by the roofline harness, and
                                                explicit (ppermute / psum) in
                                                the pipeline and compressed-DP
                                                paths

Cross-pod gradient compression: with ``compress_pod=True`` the step is
wrapped in a ``shard_map`` that is *manual* over the ``pod`` axis and auto
(GSPMD) over the rest — the cross-pod gradient reduction becomes an explicit
int8 quantize → psum → dequantize with error feedback
(:mod:`repro.optim.grad_compress`), cutting DCN bytes 4×.

Heterogeneous clusters (DESIGN.md §2): the physical mesh stays rectangular —
heterogeneity lives in the *placement*, not the mesh shape.  When
``compile_plan`` is given a mixed-hardware ``ClusterSpec`` (plus the
workload's ``WorkloadMeta``), the resulting :class:`ExecutionPlan` carries a
:class:`~repro.core.hetero.HeteroPlacement`: throughput-proportional batch
shares per device group (``placement.batch_slices()`` feeds the data
loader) and latency-equalized per-stage layer counts.  A homogeneous spec
produces a plan byte-identical to the spec-less path (regression-guarded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cost_model import StrategySpec
from repro.core.sharding import ShardingRules, hybrid_rules, use_rules
from repro.core.vdevice import Cluster


# ---------------------------------------------------------------------------
# strategy → mesh / rules
# ---------------------------------------------------------------------------

def mesh_for_strategy(strat: StrategySpec, *, devices=None,
                      pods: int = 1, cluster_spec=None) -> Mesh:
    """Build a mesh whose axes realise the strategy.

    Axis order (major→minor): pod, stage, data, model — so TP rides the
    ICI-contiguous minor axis and only DP crosses pods.

    ``cluster_spec`` (a :class:`~repro.core.cost_model.ClusterSpec`) is
    validated against the strategy: shards must tile each hardware group
    without straddling a group boundary (DESIGN.md §2).  The mesh shape
    itself is unaffected — for a homogeneous spec the returned mesh is
    identical to the spec-less call; uneven *work* splits ride the
    placement (see :func:`compile_plan`), never the mesh.
    """
    if cluster_spec is not None:
        from repro.core.hetero import strategy_fits_cluster
        if not strategy_fits_cluster(strat, cluster_spec):
            raise ValueError(
                f"{strat.describe()} does not tile the device groups "
                f"{[(g.name, g.n_devices) for g in cluster_spec.groups]}")
    shape, names = [], []
    if pods > 1:
        shape.append(pods)
        names.append("pod")
    if strat.pp > 1:
        shape.append(strat.pp)
        names.append("stage")
    shape.append(strat.dp // pods if pods > 1 else strat.dp)
    names.append("data")
    shape.append(strat.model_parallel)   # tp and nested ep share the axis
    names.append("model")
    return jax.make_mesh(tuple(shape), tuple(names), devices=devices)


def rules_for_strategy(mesh: Mesh, strat: StrategySpec) -> ShardingRules:
    rules = hybrid_rules(mesh, fsdp=strat.zero >= 3)
    if not strat.vocab_split:
        rules.rules["vocab"] = None
    return rules


def strategy_from_taskgraph(cluster: Cluster) -> StrategySpec:
    """Derive the StrategySpec implied by recorded scope annotations
    (the Cases-1..5 path: scopes → IR → engine)."""
    mesh = cluster.mesh
    tg = cluster.taskgraph
    kinds = set()
    micro = 1
    n_stages = 0
    dense_split = expert_split = False
    for sg in (tg.nodes if tg else []):
        for ann in sg.strategy:
            kinds.add(ann.kind)
            if ann.kind == "pipeline":
                micro = max(micro, ann.options.get("micro_batch", 1))
            if ann.kind == "stage":
                n_stages = max(n_stages, ann.options.get("index", 0) + 1)
            if ann.kind == "split":
                if ann.options.get("experts"):
                    expert_split = True
                else:
                    dense_split = True
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    model_ax = mesh.shape.get("model", 1)
    tp = model_ax if dense_split else 1
    ep = model_ax if expert_split else 1
    pp = mesh.shape.get("stage", 1) if kinds & {"stage", "pipeline"} else 1
    return StrategySpec(dp=dp, tp=tp, pp=pp, ep=ep, micro_batches=micro,
                        vocab_split=dense_split)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda t: isinstance(t, P))


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in t)


@dataclasses.dataclass
class ExecutionPlan:
    """Everything needed to build jitted steps for one (model, mesh, strategy).

    ``placement`` is populated only for mixed-hardware clusters: a
    :class:`~repro.core.hetero.HeteroPlacement` holding per-group batch
    shares and per-stage layer counts (None on homogeneous clusters, so
    the plan is byte-identical to the pre-heterogeneous planner).
    """
    model: Any                      # repro.models.lm.Model
    mesh: Mesh
    rules: ShardingRules
    strategy: StrategySpec
    placement: Any = None           # hetero.HeteroPlacement | None
    # per-DeviceGroup fused-kernel tile geometry ({group name → KernelTiles},
    # from repro.kernels.autotune): populated whenever the plan was compiled
    # against a ClusterSpec, so a V100 group and a P100 group in one job run
    # the same kernels with different block sizes.  None → library defaults.
    kernel_tiles: dict | None = None

    def __post_init__(self):
        self.param_axes = self.model.axes()
        self.param_shapes = self.model.param_shapes()
        fsdp = self.strategy.zero >= 3
        self.param_specs = self.rules.param_specs_tree(
            self.param_axes, self.param_shapes, fsdp=fsdp)
        self.param_shardings = _ns(self.mesh, self.param_specs)

    def tiles_for(self, group: str | None = None):
        """Autotuned :class:`~repro.kernels.autotune.KernelTiles` for one
        device group (or, with ``group=None``, the *smallest* tiling across
        groups — the safe choice for a single SPMD program that every part
        must be able to run)."""
        from repro.kernels.autotune import DEFAULT_TILES
        if not self.kernel_tiles:
            return DEFAULT_TILES
        if group is not None:
            return self.kernel_tiles.get(group, DEFAULT_TILES)
        tiles = list(self.kernel_tiles.values())
        lo = tiles[0]
        for t in tiles[1:]:
            lo = dataclasses.replace(
                lo, **{f.name: min(getattr(lo, f.name), getattr(t, f.name))
                       for f in dataclasses.fields(lo)})
        return lo

    # ---- shardings for aux trees ----
    def batch_specs(self, batch_tree):
        return jax.tree.map(
            lambda s: self.rules.spec_for(
                ("batch",) + (None,) * (len(s.shape) - 1), s.shape),
            batch_tree)

    def batch_shardings(self, batch_tree):
        return _ns(self.mesh, self.batch_specs(batch_tree))

    def opt_specs(self, optimizer):
        state_axes = optimizer.state_axes(self.param_axes)
        state_shapes = jax.eval_shape(optimizer.init, self.param_shapes)
        fsdp = self.strategy.zero >= 1
        return self.rules.param_specs_tree(state_axes, state_shapes, fsdp=fsdp)

    def state_specs(self, batch: int, cache_len: int):
        shapes = self.model.decode_state_shapes(batch, cache_len)
        axes = self.model.state_axes()
        return jax.tree.map(
            lambda names, sds: self.rules.spec_for(names, sds.shape),
            axes, shapes, is_leaf=_is_axes)

    # ---- init ----
    def init_params(self, key):
        """Initialise params directly into their shardings (no host gather)."""
        with self.mesh:
            return jax.jit(self.model.init,
                           out_shardings=self.param_shardings)(key)

    # ---- training ----
    def train_step_fn(self, optimizer, *, micro_batches: int | None = None,
                      compress_pod: bool = False,
                      shard_grads: bool = False) -> Callable:
        """(params, opt_state, batch, step) → (params, opt_state, metrics).

        Unjitted body; use :meth:`jit_train_step` for the compiled version.
        ``micro_batches`` > 1 runs sequential gradient accumulation (the
        GPipe-style micro-batching of Case 4 without the stage axis; the
        staged pipeline lives in :mod:`repro.core.pipeline`).
        ``shard_grads``: constrain accumulated gradients to the parameter
        shardings so the DP reduction lowers to reduce-scatter (ZeRO) rather
        than a full all-reduce followed by slicing.
        """
        model, rules = self.model, self.rules
        M = micro_batches or self.strategy.micro_batches or 1
        mesh = self.mesh
        gspecs = self.param_specs

        def constrain_grads(g):
            if not shard_grads:
                return g
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, s)),
                g, gspecs, is_leaf=lambda t: isinstance(t, P))

        def grads_of(params, batch):
            (loss, metrics), g = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            return constrain_grads(g), loss, metrics

        def accumulate(params, batch):
            if M <= 1:
                g, loss, metrics = grads_of(params, batch)
                return g, loss, metrics

            def to_micro(x):
                from repro.core.pipeline import check_micro_divides
                check_micro_divides(x.shape[0], M)
                return x.reshape((M, x.shape[0] // M) + x.shape[1:])

            split = jax.tree.map(to_micro, batch)

            def body(carry, mb):
                acc, loss_sum = carry
                g, loss, metrics = grads_of(params, mb)
                return (jax.tree.map(jnp.add, acc, g), loss_sum + loss), metrics

            zeros = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g, loss_sum), metrics = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), split)
            g = jax.tree.map(lambda a: a / M, g)
            metrics = jax.tree.map(lambda a: a.mean(0), metrics)
            return g, loss_sum / M, metrics

        if compress_pod and "pod" in self.mesh.shape:
            from repro.optim import grad_compress

            def step_fn(params, opt_state, batch, step, comp_err):
                with use_rules(rules):
                    g, loss, metrics = accumulate(params, batch)
                    # cross-pod reduction with int8 error feedback (explicit;
                    # the in-pod reduction already happened under GSPMD)
                    g, comp_err = grad_compress.compressed_psum_tree(
                        g, "pod", comp_err, mean=True)
                    new_params, new_opt = optimizer.apply(
                        g, opt_state, params, step)
                metrics = dict(metrics, loss=loss)
                metrics = jax.tree.map(
                    lambda m: jax.lax.pmean(m, "pod"), metrics)
                return new_params, new_opt, metrics, comp_err

            return step_fn

        def step_fn(params, opt_state, batch, step):
            with use_rules(rules):
                g, loss, metrics = accumulate(params, batch)
                new_params, new_opt = optimizer.apply(
                    g, opt_state, params, step)
            metrics = dict(metrics, loss=loss)
            return new_params, new_opt, metrics

        return step_fn

    def jit_train_step(self, optimizer, batch_tree, *,
                       micro_batches: int | None = None,
                       compress_pod: bool = False, donate: bool = True,
                       shard_grads: bool = False):
        """Jitted train step with full in/out shardings."""
        fn = self.train_step_fn(optimizer, micro_batches=micro_batches,
                                compress_pod=compress_pod,
                                shard_grads=shard_grads)
        mesh = self.mesh
        pspec = self.param_shardings
        ospec = _ns(mesh, self.opt_specs(optimizer))
        bspec = self.batch_shardings(batch_tree)
        rep = NamedSharding(mesh, P())
        if compress_pod and "pod" in mesh.shape:
            # manual over 'pod' only: GSPMD still partitions data/model inside
            from repro.core.jax_compat import shard_map
            inner = shard_map(
                fn, mesh=mesh,
                in_specs=(P(), P(), P("pod"), P(), P()),
                out_specs=(P(), P(), P(), P()),
                axis_names=frozenset({"pod"}), check_vma=False)
            in_sh = (pspec, ospec, bspec, rep, pspec)
            jfn = jax.jit(inner, in_shardings=in_sh,
                          out_shardings=(pspec, ospec, rep, pspec),
                          donate_argnums=(0, 1, 4) if donate else ())
            return jfn
        in_sh = (pspec, ospec, bspec, rep)
        return jax.jit(fn, in_shardings=in_sh,
                       out_shardings=(pspec, ospec, rep),
                       donate_argnums=(0, 1) if donate else ())

    # ---- pipelined training (pp > 1; the schedule subsystem) ----
    def stage_layers(self):
        """Per-stage pattern-repeat counts for this plan's pipeline.

        Uneven when the plan carries a balanced :class:`HeteroPlacement`
        (its latency-equalizing ``layer_alloc``), else the even split.
        """
        import repro.core.pipeline as pipe
        S = self.strategy.pp
        if self.model.stack is None:
            # encdec: the pipeline cut is the fixed encoder|decoder tower
            # edge, not a layer-count split (see make_encdec_pipeline_loss)
            ecfg = self.model.ecfg
            return (ecfg.n_enc_layers, ecfg.n_dec_layers)
        if self.placement is not None and len(
                self.placement.layer_alloc) == S:
            return pipe.stage_layers_from_alloc(
                self.model.stack, self.placement.layer_alloc)
        return pipe.even_stage_layers(self.model.stack.n_rep, S)

    def jit_pipeline_train_step(self, optimizer, *,
                                micro_batches: int | None = None,
                                schedule: str | None = None,
                                stage_layers=None,
                                donate: bool = True):
        """Jitted (params, opt_state, tokens, step) → (params, opt_state,
        loss) through the pipeline executor (paper Cases 3–4).

        Requires a ``stage`` mesh axis (``mesh_for_strategy`` adds one for
        ``pp > 1`` plans).  Stage layer counts come from
        :meth:`stage_layers` — a heterogeneous plan's uneven allocation
        executes as-is — and the schedule defaults to the plan's
        ``strategy.schedule``.  Params/optimizer state use the padded
        stage-sharded layout of ``pipeline_params`` (identity for even
        splits).
        """
        import repro.core.pipeline as pipe
        if self.strategy.pp <= 1 or "stage" not in self.mesh.shape:
            raise ValueError(
                f"pipeline step needs pp > 1 and a 'stage' mesh axis; "
                f"strategy is {self.strategy.describe()}, mesh axes "
                f"{tuple(self.mesh.shape)}")
        if self.model.stack is None:
            # encdec routes to the two-tower engine: stage 0 = frontend +
            # encoder, stage 1 = decoder + loss head; stage_layers/schedule
            # do not apply (the cut is the fixed tower edge)
            return pipe.make_encdec_pipeline_train_step(
                self.model, self.mesh, self.rules, optimizer,
                micro_batches=micro_batches
                or self.strategy.micro_batches or 1,
                donate=donate)
        return pipe.make_pipeline_train_step(
            self.model, self.mesh, self.rules, optimizer,
            micro_batches=micro_batches or self.strategy.micro_batches or 1,
            stage_layers=stage_layers or self.stage_layers(),
            schedule=schedule or self.strategy.schedule,
            donate=donate)

    def init_pipeline_params(self, key, *, stage_layers=None):
        """Initialise params directly into the pipeline's (possibly
        padded) stage-sharded layout."""
        import repro.core.pipeline as pipe
        if self.model.stack is None:
            # encdec pipeline params are stage-replicated standard layout
            with self.mesh:
                return jax.jit(self.model.init,
                               out_shardings=self.param_shardings)(key)
        sl = stage_layers or self.stage_layers()
        pspecs = pipe.staged_specs(self.rules, self.param_axes,
                                   pipe._padded_model_shapes(self.model, sl))
        psh = _ns(self.mesh, pspecs)
        with self.mesh:
            return jax.jit(
                lambda k: pipe.pipeline_params(self.model,
                                               self.model.init(k), sl),
                out_shardings=psh)(key)

    # ---- serving ----
    def jit_serve_step(self, batch: int, cache_len: int, donate: bool = True):
        model, rules, mesh = self.model, self.rules, self.mesh

        def serve(params, tokens, state):
            with use_rules(rules):
                return model.serve_step(params, tokens, state)

        sspec = _ns(mesh, self.state_specs(batch, cache_len))
        tok = NamedSharding(mesh, self.rules.spec_for(("batch",), (batch,)))
        logits_sh = NamedSharding(
            mesh, self.rules.spec_for(("batch", "vocab"),
                                      (batch, self.model.cfg.padded_vocab)))
        return jax.jit(serve,
                       in_shardings=(self.param_shardings, tok, sspec),
                       out_shardings=(logits_sh, sspec),
                       donate_argnums=(2,) if donate else ())

    def jit_prefill(self, batch_tree, gen_budget: int = 64):
        model, rules, mesh = self.model, self.rules, self.mesh

        def prefill(params, batch):
            with use_rules(rules):
                return model.prefill(params, batch, gen_budget=gen_budget)

        bspec = self.batch_shardings(batch_tree)
        return jax.jit(prefill, in_shardings=(self.param_shardings, bspec))

    def paged_state_specs(self, batch: int, n_pages: int, page_size: int,
                          max_pages: int):
        shapes = self.model.paged_state_shapes(batch, n_pages, page_size,
                                               max_pages)
        axes = self.model.paged_state_axes()
        return jax.tree.map(
            lambda names, sds: self.rules.spec_for(names, sds.shape),
            axes, shapes, is_leaf=_is_axes)

    def jit_serve_step_paged(self, batch: int, n_pages: int, page_size: int,
                             max_pages: int, donate: bool = True):
        model, rules, mesh = self.model, self.rules, self.mesh

        def serve(params, tokens, state):
            with use_rules(rules):
                return model.serve_step_paged(params, tokens, state)

        sspec = _ns(mesh, self.paged_state_specs(batch, n_pages, page_size,
                                                 max_pages))
        tok = NamedSharding(mesh, self.rules.spec_for(("batch",), (batch,)))
        logits_sh = NamedSharding(
            mesh, self.rules.spec_for(("batch", "vocab"),
                                      (batch, self.model.cfg.padded_vocab)))
        return jax.jit(serve,
                       in_shardings=(self.param_shardings, tok, sspec),
                       out_shardings=(logits_sh, sspec),
                       donate_argnums=(2,) if donate else ())

    # ---- loss only (benchmarks / eval) ----
    def jit_loss(self, batch_tree):
        model, rules, mesh = self.model, self.rules, self.mesh

        def loss(params, batch):
            with use_rules(rules):
                return model.loss_fn(params, batch)

        return jax.jit(loss, in_shardings=(self.param_shardings,
                                           self.batch_shardings(batch_tree)))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def compile_plan(model, mesh: Mesh, strategy: StrategySpec | None = None,
                 rules: ShardingRules | None = None, *,
                 cluster_spec=None, workload_meta=None, placement=None,
                 overlap: float = 0.0) -> ExecutionPlan:
    """The Whale Engine entry: model + mesh + strategy → ExecutionPlan.

    ``cluster_spec`` + ``workload_meta``: on a mixed-hardware cluster the
    plan additionally carries the balanced :class:`HeteroPlacement`
    (DESIGN.md §2) — per-group batch shares / per-stage layer counts,
    priced at ``overlap``.  A caller that already holds a placement (e.g.
    from the auto-search) passes it via ``placement`` and no re-balancing
    happens.  A homogeneous (or absent) spec leaves ``plan.placement`` as
    None and the plan is identical to the pre-heterogeneous planner.
    """
    if strategy is None:
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                dp *= mesh.shape[a]
        strategy = StrategySpec(dp=dp, tp=mesh.shape.get("model", 1),
                                pp=mesh.shape.get("stage", 1))
    if rules is None:
        rules = rules_for_strategy(mesh, strategy)
    if (placement is None and cluster_spec is not None
            and not cluster_spec.is_homogeneous and workload_meta is not None):
        from repro.core.hetero import plan_placement
        placement = plan_placement(workload_meta, strategy, cluster_spec,
                                   overlap=overlap)
    kernel_tiles = None
    if cluster_spec is not None:
        from repro.kernels.autotune import autotune_cluster
        cfg = getattr(model, "cfg", None)
        if cfg is not None and getattr(cfg, "n_heads", 0):
            kernel_tiles = autotune_cluster(
                cluster_spec, head_dim=cfg.hd,
                group=cfg.n_heads // max(cfg.n_kv_heads, 1),
                d_model=cfg.d_model, vocab=cfg.padded_vocab)
    return ExecutionPlan(model=model, mesh=mesh, rules=rules,
                         strategy=strategy, placement=placement,
                         kernel_tiles=kernel_tiles)


def compile_plan_from_cluster(cluster: Cluster, model,
                              workload_meta=None) -> ExecutionPlan:
    """Cases-1..5 path: strategy inferred from the recorded TaskGraph.

    On a mixed-hardware cluster, pass the workload's ``WorkloadMeta``
    (e.g. ``graph_from_taskgraph(tg, batch).workload_meta()`` from
    :mod:`repro.core.auto`) to get a balanced placement on the plan;
    without it — or with a homogeneous ``cluster.spec`` —
    ``plan.placement`` stays None.
    """
    strat = strategy_from_taskgraph(cluster)
    return compile_plan(model, cluster.mesh, strategy=strat,
                        cluster_spec=getattr(cluster, "spec", None),
                        workload_meta=workload_meta)

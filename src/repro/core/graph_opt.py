"""Whale graph optimizations (paper §4): nested-strategy lowering.

The paper's claim is that two annotation primitives — ``replicate`` and
``split`` — plus *graph optimizations* applied by the framework suffice to
express every hybrid the giant-model zoo needs, including the **nested**
combination that trained M6 (data-parallel replicas each containing
expert-split MoE layers).  This module is that compiler.  It consumes a
:class:`~repro.core.ir.TaskGraph` whose subgraphs carry stacked (nested)
:class:`~repro.core.ir.StrategyAnnotation`\\ s and lowers it in four passes:

1. **Nesting validation** (:func:`validate_nesting`): the legal nest
   grammar.  ``split`` is always innermost; ``stage`` needs an enclosing
   ``pipeline``; no kind nests inside itself.  Supported shapes include the
   paper's ``replica{split}`` (DP outer, expert/tensor split inner) and the
   three-level ``pipeline{stage{replica{split}}}``.  Illegal nests raise
   :class:`StrategyNestingError` at scope *entry* (strategies.py calls in),
   so the error points at the offending ``with`` line.
2. **Subgraph replication** (:func:`replication_degree`): how many copies
   of each subgraph the mesh executes, from its replica ancestry.
3. **Bridge insertion** (:func:`insert_bridges`): consecutive subgraphs
   with different layouts get a :class:`~repro.core.ir.Bridge` — identity,
   all-gather / reduce-scatter at replicate⇄split edges, all-to-all at
   expert-split boundaries (MoE dispatch/combine), p2p at stage
   boundaries.  Each bridge records its autodiff transpose, the mesh-axis
   family it rides, and the payload bytes (priced by :func:`bridge_cost`
   with the ring formulas of :mod:`repro.core.cost_model`).
4. **Gradient-aggregation placement** (:func:`place_grad_aggregation`):
   every parameter-carrying subgraph under a ``replica`` scope gets its
   gradient all-reduce placed on the data axes — at 1/ep the volume for
   expert-split params, whose shards own disjoint experts.

:func:`lower` runs all four and returns a :class:`LoweredGraph` (bridges +
aggregations + the derived nested :class:`StrategySpec`);
:func:`compile_nested_plan` threads it into the engine, yielding an
executable :class:`~repro.core.planner.ExecutionPlan` for the nested
hybrid.  DESIGN.md §6 documents the bridge taxonomy.
"""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import (StrategySpec, all_gather_time,
                                   all_reduce_time, all_to_all_time,
                                   p2p_time, reduce_scatter_time)
from repro.core.ir import (PARALLEL_KINDS, Bridge, Edge, Subgraph, TaskGraph)


class StrategyNestingError(ValueError):
    """An illegal strategy-scope nest (raised at scope entry)."""


# ---------------------------------------------------------------------------
# pass 1: nesting validation
# ---------------------------------------------------------------------------

def validate_nesting(kinds, *, entering: str | None = None,
                     in_cluster: bool = True) -> tuple:
    """Validate a scope stack (outer→inner annotation kinds).

    ``kinds`` is the stack *before* ``entering`` is pushed (pass
    ``entering=None`` to validate a complete recorded stack).  Returns the
    canonical tuple of parallel kinds; raises :class:`StrategyNestingError`
    with an actionable message otherwise.
    """
    stack = [k for k in kinds if k in PARALLEL_KINDS]
    if entering is not None:
        if entering in PARALLEL_KINDS and not in_cluster:
            raise StrategyNestingError(
                f"'{entering}' scope outside any wh.cluster(): strategy "
                f"scopes annotate the active cluster's TaskGraph — open a "
                f"`with wh.cluster(...):` block first")
        stack = stack + [entering] if entering in PARALLEL_KINDS else stack
    for i, kind in enumerate(stack):
        outer = stack[:i]
        if kind in outer:
            raise StrategyNestingError(
                f"'{kind}' scope nested inside another '{kind}' "
                f"(stack: {' > '.join(outer)} > {kind}); each strategy "
                f"kind may appear once per nest")
        if "split" in outer:
            raise StrategyNestingError(
                f"'{kind}' scope nested inside 'split' "
                f"(stack: {' > '.join(outer)} > {kind}); split is an "
                f"operator sharding and must be the innermost scope")
        if kind == "stage" and "pipeline" not in outer:
            raise StrategyNestingError(
                "'stage' scope without an enclosing 'pipeline' — stages "
                "are pipeline boundaries (wh.pipeline(...) > wh.stage())")
        if kind == "pipeline" and "stage" in outer:
            raise StrategyNestingError(
                "'pipeline' scope nested inside a 'stage' — pipelines "
                "cannot nest in their own stages")
    return tuple(stack)


# ---------------------------------------------------------------------------
# pass 2: subgraph replication
# ---------------------------------------------------------------------------

def replication_degree(sg: Subgraph, mesh_axes: dict) -> int:
    """How many replicas of ``sg`` the mesh runs (its replica ancestry ×
    the data-axis sizes; 1 when the subgraph is not under a replica)."""
    if "replica" not in sg.parallel_kinds():
        return 1
    n = 1
    for a in ("pod", "data"):
        n *= mesh_axes.get(a, 1)
    return n


# ---------------------------------------------------------------------------
# pass 3: bridge insertion
# ---------------------------------------------------------------------------

def _layout(sg: Subgraph) -> tuple:
    """(stage_index, has_replica, split_kind) — split_kind ∈
    {None, "split", "expert"}."""
    kinds = sg.parallel_kinds()
    split = None
    if "split" in kinds:
        opts = sg.split_options() or {}
        split = "expert" if opts.get("experts") else "split"
    return (sg.stage_index(), "replica" in kinds, split)


def plan_bridge(src: Subgraph, dst: Subgraph) -> Bridge:
    """The collective glue for the ``src → dst`` boundary (Whale §4).

    Rules, in precedence order:
    - different pipeline stages → ``p2p`` over the stage axis
    - expert-split on exactly one side → ``all_to_all`` over the model
      axis (MoE token dispatch entering, combine leaving; self-transpose)
    - split on the destination only → ``all_gather`` (replicas' batch
      shards gathered so every split shard sees the full input; transpose
      ``reduce_scatter``)
    - split on the source only → ``reduce_scatter`` (partial-sum combine
      + batch re-scatter onto the replicas; transpose ``all_gather``)
    - same layout → ``identity``
    """
    payload = sum(t.bytes for t in src.outputs)
    s_stage, s_rep, s_split = _layout(src)
    d_stage, d_rep, d_split = _layout(dst)
    if (s_stage is not None or d_stage is not None) and s_stage != d_stage:
        # covers stage→stage AND pipeline entry/exit (stage on one side):
        # the boundary activation still moves off/onto the stage's devices
        def _n(s):
            return "outside" if s is None else f"stage {s}"
        return Bridge(kind="p2p", bwd_kind="p2p", axis="stage",
                      bytes=payload,
                      reason=f"{_n(s_stage)} → {_n(d_stage)}")
    if (s_split == "expert") != (d_split == "expert"):
        way = "dispatch" if d_split == "expert" else "combine"
        return Bridge(kind="all_to_all", bwd_kind="all_to_all",
                      axis="model", bytes=payload,
                      reason=f"expert {way} at a replica⇄split[experts] edge")
    if s_split is None and d_split is not None:
        return Bridge(kind="all_gather", bwd_kind="reduce_scatter",
                      axis="model", bytes=payload,
                      reason="replicate → split: gather batch shards so "
                             "every split shard sees the full input")
    if s_split is not None and d_split is None:
        return Bridge(kind="reduce_scatter", bwd_kind="all_gather",
                      axis="model", bytes=payload,
                      reason="split → replicate: combine partial sums and "
                             "re-scatter the batch onto the replicas")
    return Bridge(kind="identity", bwd_kind="identity", axis="",
                  bytes=0, reason="layouts agree")


def insert_bridges(tg: TaskGraph) -> list:
    """Walk consecutive subgraph pairs, planning one bridge per edge.

    Populates (and returns) ``tg.edges``; idempotent — re-lowering a graph
    replaces its edges rather than appending duplicates.
    """
    tg.edges = []
    for src, dst in zip(tg.nodes, tg.nodes[1:]):
        tg.add_edge(Edge(src=src.name, dst=dst.name,
                         bridge=plan_bridge(src, dst)))
    return tg.edges


def bridge_cost(bridge: Bridge, hw, n: int) -> float:
    """Wall-time of one bridge crossing on ``hw`` with ``n`` participants,
    using the ring-collective formulas the cost model prices."""
    if bridge.kind == "identity" or n <= 1:
        return 0.0
    bw = hw.bw_for_axis(bridge.axis or "model")
    if bridge.kind == "all_gather":
        return all_gather_time(bridge.bytes, n, bw)
    if bridge.kind == "reduce_scatter":
        return reduce_scatter_time(bridge.bytes, n, bw)
    if bridge.kind == "all_to_all":
        return all_to_all_time(bridge.bytes, n, bw)
    if bridge.kind == "p2p":
        return p2p_time(bridge.bytes, bw)
    if bridge.kind == "all_reduce":
        return all_reduce_time(bridge.bytes, n, bw)
    raise ValueError(f"unknown bridge kind {bridge.kind!r}")


# ---------------------------------------------------------------------------
# pass 4: gradient-aggregation placement
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradAgg:
    """Where one subgraph's gradient reduction runs (Whale §4: gradient
    aggregation is placed at the outermost replicate scope)."""
    subgraph: str
    collective: str            # "all_reduce" | "none"
    axes: tuple                # mesh-axis families the reduction rides
    bytes: float               # per-shard payload
    note: str = ""


def place_grad_aggregation(tg: TaskGraph, *, ep: int = 1,
                           tp: int = 1) -> list:
    """One :class:`GradAgg` per parameter-carrying subgraph.

    Replicated params all-reduce their grads over the data axes.  Under a
    nested expert split the expert shards own disjoint experts, so the
    aggregation stays on the data axes at ``1/ep`` the volume; a plain
    (tensor) split leaves grads model-sharded, so its per-shard data-axis
    reduction moves ``1/tp`` the volume.  Subgraphs outside any replica
    scope need no aggregation (their params live on exactly one device
    group).
    """
    out = []
    for sg in tg.nodes:
        if not sg.params:
            continue
        kinds = sg.parallel_kinds()
        pb = float(sg.param_bytes)
        if "replica" not in kinds:
            out.append(GradAgg(subgraph=sg.name, collective="none",
                               axes=(), bytes=0.0,
                               note="no replica scope — single owner"))
            continue
        opts = sg.split_options() or {}
        if "split" in kinds and opts.get("experts"):
            out.append(GradAgg(
                subgraph=sg.name, collective="all_reduce", axes=("data",),
                bytes=pb / max(ep, 1),
                note="expert-split: shards own disjoint experts — "
                     "data-axis reduction at 1/ep volume"))
        elif "split" in kinds:
            out.append(GradAgg(
                subgraph=sg.name, collective="all_reduce", axes=("data",),
                bytes=pb / max(tp, 1),
                note="tensor-split: model-sharded grads reduce over data "
                     "at 1/tp volume per shard"))
        else:
            out.append(GradAgg(
                subgraph=sg.name, collective="all_reduce", axes=("data",),
                bytes=pb, note="replicated params reduce over data"))
    return out


# ---------------------------------------------------------------------------
# the lowering driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredGraph:
    """The graph optimizer's output: a validated, bridged TaskGraph plus
    the nested strategy it implies.  ``replication`` maps each subgraph
    name to the number of copies the mesh runs (pass 2)."""
    taskgraph: TaskGraph
    strategy: StrategySpec
    edges: list
    grad_aggs: list
    replication: dict = dataclasses.field(default_factory=dict)

    @property
    def max_nesting_depth(self) -> int:
        return max((sg.nesting_depth for sg in self.taskgraph.nodes),
                   default=0)

    def bridges(self, kind: str | None = None) -> list:
        bs = [e.bridge for e in self.edges]
        return bs if kind is None else [b for b in bs if b.kind == kind]

    def describe(self) -> str:
        n_comm = sum(1 for b in self.bridges() if b.kind != "identity")
        return (f"{self.strategy.describe()} | depth "
                f"{self.max_nesting_depth} | {len(self.edges)} edges "
                f"({n_comm} bridged) | "
                + ", ".join(f"{e.src}→{e.dst}:{e.bridge.kind}"
                            for e in self.edges if e.bridge.kind != "identity"))


def lower(cluster) -> LoweredGraph:
    """Run the four optimization passes over ``cluster``'s TaskGraph."""
    tg = cluster.taskgraph
    if tg is None or not tg.nodes:
        raise ValueError("cluster has no recorded TaskGraph — trace the "
                         "model under `with wh.cluster(...):` first")
    for sg in tg.nodes:
        validate_nesting(sg.strategy_kinds())
    from repro.core.planner import strategy_from_taskgraph
    strat = strategy_from_taskgraph(cluster)
    mesh_axes = dict(cluster.mesh.shape)
    repl = {sg.name: replication_degree(sg, mesh_axes) for sg in tg.nodes}
    edges = insert_bridges(tg)
    aggs = place_grad_aggregation(tg, ep=strat.ep, tp=strat.tp)
    return LoweredGraph(taskgraph=tg, strategy=strat, edges=edges,
                        grad_aggs=aggs, replication=repl)


def compile_nested_plan(cluster, model, *, workload_meta=None,
                        overlap: float = 0.0):
    """Lower the recorded nested annotations and hand the result to the
    engine: cluster + model → :class:`~repro.core.planner.ExecutionPlan`.

    The returned plan's ``strategy`` carries the nested degrees (``dp``,
    ``tp``/``ep``, ``pp``) the graph optimizer derived; on a
    mixed-hardware ``cluster.spec`` the plan is balanced by
    :mod:`repro.core.hetero` exactly as explicit-strategy plans are.
    """
    lowered = lower(cluster)
    from repro.core.planner import compile_plan
    return compile_plan(model, cluster.mesh, strategy=lowered.strategy,
                        cluster_spec=getattr(cluster, "spec", None),
                        workload_meta=workload_meta, overlap=overlap)

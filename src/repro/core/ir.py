"""Whale IR: strategy-annotated subgraphs with meta-driven cost capture.

A :class:`Subgraph` records (a) the callable, (b) its strategy annotation
(from the enclosing scopes), (c) *metadata* captured abstractly — tensor
shapes/dtypes via ``jax.eval_shape`` and FLOPs/bytes via a jaxpr walk — with
no execution and no device allocation.  This is the paper's "meta-driven"
methodology (§2: "Different from the dry-run methodology, we use a
meta-driven method"): everything the planner and the auto-parallel cost model
need is available before anything runs.

The :class:`TaskGraph` is the sequential composition of subgraphs (Whale's
models are layered pipelines; general DAGs reduce to this for the strategies
in the paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TensorMeta:
    """Multi-Dimension tensor metadata (abstraction #2)."""
    shape: tuple
    dtype: Any
    logical_axes: tuple | None = None

    @property
    def bytes(self) -> int:
        return int(math.prod(self.shape)) * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass
class StrategyAnnotation:
    kind: str                      # replica | split | stage | pipeline | auto
    options: dict = dataclasses.field(default_factory=dict)
    depth: int = 0                 # nesting depth at which the scope opened
                                   # (0 = outermost; recorded by strategies)


# Parallelism-bearing annotation kinds, outermost-legal first.  "auto" is a
# marker for the search, not a layout, and never participates in nesting
# legality (repro.core.graph_opt.validate_nesting owns the rules).
PARALLEL_KINDS = ("pipeline", "stage", "replica", "split")


@dataclasses.dataclass(frozen=True)
class Bridge:
    """Collective glue inserted at a strategy boundary (Whale §4).

    The forward collective ``kind`` and its autodiff transpose ``bwd_kind``
    ride mesh-axis family ``axis``; ``bytes`` is the forward payload (the
    source subgraph's boundary activations).  Taxonomy (DESIGN.md §6):

    - ``identity``        same layout on both sides — no comm
    - ``all_gather``      replicate → split edge (fwd); transpose is
      ``reduce_scatter``
    - ``reduce_scatter``  split → replicate edge (partial-sum combine +
      batch re-scatter); transpose is ``all_gather``
    - ``all_to_all``      expert-split boundary (MoE dispatch/combine) —
      self-transpose
    - ``p2p``             pipeline stage boundary — self-transpose
    """
    kind: str
    bwd_kind: str
    axis: str
    bytes: int = 0
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class Edge:
    """A directed dataflow edge between two named subgraphs, carrying the
    bridge the graph optimizer inserted for their layout mismatch."""
    src: str
    dst: str
    bridge: Bridge


@dataclasses.dataclass
class Subgraph:
    """Unit of parallelism (abstraction #1)."""
    name: str
    fn: Callable | None
    strategy: list                 # stack of StrategyAnnotation (outer→inner)
    inputs: list = dataclasses.field(default_factory=list)    # TensorMeta
    outputs: list = dataclasses.field(default_factory=list)   # TensorMeta
    params: list = dataclasses.field(default_factory=list)    # TensorMeta
    flops: int = 0                 # fwd FLOPs, meta-derived
    vdevice: Any = None

    @property
    def param_bytes(self) -> int:
        return sum(t.bytes for t in self.params)

    @property
    def activation_bytes(self) -> int:
        return sum(t.bytes for t in self.outputs)

    def strategy_kinds(self) -> tuple:
        return tuple(s.kind for s in self.strategy)

    def parallel_kinds(self) -> tuple:
        """Layout-bearing annotation kinds, outer→inner (drops ``auto``)."""
        return tuple(s.kind for s in self.strategy if s.kind in PARALLEL_KINDS)

    @property
    def nesting_depth(self) -> int:
        """How many parallelism scopes enclose this subgraph (the paper's
        nested-hybrid depth: replica{split} = 2, pipeline{replica{split}},
        counted per layout scope — stage boundaries included)."""
        return len(self.parallel_kinds())

    def stage_index(self) -> int | None:
        for s in self.strategy:
            if s.kind == "stage":
                return s.options.get("index")
        return None

    def split_options(self) -> dict | None:
        for s in reversed(self.strategy):     # innermost split wins
            if s.kind == "split":
                return s.options
        return None


@dataclasses.dataclass
class TaskGraph:
    nodes: list = dataclasses.field(default_factory=list)
    # dataflow edges + their inserted bridges, populated by the graph
    # optimizer (repro.core.graph_opt.insert_bridges)
    edges: list = dataclasses.field(default_factory=list)

    def add(self, sg: Subgraph) -> Subgraph:
        self.nodes.append(sg)
        return sg

    def add_edge(self, edge: Edge) -> Edge:
        self.edges.append(edge)
        return edge

    def edges_into(self, name: str) -> list:
        return [e for e in self.edges if e.dst == name]

    def by_name(self, name: str) -> Subgraph:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def cluster_repeats(self) -> list:
        """Group structurally-identical consecutive nodes (paper §1 item 3:
        'groups repeatedly occurred sub-structures to prune the search
        space').  Two nodes are identical if their param/output signatures
        and strategies match."""
        groups: list = []
        for n in self.nodes:
            sig = (tuple((t.shape, str(t.dtype)) for t in n.params),
                   tuple((t.shape, str(t.dtype)) for t in n.outputs),
                   n.strategy_kinds())
            if groups and groups[-1]["sig"] == sig:
                groups[-1]["nodes"].append(n)
            else:
                groups.append({"sig": sig, "nodes": [n]})
        return groups


# ---------------------------------------------------------------------------
# meta-driven FLOPs: walk a jaxpr, count dot/conv work, scale scans by length
# ---------------------------------------------------------------------------

def _dot_flops(eqn) -> int:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = math.prod(lhs.shape[i] for i in lc) if lc else 1
    return 2 * math.prod(out.shape) * k


def jaxpr_flops(jaxpr) -> int:
    """Forward FLOPs of a closed jaxpr: dots + convs, recursing into
    control flow with trip-count multipliers (scan length, while=1).

    Generic recursion: any equation whose params carry a (list of) closed
    jaxpr(s) is descended into — this covers pjit, remat/checkpoint,
    custom_{jvp,vjp} wrappers and pallas grids regardless of the primitive
    name du jour.
    """
    total = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            out = eqn.outvars[0].aval
            lhs = eqn.invars[0].aval
            rhs = eqn.invars[1].aval
            total += 2 * math.prod(out.shape) * math.prod(rhs.shape[2:]) * lhs.shape[1]
        elif prim == "scan":
            inner = jaxpr_flops(eqn.params["jaxpr"].jaxpr)
            total += inner * eqn.params["length"]
        elif prim == "while":
            total += jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(jaxpr_flops(b.jaxpr) for b in branches)
        else:
            for v in eqn.params.values():
                for j in (v if isinstance(v, (tuple, list)) else (v,)):
                    inner = getattr(j, "jaxpr", j)   # ClosedJaxpr or raw Jaxpr
                    if hasattr(inner, "eqns"):
                        total += jaxpr_flops(inner)
    return total


def capture_meta(fn: Callable, *args, logical_axes=None) -> tuple:
    """eval_shape + jaxpr-FLOPs for `fn(*args)` — fully abstract."""
    out_shape = jax.eval_shape(fn, *args)
    jaxpr = jax.make_jaxpr(fn)(*args)
    flops = jaxpr_flops(jaxpr.jaxpr)

    def metas(tree):
        return [TensorMeta(tuple(x.shape), x.dtype) for x in jax.tree.leaves(tree)]

    return metas(args), metas(out_shape), flops, out_shape

"""Automatic parallel-strategy search (paper Case 5 / contributions #3–4).

Given a workload's metadata (from the Whale IR or directly from an LMCfg —
both are meta-driven, nothing executes) and a device budget, enumerate the
pruned strategy space and rank by the cost model:

- **Clustering** (paper: "groups repeatedly occurred sub-structures to prune
  the search space"): the TaskGraph's repeated layers are collapsed by
  :meth:`TaskGraph.cluster_repeats`; cost is evaluated once per distinct
  group × repeat count.  For LMCfg workloads the clustering is already
  structural (one pattern × n_rep), so the search never scales with depth.
- **Pruning**: (dp, tp, pp) only ranges over divisor factorizations of the
  device count; tp is capped at the size of one pod's minor dimension
  (operator sharding across DCN is never competitive); pp over divisors of
  the layer count; micro-batches over powers of two up to batch; pipelined
  points are priced under both schedules (GPipe vs the memory-frugal 1F1B
  — same bubble, different peak activation memory; see
  :mod:`repro.core.schedule`); infeasible (OOM) points are discarded by
  the cost model's memory term.

Returns the ranked candidates so callers can inspect the frontier (the
EXPERIMENTS.md §Auto table does exactly this).

**Heterogeneous clusters** (DESIGN.md §2): ``search`` / ``auto_parallel``
accept a :class:`~repro.core.cost_model.ClusterSpec` in place of the plain
device count.  The enumeration is then additionally pruned to placements
that tile every hardware group (no shard straddles a group boundary), each
candidate is balanced by :mod:`repro.core.hetero` (throughput-proportional
batch shares / latency-equalized stage layers), priced per group with the
slowest group dominating, and discarded if any group's HBM overflows.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.cost_model import (ClusterSpec, CostBreakdown, Hardware,
                                   ModelGraph, SegmentMeta, StrategySpec,
                                   TPU_V5E, WorkloadMeta, as_workload_meta,
                                   step_cost)


def divisors(n: int) -> list:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return out


@dataclasses.dataclass(frozen=True)
class Candidate:
    strategy: StrategySpec
    cost: CostBreakdown
    placement: object = None    # hetero.HeteroPlacement on mixed clusters

    @property
    def total(self) -> float:
        return self.cost.total


def enumerate_strategies(meta, devices, *,
                         max_tp: int = 16, max_pp: int | None = None,
                         micro_options: Iterable | None = None,
                         schedules: Iterable | None = None,
                         ) -> list:
    """Pruned (dp, tp, pp, micro, zero, vocab_split, schedule) enumeration.

    ``devices`` may be a plain count or a :class:`ClusterSpec`; the latter
    adds the group-tiling prune (shards never straddle a hardware group).

    ``meta`` may be a flat :class:`WorkloadMeta` or a segment-aware
    :class:`ModelGraph`.  For multi-segment graphs the pipeline-depth
    prune changes meaning: instead of ``n_layers % pp == 0`` (every layer
    interchangeable), ``pp`` is kept when a *segment-respecting* stage
    partition exists (stage boundaries subdivide one segment or land on
    segment edges; atomic frontends stay whole) — uneven stage sizes are
    the point of the multimodal search, the hetero balancer sizes them.

    ``schedules`` restricts the pipeline-schedule dimension (default both
    ``gpipe`` and ``1f1b`` when pp > 1).  Note the 1F1B activation pricing
    (min(M, S) in-flight) is the *schedule's* bound; the fused SPMD
    engine in :mod:`repro.core.pipeline` materializes gpipe-order memory
    under autodiff — pass ``schedules=("gpipe",)`` to search for that
    engine's HBM envelope (the executor warns on the mismatch too).
    """
    graph = meta if isinstance(meta, ModelGraph) else None
    if graph is not None and len(graph.segments) == 1:
        graph = None                 # layer-homogeneous: flat rules apply
    meta = as_workload_meta(meta)
    spec = devices if isinstance(devices, ClusterSpec) else None
    if spec is not None:
        from repro.core.hetero import strategy_fits_cluster
        devices = spec.n_devices
    max_pp = max_pp or min(meta.n_layers, 16)
    out = []
    for mp in divisors(devices):     # size of the model mesh axis
        if mp > max_tp:
            continue
        # how the model axis is used: flat operator split (tp), and — for
        # MoE workloads whose expert count it divides — the *nested*
        # replica{split[experts]} hybrid (ep), the paper's §4 nesting
        axis_uses = [{"tp": mp, "ep": 1}]
        if (mp > 1 and meta.n_moe_layers
                and meta.n_experts and meta.n_experts % mp == 0):
            axis_uses.append({"tp": 1, "ep": mp})
        rest = devices // mp
        for pp in divisors(rest):
            if pp > max_pp:
                continue
            if graph is not None:
                if pp > 1 and not graph.feasible_pp(pp):
                    continue
            elif meta.n_layers % pp:
                continue
            dp = rest // pp
            if meta.batch % dp:
                continue
            micros = micro_options or [m for m in (1, 2, 4, 8, 16, 32)
                                       if meta.batch // dp >= m]
            # pipelined points price both schedules: same bubble, but 1F1B
            # buffers min(M, S) in-flight micro-batches vs GPipe's M — the
            # memory term decides which (if either) fits
            scheds = (tuple(schedules) if schedules is not None
                      else ("gpipe", "1f1b")) if pp > 1 else ("gpipe",)
            for use in axis_uses:
                if spec is not None and not strategy_fits_cluster(
                        StrategySpec(dp=dp, pp=pp, **use), spec):
                    continue
                tp = use["tp"]
                for m in (micros if pp > 1 else [1]):
                    for zero in ((0, 1, 3) if dp > 1 else (0,)):
                        for vs in ((True, False) if tp > 1 else (False,)):
                            for of in (False, True):
                                for sched in scheds:
                                    out.append(StrategySpec(
                                        dp=dp, pp=pp, micro_batches=m,
                                        zero=zero, vocab_split=vs,
                                        opt_factored=of, schedule=sched,
                                        **use))
    return out


def search(meta, devices, hw: Hardware = TPU_V5E, *,
           top_k: int = 5, overlap: float = 0.5, **enum_kw) -> list:
    """Rank the pruned strategy space by estimated step time.

    Returns the ``top_k`` feasible :class:`Candidate`s, best first.
    ``devices`` may be a :class:`ClusterSpec` (mixed hardware); ``hw`` is
    then ignored and each candidate is balanced + priced per device group
    (candidates carry their :class:`HeteroPlacement`).

    ``meta`` may be a segment-aware :class:`ModelGraph` — pipelined
    candidates then cut stages at segment-respecting boundaries and price
    each stage from its own segments' arithmetic; flat metas price exactly
    as before (byte-identical via the single-segment flattening).
    """
    spec = devices if isinstance(devices, ClusterSpec) else None
    flat = as_workload_meta(meta)
    cands = []
    for strat in enumerate_strategies(meta, devices, **enum_kw):
        if spec is not None:
            from repro.core.hetero import plan_placement
            try:
                pl = plan_placement(meta, strat, spec, overlap=overlap)
            except ValueError:      # no HBM-feasible balance exists
                continue
            if pl.cost.feasible:
                cands.append(Candidate(strategy=strat, cost=pl.cost,
                                       placement=pl))
            continue
        if isinstance(meta, ModelGraph) and len(meta.segments) > 1 \
                and strat.pp > 1:
            # single homogeneous hardware, multi-segment graph: the exact
            # min-max segment-respecting partition under full pricing,
            # slowest stage dominating
            from repro.core.hetero import partition_min_max

            def span_cost(s, lo, hi, _strat=strat):
                return step_cost(meta.stage_meta(lo, hi, _strat.pp),
                                 _strat, hw, overlap=overlap).total

            counts = partition_min_max(meta, strat.pp, span_cost)
            if counts is None:
                continue
            off, worst = 0, None
            for ls in counts:
                c = step_cost(meta.stage_meta(off, off + ls, strat.pp),
                              strat, hw, overlap=overlap)
                off += ls
                if worst is None or c.total > worst.total:
                    worst = c
            if worst is not None and worst.feasible:
                cands.append(Candidate(strategy=strat, cost=worst))
            continue
        c = step_cost(flat, strat, hw, overlap=overlap)
        if c.feasible:
            cands.append(Candidate(strategy=strat, cost=c))
    cands.sort(key=lambda c: c.total)
    return cands[:top_k]


def auto_parallel(meta, devices,
                  hw: Hardware = TPU_V5E, **kw) -> StrategySpec:
    """The one-liner of Case 5: pick the best strategy, raise if none fits."""
    best = search(meta, devices, hw, top_k=1, **kw)
    if not best:
        if isinstance(devices, ClusterSpec):
            where = "+".join(f"{g.n_devices}×{g.hw.name}"
                             for g in devices.groups)
        else:
            where = f"{devices}×{hw.name}"
        raise RuntimeError(
            f"no feasible strategy for {as_workload_meta(meta).name} "
            f"on {where}")
    return best[0].strategy


# ---------------------------------------------------------------------------
# TaskGraph path (the scopes API): cluster repeats → segments → ModelGraph
# ---------------------------------------------------------------------------

def graph_from_taskgraph(tg, batch: int, *, name: str = "taskgraph"
                         ) -> ModelGraph:
    """Segment-aware workload summary from recorded Subgraph metadata.

    Clustering: each repeated-substructure group from
    :meth:`TaskGraph.cluster_repeats` becomes ONE segment — (cost of one
    representative) × (group size), the paper's search-space pruning —
    so a traced vision-tower → decoder nest arrives at the planner with
    its segment boundaries intact instead of flattened away.
    """
    segments = []
    for idx, g in enumerate(tg.cluster_repeats()):
        rep = g["nodes"][0]
        k = len(g["nodes"])
        segments.append(SegmentMeta(
            name=f"{rep.name}×{k}" if hasattr(rep, "name") else f"group{idx}",
            n_layers=k,
            fwd_flops=float(rep.flops * k),
            param_bytes=float(rep.param_bytes * k),
            act_bytes_per_layer=float(rep.activation_bytes)))
    if not segments:
        segments = [SegmentMeta(name="empty", n_layers=1, fwd_flops=0.0,
                                param_bytes=0.0, act_bytes_per_layer=0.0)]
    # traced graphs don't distinguish norm/bias params → the flatter 0.95
    # shardable fraction this path has always used
    return ModelGraph(name=name, segments=tuple(segments), batch=batch,
                      tp_shardable_fraction=0.95)

# NOTE: the deprecated ``meta_from_taskgraph`` shim was removed — use
# graph_from_taskgraph(tg, batch), which keeps segment boundaries for the
# planner, and flatten with .workload_meta() if a flat WorkloadMeta is
# needed.

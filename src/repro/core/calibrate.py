"""Profile-guided calibration of ``Hardware`` tables (DESIGN.md §10).

The cost model prices every plan — batch splits, layer allocations, serving
partitions, kernel tiles — from a hand-written ``Hardware`` table.  A mis-set
entry silently mis-routes all of them at once.  This module closes the
sim-to-measured loop: given timing *observations* recorded by
:mod:`repro.runtime.profiler` (wall-clock on real devices, the fault
injector's simulated clock in tests), it re-fits the table entries so the
analytic formulas price with measured numbers.

The key structural fact (see ``cost_model.step_cost_features``) is that every
analytic time is **linear in the reciprocals** of the hardware parameters:

    t  =  F·x_flops + H·x_hbm + B_f·x_fast + B_s·x_slow,
    x_p = 1/rate_p,

where the coefficients ``(F, H, B_f, B_s)`` depend only on the workload
(FLOP volume with the pipeline-bubble factor folded in; HBM traffic; ring-
effective bytes per link kind with overlap discounts folded in).  Fitting is
therefore ordinary least squares over the observation design matrix — no
iterative optimiser, no scipy.

Ridge-to-prior regularisation keeps the solve well-posed when observations
are collinear (whole-step times alone cannot separate FLOPs from bandwidth):
unidentifiable directions stay at the prior table's values and report zero
confidence, while decomposed observations (per-collective, per-kernel,
compute-only) make every parameter separately identifiable.

Units: observations timed on a *simulated* clock fit parameters in "FLOPs
(or bytes) per simulated second".  That is internally consistent — every
consumer of the fitted table compares times against other times from the
same table — so relative planning decisions (batch shares, strategy ranking)
are exactly as correct as with real seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import (CALIBRATION_PARAMS, ClusterSpec, Hardware,
                                   hardware_reciprocals, predict_step_time,
                                   step_cost_features)

__all__ = [
    "Observation", "CalibratedHardware", "fit", "prediction_error",
    "refit_spec", "synthesize_observations", "parameter_error",
]


# ---------------------------------------------------------------------------
# observation schema (DESIGN.md §10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Observation:
    """One timed event attributed to one device group.

    ``features`` maps calibration parameters to their linear coefficients
    (``cost_model.CALIBRATION_PARAMS``): per-device FLOPs for
    ``eff_flops``, HBM traffic bytes for ``hbm_bw``, ring-effective byte
    volumes for ``link_fast``/``link_slow``.  ``wall_s`` is the measured
    duration — real seconds on devices, simulated seconds under the fault
    injector.  ``kind`` is a label for reporting ("step", "compute",
    "collective", "kernel"); the fit only reads ``features``/``wall_s``.
    """
    kind: str
    group: str
    wall_s: float
    features: Mapping[str, float]
    step: int = -1


@dataclasses.dataclass(frozen=True)
class CalibratedHardware(Hardware):
    """A ``Hardware`` whose rate entries were re-fitted from observations.

    Drop-in everywhere a hand-written table is accepted (``step_cost``,
    ``prefill_time``, autotuning, placement search) — it *is* a
    ``Hardware``.  Extra fields record provenance: ``confidence`` maps each
    of ``CALIBRATION_PARAMS`` to a [0, 1] score (0 = parameter was not
    identifiable from the observations and sits at the prior; near 1 =
    tightly determined), ``n_observations`` the sample count, ``base_name``
    the prior table's name.
    """
    confidence: Mapping[str, float] = dataclasses.field(default_factory=dict)
    n_observations: int = 0
    base_name: str = ""


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------


def fit(observations: Sequence[Observation], base: Hardware, *,
        name: str | None = None, ridge: float = 1e-4) -> CalibratedHardware:
    """Least-squares re-fit of ``base``'s rate entries from observations.

    Solves ``min_x Σ_i ((a_i·x − t_i)/t_i)² + Σ_j λ_j (x_j − x0_j)²`` where
    row i holds observation i's feature coefficients and ``x0`` the prior
    reciprocals from ``base``.  Residuals are *relative* (each row scaled
    by 1/t_i): timing jitter is multiplicative, and without the weighting a
    microsecond kernel observation is invisible next to a second-long step.
    The per-column ridge weight ``λ_j = ridge · ||A_:j||²`` (computed on
    the weighted matrix) is scale-free: it only matters for directions the
    data barely constrains, pulling them to the prior instead of letting
    the solve blow up.

    Confidence per parameter is ``clip(1 − se_j / x_j, 0, 1) · n_j/(n_j+2)``
    with ``se_j`` the standard error from the residual variance and ``n_j``
    the number of observations touching the parameter — 0 for columns with
    no observations at all (kept exactly at the prior).
    """
    params = CALIBRATION_PARAMS
    x0 = np.array([hardware_reciprocals(base)[p] for p in params])
    obs = [o for o in observations if o.wall_s > 0.0]
    if not obs:
        return _build(base, x0, {p: 0.0 for p in params}, 0, name)

    raw = np.array([[float(o.features.get(p, 0.0)) for p in params]
                    for o in obs])
    t_raw = np.array([float(o.wall_s) for o in obs])
    A = raw / t_raw[:, None]       # relative residuals: each row / t_i
    t = np.ones_like(t_raw)

    col_sq = (A * A).sum(axis=0)
    seen = col_sq > 0.0
    lam = ridge * col_sq  # scale-free per-column ridge weight

    # Augmented rows implement the ridge-to-prior penalty exactly.
    sqrt_lam = np.sqrt(lam[seen])
    As = np.concatenate([A[:, seen], np.diag(sqrt_lam)], axis=0)
    ts = np.concatenate([t, sqrt_lam * x0[seen]])
    sol, *_ = np.linalg.lstsq(As, ts, rcond=None)

    x = x0.copy()
    x[seen] = sol
    # A non-positive reciprocal is unphysical (negative rate); noise can
    # produce one only for barely-constrained columns — snap to prior.
    bad = x <= 0.0
    x[bad] = x0[bad]

    n, k = A[:, seen].shape
    resid = A[:, seen] @ x[seen] - t
    sigma2 = float(resid @ resid) / max(n - k, 1)
    gram = A[:, seen].T @ A[:, seen] + np.diag(lam[seen])
    try:
        cov = sigma2 * np.linalg.inv(gram)
        se = np.sqrt(np.clip(np.diag(cov), 0.0, None))
    except np.linalg.LinAlgError:  # pragma: no cover - gram is PD by ridge
        se = np.full(k, np.inf)

    # per-column sample counts, for the small-sample confidence discount:
    # with 3 observations the residual variance estimate is itself noisy,
    # so the standard error alone overstates certainty.
    n_col = (A != 0.0).sum(axis=0)
    confidence = {}
    ji = 0
    for j, p in enumerate(params):
        if not seen[j] or bad[j]:
            confidence[p] = 0.0
        else:
            c = float(np.clip(1.0 - se[ji] / x[j], 0.0, 1.0))
            confidence[p] = c * n_col[j] / (n_col[j] + 2.0)
        if seen[j]:
            ji += 1
    return _build(base, x, confidence, len(obs), name)


def _build(base: Hardware, x: np.ndarray, confidence: Mapping[str, float],
           n_obs: int, name: str | None) -> CalibratedHardware:
    by = dict(zip(CALIBRATION_PARAMS, (float(v) for v in x)))
    link_bw = dict(base.link_bw)
    link_bw["fast"] = 1.0 / by["link_fast"]
    link_bw["slow"] = 1.0 / by["link_slow"]
    return CalibratedHardware(
        name=name or base.name,
        # the fit sees only the effective rate peak·mxu_eff; report it as
        # peak_flops holding mxu_eff at the prior so consumers that form
        # peak_flops·mxu_eff recover exactly the fitted effective rate.
        peak_flops=(1.0 / by["eff_flops"]) / base.mxu_eff,
        hbm_bw=1.0 / by["hbm_bw"],
        hbm_bytes=base.hbm_bytes,
        link_bw=link_bw,
        mxu_eff=base.mxu_eff,
        vmem_bytes=base.vmem_bytes,
        axis_kind=dict(base.axis_kind),
        confidence=dict(confidence),
        n_observations=n_obs,
        base_name=base.name if not isinstance(base, CalibratedHardware)
        else (base.base_name or base.name),
    )


# ---------------------------------------------------------------------------
# evaluation helpers
# ---------------------------------------------------------------------------


def prediction_error(observations: Sequence[Observation],
                     hw: Hardware) -> float:
    """Mean relative |predicted − measured| / measured over observations."""
    errs = [abs(predict_step_time(o.features, hw) - o.wall_s) / o.wall_s
            for o in observations if o.wall_s > 0.0]
    return float(np.mean(errs)) if errs else float("inf")


def parameter_error(fitted: Hardware, truth: Hardware,
                    params: Sequence[str] = CALIBRATION_PARAMS) -> float:
    """Max relative error of fitted rates vs a ground-truth table.

    Compared in rate space (effective FLOP/s, bytes/s) — the quantities the
    cost model actually consumes — so a ``CalibratedHardware`` that moved
    ``peak_flops`` while holding ``mxu_eff`` at the prior is judged on the
    product.
    """
    rf, rt = hardware_reciprocals(fitted), hardware_reciprocals(truth)
    return max(abs(1.0 / rf[p] - 1.0 / rt[p]) / (1.0 / rt[p])
               for p in params)


def refit_spec(spec: ClusterSpec,
               fits: Mapping[str, Hardware]) -> ClusterSpec:
    """Swap fitted tables into a ``ClusterSpec`` by device-group name.

    Groups without an entry keep their prior table, so a partial fit (one
    group never produced observations) still yields a usable spec.
    """
    return ClusterSpec(groups=tuple(
        dataclasses.replace(g, hw=fits[g.name]) if g.name in fits else g
        for g in spec.groups))


# ---------------------------------------------------------------------------
# synthetic observations (round-trip tests, fig_calibration part (a))
# ---------------------------------------------------------------------------


def synthesize_observations(meta, strat, truth: Hardware, *,
                            n_steps: int = 32, overlap: float = 0.0,
                            noise: float = 0.0, seed: int = 0,
                            group: str | None = None,
                            kernel_bytes: float | None = None,
                            decomposed: bool = True) -> list[Observation]:
    """Observations drawn from the analytic formulas on ``truth`` (+ noise).

    The round-trip test input: ``fit`` over these must recover ``truth``'s
    rates.  ``decomposed=True`` emits what a real profiler sees — separate
    compute, per-link collective, and HBM-bound kernel timings per step —
    which makes every parameter identifiable.  ``decomposed=False`` emits
    only whole-step times (collinear: the fit can then only be judged on
    *predictions*, not per-parameter recovery).  Multiplicative Gaussian
    noise models jitter; ``kernel_bytes`` defaults to one layer's
    activation traffic.
    """
    feats = step_cost_features(meta, strat, truth, overlap=overlap)
    recips = hardware_reciprocals(truth)
    gname = group or truth.name
    kb = float(kernel_bytes if kernel_bytes is not None
               else meta.act_bytes_per_layer)
    rng = np.random.default_rng(seed)

    def jit() -> float:
        return max(1.0 + noise * float(rng.standard_normal()), 0.05)

    out: list[Observation] = []
    for s in range(n_steps):
        if not decomposed:
            out.append(Observation("step", gname,
                                   predict_step_time(feats, truth) * jit(),
                                   dict(feats), s))
            continue
        comp = {"eff_flops": feats["eff_flops"]}
        out.append(Observation("compute", gname,
                               feats["eff_flops"] * recips["eff_flops"]
                               * jit(), comp, s))
        for p in ("link_fast", "link_slow"):
            if feats[p] > 0.0:
                out.append(Observation("collective", gname,
                                       feats[p] * recips[p] * jit(),
                                       {p: feats[p]}, s))
        if kb > 0.0:
            out.append(Observation("kernel", gname, kb * recips["hbm_bw"]
                                   * jit(), {"hbm_bw": kb}, s))
    return out

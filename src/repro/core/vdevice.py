"""Virtual devices (Whale abstraction #3).

A :class:`VirtualDevice` is a named group of physical devices; a
:class:`Cluster` owns the physical `jax.sharding.Mesh` and hands out virtual
devices.  Strategy scopes attach subgraphs to virtual devices; the planner
maps a virtual device onto mesh axes (replica groups ride the `data` axes,
operator shards the `model` axis, pipeline stages a `stage` axis) — see
DESIGN.md §4.

On TPU the mesh-axis order *is* the topology mapping: minor axes are
ICI-contiguous, the outermost (`pod`) axis crosses DCN — choosing which
logical axis lands where is exactly Whale's "choose the proper VD for a
Subgraph according to cluster topology".

Heterogeneous clusters (DESIGN.md §2): a Cluster may carry a
:class:`~repro.core.cost_model.ClusterSpec` describing per-device-group
hardware tables; virtual devices are then tagged with the hardware they
land on, and the planner/auto layers use the spec to balance work.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class VirtualDevice:
    """A logical device group = a sub-rectangle of the mesh."""
    name: str
    axes: tuple            # mesh axes this VD spans
    index: int = 0         # which slice along the partitioning axis (stages)
    hardware: str | None = None   # Hardware.name this VD lands on (hetero)

    def size(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.axes]))


class Cluster:
    """Physical cluster + virtual-device factory (Whale `wh.cluster`).

    Also the ambient context that strategy scopes and `wh.sub` record into.
    """

    _active: list = []

    def __init__(self, mesh: Mesh | None = None, *, mesh_shape: tuple | None = None,
                 axis_names: tuple | None = None, layout: dict | None = None,
                 spec=None):
        if mesh is None:
            if mesh_shape is None:
                n = len(jax.devices())
                mesh_shape, axis_names = (n,), ("data",)
            axis_names = axis_names or tuple(
                f"ax{i}" for i in range(len(mesh_shape)))
            mesh = jax.make_mesh(tuple(mesh_shape), tuple(axis_names))
        self.mesh = mesh
        self.layout = layout or {}
        # per-device-group Hardware tables (cost_model.ClusterSpec) — None
        # means "treat as homogeneous" (every pre-existing call site)
        self.spec = spec
        self.taskgraph = None   # filled by strategies.trace / scopes
        self._scope_stack: list = []

    # --- context management (the `with wh.cluster():` API) ---
    def __enter__(self):
        Cluster._active.append(self)
        from repro.core.ir import TaskGraph
        if self.taskgraph is None:
            self.taskgraph = TaskGraph()
        return self

    def __exit__(self, *exc):
        Cluster._active.pop()
        return False

    @classmethod
    def current(cls) -> "Cluster | None":
        return cls._active[-1] if cls._active else None

    # --- heterogeneous hardware tags ---
    def _uniform_hw(self) -> str | None:
        if self.spec is not None and self.spec.is_homogeneous:
            return self.spec.groups[0].hw.name
        return None

    def hardware_for_stage(self, index: int, n_stages: int) -> str | None:
        """Hardware tag for pipeline stage ``index`` of ``n_stages``.

        Delegates to :func:`repro.core.hetero.stage_groups_for` — the
        same dealing the planner prices — so tags always agree with a
        realizable placement.  A layout the planner would reject (groups
        don't tile whole stages) gets no tag rather than a wrong one.
        """
        if self.spec is None:
            return None
        from repro.core.cost_model import StrategySpec
        from repro.core.hetero import stage_groups_for
        per_stage, rem = divmod(self.spec.n_devices, n_stages)
        if rem or per_stage == 0:
            return None
        try:
            sgroups = stage_groups_for(
                self.spec, StrategySpec(dp=per_stage, pp=n_stages))
        except ValueError:
            return None
        return sgroups[index].hw.name

    # --- virtual devices ---
    def replica_vd(self) -> VirtualDevice:
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
        return VirtualDevice("replica", axes, hardware=self._uniform_hw())

    def split_vd(self) -> VirtualDevice:
        ax = "model" if "model" in self.mesh.shape else self.mesh.axis_names[-1]
        return VirtualDevice("split", (ax,), hardware=self._uniform_hw())

    def hybrid_vd(self) -> VirtualDevice:
        """Nested replica{split}: one VD spanning the data AND model axes
        (the subgraph is replicated over data, sharded over model)."""
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
        ax = "model" if "model" in self.mesh.shape else self.mesh.axis_names[-1]
        return VirtualDevice("hybrid", axes + (ax,),
                             hardware=self._uniform_hw())

    def stage_vd(self, index: int, n_stages: int | None = None) -> VirtualDevice:
        ax = "stage" if "stage" in self.mesh.shape else self.mesh.axis_names[0]
        if n_stages is None:
            # the stage axis size IS the pipeline depth on a staged mesh —
            # existing call sites (wh.sub tracing) get tags for free
            n_stages = self.mesh.shape.get("stage")
        hw = self._uniform_hw()
        if hw is None and self.spec is not None and n_stages:
            hw = self.hardware_for_stage(index, n_stages)
        return VirtualDevice(f"stage{index}", (ax,), index, hardware=hw)

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

"""Virtual devices (Whale abstraction #3).

A :class:`VirtualDevice` is a named group of physical devices; a
:class:`Cluster` owns the physical `jax.sharding.Mesh` and hands out virtual
devices.  Strategy scopes attach subgraphs to virtual devices; the planner
maps a virtual device onto mesh axes (replica groups ride the `data` axes,
operator shards the `model` axis, pipeline stages a `stage` axis).

On TPU the mesh-axis order *is* the topology mapping: minor axes are
ICI-contiguous, the outermost (`pod`) axis crosses DCN — choosing which
logical axis lands where is exactly Whale's "choose the proper VD for a
Subgraph according to cluster topology".
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class VirtualDevice:
    """A logical device group = a sub-rectangle of the mesh."""
    name: str
    axes: tuple            # mesh axes this VD spans
    index: int = 0         # which slice along the partitioning axis (stages)

    def size(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.axes]))


class Cluster:
    """Physical cluster + virtual-device factory (Whale `wh.cluster`).

    Also the ambient context that strategy scopes and `wh.sub` record into.
    """

    _active: list = []

    def __init__(self, mesh: Mesh | None = None, *, mesh_shape: tuple | None = None,
                 axis_names: tuple | None = None, layout: dict | None = None):
        if mesh is None:
            if mesh_shape is None:
                n = len(jax.devices())
                mesh_shape, axis_names = (n,), ("data",)
            axis_names = axis_names or tuple(
                f"ax{i}" for i in range(len(mesh_shape)))
            mesh = jax.make_mesh(tuple(mesh_shape), tuple(axis_names))
        self.mesh = mesh
        self.layout = layout or {}
        self.taskgraph = None   # filled by strategies.trace / scopes
        self._scope_stack: list = []

    # --- context management (the `with wh.cluster():` API) ---
    def __enter__(self):
        Cluster._active.append(self)
        from repro.core.ir import TaskGraph
        if self.taskgraph is None:
            self.taskgraph = TaskGraph()
        return self

    def __exit__(self, *exc):
        Cluster._active.pop()
        return False

    @classmethod
    def current(cls) -> "Cluster | None":
        return cls._active[-1] if cls._active else None

    # --- virtual devices ---
    def replica_vd(self) -> VirtualDevice:
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
        return VirtualDevice("replica", axes)

    def split_vd(self) -> VirtualDevice:
        ax = "model" if "model" in self.mesh.shape else self.mesh.axis_names[-1]
        return VirtualDevice("split", (ax,))

    def stage_vd(self, index: int) -> VirtualDevice:
        ax = "stage" if "stage" in self.mesh.shape else self.mesh.axis_names[0]
        return VirtualDevice(f"stage{index}", (ax,), index)

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

"""Multi-Dimension → mesh mapping (Whale's unified dimension abstraction).

Tensors in the model substrate are annotated with *logical* dimension names
("batch", "seq", "q_heads", "mlp", "experts", "vocab", ...).  A
:class:`ShardingRules` object — produced by the planner from the user's
strategy scopes — maps each logical name to zero or more physical mesh axes.
Models call :func:`constrain` / :func:`spec_for`; they never mention mesh
axes, which is what lets one model definition run under any Whale strategy
(replica / split / stage / pipeline / hybrid).

Divisibility pruning: when a logical dim's size does not divide evenly over
its assigned mesh axes, the assignment is dropped for that tensor (e.g. a
kv_heads=8 tensor on a 16-way model axis stays replicated).  This mirrors
Whale's planner choosing a legal sharding per subgraph rather than failing.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> mesh axis name, tuple of axis names, or None (replicated)
RuleMap = Mapping[str, object]

_tls = threading.local()


@dataclass
class ShardingRules:
    mesh: Mesh
    rules: dict = field(default_factory=dict)

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape.get(a, 1)
        return n

    def spec_for(self, names: Sequence[str | None], shape: Sequence[int] | None = None,
                 ) -> P:
        """Build a PartitionSpec for logical dim names, pruning non-divisible axes.

        Mesh axes may be used at most once in a spec; first-come wins (matching
        GSPMD's constraint that an axis shards a single dim).
        """
        used: set[str] = set()
        parts = []
        for i, name in enumerate(names):
            assigned = self.rules.get(name) if name is not None else None
            if assigned is None:
                parts.append(None)
                continue
            axes = (assigned,) if isinstance(assigned, str) else tuple(assigned)
            axes = tuple(a for a in axes if a in self.mesh.shape and a not in used)
            if not axes:
                parts.append(None)
                continue
            if shape is not None:
                # prune trailing axes until divisible
                while axes:
                    n = 1
                    for a in axes:
                        n *= self.mesh.shape[a]
                    if shape[i] % n == 0:
                        break
                    axes = axes[:-1]
                if not axes:
                    parts.append(None)
                    continue
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else tuple(axes))
        return P(*parts)

    def sharding_for(self, names, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(names, shape))

    def param_spec(self, names: Sequence[str | None], shape: Sequence[int],
                   *, fsdp_axes: Sequence[str] = (), min_fsdp_size: int = 65536,
                   ) -> P:
        """TP spec from the rules + ZeRO-3/FSDP extension: the largest
        still-unsharded, divisible, non-scan dim takes the data axes."""
        spec = self.spec_for(names, shape)
        fa = tuple(a for a in fsdp_axes if a in self.mesh.shape)
        if not fa or int(np.prod(shape)) < min_fsdp_size:
            return spec
        used = set()
        for p in spec:
            for a in ((p,) if isinstance(p, str) else (p or ())):
                used.add(a)
        fa = tuple(a for a in fa if a not in used)
        if not fa:
            return spec
        n = 1
        for a in fa:
            n *= self.mesh.shape[a]
        parts = list(spec)
        cands = [i for i in range(len(shape))
                 if parts[i] is None and (names[i] != "layers")
                 and shape[i] % n == 0]
        if not cands:
            return spec
        i = max(cands, key=lambda j: shape[j])
        parts[i] = fa[0] if len(fa) == 1 else fa
        return P(*parts)

    def param_specs_tree(self, axes_tree, shapes_tree, *, fsdp: bool = True,
                         fsdp_axes: Sequence[str] = ("pod", "data")):
        fa = fsdp_axes if fsdp else ()
        return jax.tree.map(
            lambda names, sds: self.param_spec(names, sds.shape, fsdp_axes=fa),
            axes_tree, shapes_tree,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t),
        )


def current_rules() -> ShardingRules | None:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def constrain(x: jax.Array, names: Sequence[str | None]) -> jax.Array:
    """Apply a logical sharding constraint if rules are active; else identity.

    Inside a partially-manual ``shard_map`` (the pipeline path) the context
    mesh differs from the rules' concrete mesh in axis *types*, so the spec
    is passed bare (resolved against the context mesh) with any manual axes
    stripped — those dims are already physically local.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec_for(names, x.shape)
    from repro.core.jax_compat import manual_axis_names
    manual = manual_axis_names()
    if manual:
        parts = tuple(None if (p in manual or (isinstance(p, tuple) and
                                               set(p) & manual)) else p
                      for p in spec)
        return jax.lax.with_sharding_constraint(x, P(*parts))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def tree_specs(axes_tree, shapes_tree, rules: ShardingRules):
    """Map an axes pytree (+ matching ShapeDtypeStruct pytree) to PartitionSpecs."""
    return jax.tree.map(
        lambda names, sds: rules.spec_for(names, sds.shape),
        axes_tree, shapes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t),
    )


def tree_shardings(axes_tree, shapes_tree, rules: ShardingRules):
    specs = tree_specs(axes_tree, shapes_tree, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda t: isinstance(t, P))


# ---------------------------------------------------------------------------
# canonical rule sets (the planner composes/overrides these)
# ---------------------------------------------------------------------------

def hybrid_rules(mesh: Mesh, *, fsdp: bool = True, data_axes=("pod", "data"),
                 model_axis: str = "model",
                 context_parallel: bool = False,
                 expert_axis: str | None = None) -> ShardingRules:
    """Whale Case-2 style hybrid: replica over data axes × operator split over model.

    - batch           → all data axes (pod-major)
    - TP targets      → model axis (q_heads/kv_heads/mlp/experts/vocab/ssm_heads)
    - FSDP (ZeRO-3)   → params additionally sharded over data axes on 'embed'
    - seq_shard       → decode-time KV sequence dim (flash-decode combine)
    - context_parallel → the *query sequence* dim additionally takes the
      model axis.  This is Whale's `split` applied along the sequence
      Multi-Dimension: for archs whose head count does not divide the model
      axis (gemma: 8 heads, qwen2-vl: 12 heads on 16 shards) head-sharding
      prunes and attention would otherwise replicate 16× — sharding q-seq
      restores the 1/16 work split (KV stays replicated, MQA-style CP).
    - expert_axis → a dedicated *expert-parallel* mesh axis (the nested
      ``replica{split[experts]}`` hybrid of graph_opt): the `experts`
      Multi-Dimension shards over it first, ahead of the model axis, so a
      mesh carrying an ``expert`` axis places whole experts per shard and
      the graph optimizer's all-to-all bridges carry the dispatch.  The
      explicit shard_map execution path is ``models.moe.moe_block_ep``.
    """
    data_axes = tuple(a for a in data_axes if a in mesh.shape)
    if expert_axis is None and "expert" in mesh.shape:
        expert_axis = "expert"
    rules = {
        "batch": data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None),
        # NOTE: a full-sequence-parallel variant ("seq" → model axis, the
        # residual stream staying seq-sharded through the block) was tried
        # and REFUTED in §Perf iteration 3: GSPMD falls into "involuntary
        # full rematerialization" on the (seq × d_ff) 2-D-conflicting MLP
        # grads and re-shards whole weight matrices per layer.  Only the
        # attention q/out path is seq-sharded (q_seq below).
        "seq": None,
        "embed": None,
        "q_heads": model_axis,
        "kv_heads": model_axis,
        "head_dim": None,
        "mlp": model_axis,
        "experts": ((expert_axis, model_axis)
                    if expert_axis and expert_axis in mesh.shape
                    else model_axis),
        # fallback: when `experts` prunes (E ∤ model axis, e.g. grok-1's 8
        # experts on 16 shards) the within-expert d_ff takes the model axis
        # instead (expert tensor parallelism).  spec_for's first-come-wins
        # rule arbitrates — see models/moe.py docstring.
        "expert_mlp": model_axis,
        "vocab": model_axis,
        "ssm_heads": model_axis,
        "state": None,
        "conv": None,
        "layers": None,
        # sequence dim of q when head-sharding is impossible (see above)
        "q_seq": (model_axis,) if context_parallel else None,
        "kv_seq": (model_axis,),            # decode KV cache sequence shards
        "fsdp": data_axes if fsdp else None,  # weight dim tagged for ZeRO-3
    }
    return ShardingRules(mesh=mesh, rules=rules)

"""Version-tolerant shims over jax APIs that moved between 0.4.x and 0.5+.

The repo targets current jax (``jax.shard_map``, abstract-mesh manual-axis
tracking) but must degrade gracefully on the 0.4.x line some containers
ship.  Only the two APIs the core actually uses are shimmed:

- :func:`shard_map` — ``jax.shard_map(..., axis_names=, check_vma=)`` on
  new jax; falls back to ``jax.experimental.shard_map.shard_map`` with the
  equivalent ``auto=`` / ``check_rep=`` spelling (``axis_names`` lists the
  *manual* axes, legacy ``auto`` lists the complement).
- :func:`manual_axis_names` — the set of mesh axes that are manual in the
  current tracing context (inside a ``shard_map`` body).  New jax exposes
  this via the abstract mesh; 0.4.x binds manual axes into the axis env.
"""
from __future__ import annotations

import jax


def manual_axis_names() -> frozenset:
    """Mesh axes currently bound manual (inside shard_map); else empty."""
    gam = getattr(jax.sharding, "get_abstract_mesh", None)
    if gam is not None:
        am = gam()
        if am is None or getattr(am, "empty", True):
            return frozenset()
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is None:
            return frozenset()
        return frozenset(a for a, t in getattr(am, "_name_to_type", {}).items()
                         if t == axis_type.Manual)
    from jax._src import core
    try:
        return frozenset(core.get_axis_env().axis_sizes)
    except Exception:
        return frozenset()


def axis_size(axis) -> jax.Array:
    """``jax.lax.axis_size`` (new jax) or the psum-of-ones equivalent
    (0.4.x, where the collective folds to a constant at trace time)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    import jax.numpy as jnp
    return jax.lax.psum(jnp.ones((), jnp.int32), axis)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` on new jax; legacy experimental spelling on 0.4.x."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as legacy
    kw = {"check_rep": bool(check_vma)}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

"""Meta-driven cost model (paper contribution #4).

"Different from the dry-run methodology, we use a meta-driven method to
measure the cost when we run the workload in different devices or
environments" — the cost of a candidate strategy is computed analytically
from tensor *metadata* (shapes/dtypes/FLOPs captured by the Whale IR via
``jax.eval_shape``) plus a table of hardware constants.  Nothing is lowered,
compiled, or executed during strategy search.

The cost of one training step under a strategy is a four-term sum (the
paper: "a combination of computation, communication, memory and other
metadata"):

  T_step = T_compute + T_comm + T_bubble        subject to  M_peak <= HBM

- ``T_compute``: FLOPs / (devices-sharing-the-work × peak FLOP/s), with a
  configurable MXU efficiency factor.  Training FLOPs = 3 × forward (fwd +
  2×bwd), + 1 extra forward when full remat is on.
- ``T_comm``: per-collective byte volumes × the bandwidth of the mesh axis
  they ride (ICI vs DCN), using standard ring-collective cost formulas
  (all-reduce moves 2·(n−1)/n · bytes, all-gather/reduce-scatter (n−1)/n).
- ``T_bubble``: GPipe bubble fraction (S−1)/(M+S−1) applied to the pipeline's
  compute time.
- ``M_peak``: params + optimizer state + gradients (each divided by the axes
  that shard them) + activation working set (micro-batched, remat-aware).

Hardware tables ship for TPU_V5E (the target), V100_16G/ETH35 (the paper's
own cluster — used by benchmarks/fig2 & fig5 to check the cost model
reproduces the paper's measured speedup ratios), and the P100/T4-class
parts that appear in Whale's *heterogeneous* experiments (§5).

Heterogeneous clusters (DESIGN.md §2–3): a :class:`ClusterSpec` holds one
:class:`DeviceGroup` per hardware kind (e.g. 8×V100 + 8×T4).  The four-term
cost is then evaluated *per group* — each group sees its own ``Hardware``
table and its share of the work — and the step time is the **max** over
groups (the slowest group dominates a synchronous step).  The balancing
mechanisms that choose those shares live in :mod:`repro.core.hetero`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

# ---------------------------------------------------------------------------
# hardware tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float            # FLOP/s per chip (bf16 / fp16 tensor)
    hbm_bw: float                # bytes/s per chip
    hbm_bytes: float             # device memory per chip
    link_bw: dict                # mesh-axis kind -> bytes/s per chip (uni-dir)
    mxu_eff: float = 0.55        # achievable fraction of peak on real matmuls
    # on-chip fast-memory budget visible to a Pallas program (VMEM on TPU;
    # the shared-memory/L2 working-set analog on GPUs).  The per-Hardware
    # kernel autotuner (repro.kernels.autotune) sizes its tiles against
    # this, so a small-VMEM part tiles smaller than a big one.
    vmem_bytes: float = 16 * 2**20
    axis_kind: Mapping[str, str] = dataclasses.field(
        default_factory=lambda: {})

    def bw_for_axis(self, axis: str) -> float:
        kind = self.axis_kind.get(axis, "fast")
        return self.link_bw[kind]

    @property
    def flops_per_hbm_byte(self) -> float:
        """Roofline balance point: achievable FLOPs per HBM byte moved.
        A kernel tile must reuse each loaded byte at least this many times
        or the part runs bandwidth-bound — the autotuner grows tiles on
        high-ratio parts (T4, TPU) and shrinks them on low-ratio ones."""
        return self.peak_flops * self.mxu_eff / self.hbm_bw


# TPU v5e (assignment constants): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI.
TPU_V5E = Hardware(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=16 * 2**20,                    # ~16 MiB VMEM per core
    link_bw={"fast": 50e9, "slow": 6.25e9},   # ICI link / DCN per chip
    axis_kind={"data": "fast", "model": "fast", "stage": "fast",
               "pod": "slow"},
)

# The paper's cluster: V100-16G with NVLink inside a server, 35 Gb/s Ethernet
# between servers (§3).  8 GPUs per server.
V100_PAPER = Hardware(
    name="v100_eth35",
    peak_flops=125e12,            # V100 tensor-core fp16 peak
    hbm_bw=900e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=8 * 2**20,                     # Volta SMEM+L2 working set
    link_bw={"fast": 150e9, "slow": 35e9 / 8 / 2},  # NVLink vs 35Gb shared by 8
    axis_kind={"data": "slow", "model": "fast", "stage": "fast"},
    mxu_eff=0.45,
)

# P100-16G: the previous-generation part Whale's heterogeneous cluster mixes
# with V100s (§5).  No tensor cores — fp16 peak ≈ 2× the 9.3 TFLOP/s fp32.
P100_16G = Hardware(
    name="p100_16g",
    peak_flops=18.7e12,
    hbm_bw=732e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=4 * 2**20,                     # Pascal: half Volta's on-chip
    link_bw={"fast": 80e9, "slow": 35e9 / 8 / 2},   # NVLink1 vs shared Eth
    axis_kind={"data": "slow", "model": "fast", "stage": "fast"},
    mxu_eff=0.40,
)

# T4-16G: the inference-class card that shows up in shared production pools —
# 65 TFLOP/s fp16 tensor, PCIe only (no NVLink).
T4_16G = Hardware(
    name="t4_16g",
    peak_flops=65e12,
    hbm_bw=300e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=6 * 2**20,                     # Turing SMEM+L2 working set
    link_bw={"fast": 16e9, "slow": 35e9 / 8 / 2},   # PCIe3 x16 vs shared Eth
    axis_kind={"data": "slow", "model": "fast", "stage": "fast"},
    mxu_eff=0.40,
)


# ---------------------------------------------------------------------------
# heterogeneous cluster description (DESIGN.md §2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceGroup:
    """A homogeneous pool of devices inside a (possibly mixed) cluster."""
    name: str
    hw: Hardware
    n_devices: int

    @property
    def device_flops(self) -> float:
        """Effective FLOP/s of ONE device (peak × achievable efficiency)."""
        return self.hw.peak_flops * self.hw.mxu_eff

    @property
    def group_flops(self) -> float:
        """Effective FLOP/s of the whole group."""
        return self.device_flops * self.n_devices


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Per-device-group hardware tables for one physical cluster.

    A homogeneous cluster is the single-group special case; every
    heterogeneity-aware code path must reduce *exactly* to the homogeneous
    behaviour when ``is_homogeneous`` (regression-guarded by
    tests/test_heterogeneous.py).
    """
    groups: tuple

    def __post_init__(self):
        if not self.groups:
            raise ValueError("ClusterSpec needs at least one DeviceGroup")

    @classmethod
    def homogeneous(cls, hw: Hardware, n_devices: int,
                    name: str | None = None) -> "ClusterSpec":
        return cls(groups=(DeviceGroup(name or hw.name, hw, n_devices),))

    @property
    def n_devices(self) -> int:
        return sum(g.n_devices for g in self.groups)

    @property
    def is_homogeneous(self) -> bool:
        return len({g.hw.name for g in self.groups}) == 1

    @property
    def total_flops(self) -> float:
        return sum(g.group_flops for g in self.groups)

    def slowest(self) -> DeviceGroup:
        return min(self.groups, key=lambda g: g.device_flops)

    def min_bw(self, axis: str) -> float:
        """Bottleneck bandwidth for a collective spanning every group."""
        return min(g.hw.bw_for_axis(axis) for g in self.groups)


# ---------------------------------------------------------------------------
# collective cost formulas (ring algorithms)
# ---------------------------------------------------------------------------

def all_reduce_time(bytes_: float, n: int, bw: float) -> float:
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * bytes_ / bw


def all_gather_time(bytes_: float, n: int, bw: float) -> float:
    """bytes_ = full (gathered) tensor size."""
    if n <= 1:
        return 0.0
    return (n - 1) / n * bytes_ / bw


reduce_scatter_time = all_gather_time


def all_to_all_time(bytes_: float, n: int, bw: float) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) / n * bytes_ / bw / n


def p2p_time(bytes_: float, bw: float) -> float:
    return bytes_ / bw


# ---------------------------------------------------------------------------
# strategy description (what the auto-searcher enumerates)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """A point in Whale's strategy space for one TaskGraph.

    dp × max(tp, ep) × pp must equal the device count.  ``zero`` ∈
    {0, 1, 2, 3} (stage-3 = FSDP: params sharded over dp).  ``vocab_split``
    shards the classifier head over tp (the paper's Fig-4 technique).
    ``micro_batches`` only matters when pp > 1 (GPipe) or when used for
    grad accumulation.

    ``ep`` is the *nested* expert-parallel degree — the paper's
    ``replicate{split}`` hybrid (§4, the M6 recipe): DP outer, the MoE
    layers' ``experts`` dimension split over the model axis inner.  Expert
    weights shard ep-ways, the dense layers see the model axis as extra
    data parallelism, and dispatch/combine become all-to-all bridges
    (:mod:`repro.core.graph_opt`).  ``ep`` rides the same mesh axis as
    ``tp`` — when both exceed 1 they must be equal.
    """
    dp: int = 1
    tp: int = 1
    pp: int = 1
    micro_batches: int = 1
    zero: int = 0
    remat: bool = True
    vocab_split: bool = True
    opt_factored: bool = False     # adafactor-style O(N/d) second moments
    # pipeline schedule (repro.core.schedule): "gpipe" holds all M
    # micro-batches of activations in flight; "1f1b" caps at min(M, pp)
    schedule: str = "gpipe"
    # nested expert parallelism: experts split over the model axis inside
    # each data-parallel replica (replica{split} — Whale §4 nesting)
    ep: int = 1

    def __post_init__(self):
        if self.ep < 1:
            raise ValueError(f"ep must be >= 1, got {self.ep}")
        if self.ep > 1 and self.tp > 1 and self.ep != self.tp:
            raise ValueError(
                f"nested ep={self.ep} and tp={self.tp} ride the same model "
                f"axis and must be equal when both exceed 1")

    @property
    def model_parallel(self) -> int:
        """Size of the model mesh axis: operator split and expert split
        share it (ep == tp when both are active)."""
        return max(self.tp, self.ep)

    @property
    def devices(self) -> int:
        return self.dp * self.model_parallel * self.pp

    def describe(self) -> str:
        bits = []
        inner = []
        if self.tp > 1:
            inner.append(f"split×{self.tp}")
        if self.ep > 1:
            inner.append(f"split[experts]×{self.ep}")
        if self.dp > 1:
            nest = "{" + " ".join(inner) + "}" if inner else ""
            bits.append(f"replica×{self.dp}"
                        + (f"+zero{self.zero}" if self.zero else "") + nest)
        else:
            bits.extend(inner)
        if self.pp > 1:
            sched = "" if self.schedule == "gpipe" else f",{self.schedule}"
            bits.append(f"pipeline×{self.pp}(µb={self.micro_batches}{sched})")
        if self.opt_factored:
            bits.append("adafactor")
        if not bits:
            bits.append("single-device")
        return " ".join(bits)


@dataclasses.dataclass(frozen=True)
class WorkloadMeta:
    """Per-step metadata of one model, extracted from the Whale IR / config.

    Everything here is derivable with eval_shape — no execution.  FLOPs are
    *forward* FLOPs for the global batch; the cost model applies the 3×
    training multiplier itself.
    """
    name: str
    fwd_flops: float               # forward FLOPs / step (global batch)
    param_bytes: float             # total parameter bytes
    # bytes of params that a `split`/tp strategy can shard (e.g. the big FC);
    # the rest is replicated under pure TP.
    tp_shardable_param_bytes: float
    act_bytes_per_layer: float     # activation bytes / layer for global batch
    n_layers: int
    batch: int
    # classifier-head term (the paper's Fig-4/5 case): logits bytes / step
    logits_bytes: float = 0.0
    head_param_bytes: float = 0.0
    # grad/optimizer bytes per param byte (AdamW fp32: grads 1 + m 1 + v 1)
    opt_state_factor: float = 2.0
    grad_factor: float = 1.0
    # MoE terms (zero for dense models — every ep-aware path then
    # reduces exactly to the flat pricing):
    n_experts: int = 0             # routed experts per MoE layer
    n_moe_layers: int = 0          # layers carrying an expert block
    expert_param_bytes: float = 0.0   # total expert-weight bytes (all layers)
    # routed-token dispatch buffer bytes per MoE layer, global batch
    # (B·S·top_k·capacity_factor·d_model·act_bytes) — the all-to-all payload
    moe_dispatch_bytes: float = 0.0


# ---------------------------------------------------------------------------
# segment-aware workload description (the M6 multimodal path)
# ---------------------------------------------------------------------------
#
# ``WorkloadMeta`` is layer-homogeneous: one ``fwd_flops`` total, one
# ``act_bytes_per_layer``, and every layer interchangeable.  That cannot
# describe M6 — a vision frontend stitched to a text decoder — where a
# pipeline cut between the modalities is the whole point (HetPipe's
# per-segment cost problem).  A :class:`ModelGraph` is the richer
# description: an ordered sequence of :class:`SegmentMeta` spans, each
# internally homogeneous, with the legacy flat meta recoverable as the
# flattened sum (``workload_meta()``) so every existing ``step_cost`` /
# ``auto.search`` / calibration call site keeps pricing byte-identically.


@dataclasses.dataclass(frozen=True)
class SegmentMeta:
    """One contiguous, internally homogeneous span of a model graph.

    ``n_layers`` are interchangeable *within* the segment (the unit the
    stage balancer moves); flops/params/activations are totals for the
    whole segment at the graph's global batch.  ``atomic`` spans (vision
    towers, fused frontends) may never be split across pipeline stages.
    """
    name: str
    n_layers: int
    fwd_flops: float
    param_bytes: float
    act_bytes_per_layer: float
    atomic: bool = False
    # MoE terms for segments carrying expert blocks (zero elsewhere)
    n_experts: int = 0
    n_moe_layers: int = 0
    expert_param_bytes: float = 0.0
    moe_dispatch_bytes: float = 0.0

    def __post_init__(self):
        if self.n_layers < 1:
            raise ValueError(f"segment {self.name!r} needs >=1 layer")
        if self.n_moe_layers > self.n_layers:
            raise ValueError(f"segment {self.name!r}: n_moe_layers "
                             f"{self.n_moe_layers} > n_layers {self.n_layers}")


@dataclasses.dataclass(frozen=True)
class ModelGraph:
    """An ordered sequence of heterogeneous segments + stack-external terms.

    The stack-external terms (embeddings/head params, the lm-head matmul,
    logits) are not owned by any segment; flattening and per-stage slicing
    spread them evenly across layers, exactly as the legacy
    ``scale_meta_stage`` view did.

    ``workload_meta()`` flattens to the legacy :class:`WorkloadMeta`; for
    the single-segment graphs the per-family builders in
    :mod:`repro.models.lm` produce for dense/moe/ssm/hybrid configs, the
    flattening is **byte-identical** to the retired ``lm_workload_meta``
    if-ladder (regression-guarded in tests/test_model_graph.py).
    """
    name: str
    segments: tuple
    batch: int
    extra_fwd_flops: float = 0.0      # lm-head matmul and friends
    extra_param_bytes: float = 0.0    # embeddings / head / final norm
    logits_bytes: float = 0.0
    head_param_bytes: float = 0.0
    opt_state_factor: float = 2.0
    grad_factor: float = 1.0
    # fraction of param bytes a tp `split` can shard (norms/bias stay
    # replicated); the taskgraph deriver uses a different constant, which
    # is why this is a field and not hard-coded in the flatten
    tp_shardable_fraction: float = 0.98

    def __post_init__(self):
        if not self.segments:
            raise ValueError("ModelGraph needs at least one segment")

    # ---- structure --------------------------------------------------------

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    def boundaries(self) -> tuple:
        """Cumulative segment edges: (0, l₀, l₀+l₁, …, L)."""
        out, off = [0], 0
        for s in self.segments:
            off += s.n_layers
            out.append(off)
        return tuple(out)

    def segment_spans(self) -> tuple:
        """Per-segment ``(start, stop)`` layer offsets."""
        b = self.boundaries()
        return tuple(zip(b[:-1], b[1:]))

    def valid_span(self, lo: int, hi: int) -> bool:
        """May layers ``[lo, hi)`` form one pipeline stage?

        The segment-respecting rule: a stage boundary may fall anywhere
        *between* layers EXCEPT inside an ``atomic`` segment (a fused
        frontend tower is one indivisible unit — a stage either contains
        it whole or not at all).  Non-atomic segments may be subdivided
        freely; segment edges matter to the balancer because per-layer
        costs change across them, not because cuts are forbidden near
        them.
        """
        if not (0 <= lo < hi <= self.n_layers):
            return False
        for s, (s0, s1) in zip(self.segments, self.segment_spans()):
            if not s.atomic:
                continue
            ov = min(hi, s1) - max(lo, s0)
            if 0 < ov < s1 - s0:     # partial coverage of an atomic span
                return False
        return True

    def valid_partition(self, layer_counts) -> bool:
        """Do the per-stage layer counts cut only at valid span edges?"""
        if sum(layer_counts) != self.n_layers:
            return False
        off = 0
        for n in layer_counts:
            if n < 1 or not self.valid_span(off, off + n):
                return False
            off += n
        return True

    def feasible_pp(self, pp: int) -> bool:
        """Does ANY segment-respecting partition into ``pp`` stages exist?"""
        if pp < 1:
            return False
        if pp == 1:
            return True
        L = self.n_layers
        # dp over cut positions: reach[k] = set of prefixes coverable by k
        # valid spans.  L is a few hundred at most — this is cheap.
        reach = {0}
        for _ in range(pp - 1):
            reach = {m for c in reach for m in range(c + 1, L)
                     if self.valid_span(c, m)}
            if not reach:
                return False
        return any(self.valid_span(c, L) for c in reach)

    def layer_costs(self) -> list:
        """Per-layer forward FLOPs (stack-external flops spread evenly) —
        the weights the segment-aware stage balancer allocates against."""
        L = self.n_layers
        extra = self.extra_fwd_flops / L
        out = []
        for s in self.segments:
            out.extend([s.fwd_flops / s.n_layers + extra] * s.n_layers)
        return out

    # ---- flattening -------------------------------------------------------

    def workload_meta(self) -> WorkloadMeta:
        """Flatten to the legacy layer-homogeneous :class:`WorkloadMeta`.

        Association order matches the retired if-ladder (flops summed
        first, the head added last; shardable bytes derived from the final
        param total) so single-segment graphs flatten byte-identically.
        """
        flops = 0.0
        pbytes = 0.0
        exp_bytes = 0.0
        for s in self.segments:
            flops += s.fwd_flops
            pbytes += s.param_bytes
            exp_bytes += s.expert_param_bytes
        flops += self.extra_fwd_flops
        pbytes += self.extra_param_bytes
        n_moe = sum(s.n_moe_layers for s in self.segments)
        return WorkloadMeta(
            name=self.name,
            fwd_flops=float(flops),
            param_bytes=float(pbytes),
            tp_shardable_param_bytes=float(pbytes
                                           * self.tp_shardable_fraction),
            act_bytes_per_layer=float(max(s.act_bytes_per_layer
                                          for s in self.segments)),
            n_layers=max(self.n_layers, 1),
            batch=self.batch,
            logits_bytes=float(self.logits_bytes),
            head_param_bytes=float(self.head_param_bytes),
            opt_state_factor=self.opt_state_factor,
            grad_factor=self.grad_factor,
            n_experts=max((s.n_experts for s in self.segments), default=0),
            n_moe_layers=int(n_moe),
            expert_param_bytes=float(exp_bytes),
            moe_dispatch_bytes=float(max(s.moe_dispatch_bytes
                                         for s in self.segments)))

    def stage_meta(self, lo: int, hi: int, pp: int) -> WorkloadMeta:
        """The workload as seen by ONE stage holding layers ``[lo, hi)``.

        The per-segment counterpart of ``hetero.scale_meta_stage``: slice
        totals come from the covering segments' own arithmetic instead of
        a uniform ``layers/L`` fraction; the ``·pp`` re-scaling convention
        (``step_cost`` divides by ``pp`` internally) and the keep-whole
        treatment of logits/head are identical.  On a single-segment graph
        this IS ``scale_meta_stage`` of the flattened meta.
        """
        if not (0 <= lo < hi <= self.n_layers):
            raise ValueError(f"bad stage span [{lo}, {hi}) of "
                             f"{self.n_layers} layers")
        n = hi - lo
        flops = pbytes = exp = 0.0
        act = disp = 0.0
        nmoe = 0.0
        nexp = 0
        for s, (s0, s1) in zip(self.segments, self.segment_spans()):
            ov = min(hi, s1) - max(lo, s0)
            if ov <= 0:
                continue
            frac = ov / s.n_layers
            flops += s.fwd_flops * frac
            pbytes += s.param_bytes * frac
            act = max(act, s.act_bytes_per_layer)
            nmoe += s.n_moe_layers * frac
            exp += s.expert_param_bytes * frac
            disp = max(disp, s.moe_dispatch_bytes)
            if s.n_moe_layers:
                nexp = max(nexp, s.n_experts)
        scale = n / self.n_layers
        flops += self.extra_fwd_flops * scale
        pbytes += self.extra_param_bytes * scale
        n_moe_stage = int(round(nmoe))
        return WorkloadMeta(
            name=f"{self.name}[{lo}:{hi}]",
            fwd_flops=float(flops * pp),
            param_bytes=float(pbytes * pp),
            tp_shardable_param_bytes=float(pbytes * pp
                                           * self.tp_shardable_fraction),
            act_bytes_per_layer=float(act),
            n_layers=n * pp,
            batch=self.batch,
            logits_bytes=float(self.logits_bytes),
            head_param_bytes=float(self.head_param_bytes),
            opt_state_factor=self.opt_state_factor,
            grad_factor=self.grad_factor,
            n_experts=nexp if n_moe_stage else 0,
            n_moe_layers=n_moe_stage * pp,
            expert_param_bytes=float(exp * pp),
            moe_dispatch_bytes=float(disp if n_moe_stage else 0.0))

    def describe(self) -> str:
        segs = " → ".join(f"{s.name}×{s.n_layers}" for s in self.segments)
        return f"{self.name}: {segs} ({self.n_layers} layers)"


def as_workload_meta(workload) -> WorkloadMeta:
    """Accept either description; flatten graphs to the legacy meta."""
    if isinstance(workload, ModelGraph):
        return workload.workload_meta()
    return workload


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute: float
    comm: float
    bubble: float
    mem_bytes: float
    feasible: bool
    detail: dict

    @property
    def total(self) -> float:
        if not self.feasible:
            return math.inf
        return self.compute + self.comm + self.bubble


def step_cost(meta: WorkloadMeta, strat: StrategySpec, hw: Hardware,
              *, overlap: float = 0.0) -> CostBreakdown:
    """Estimated wall-time of one training step under ``strat`` on ``hw``.

    ``overlap`` ∈ [0, 1): fraction of DP gradient communication hidden under
    backward compute (XLA latency hiding / Horovod fusion both give ~some).
    """
    dp, tp, pp, ep = strat.dp, strat.tp, strat.pp, strat.ep
    detail: dict = {}

    # ---- compute ----
    train_flops = meta.fwd_flops * (4.0 if strat.remat else 3.0)
    # every device computes 1/devices of the work: under nested ep the
    # model axis acts as extra data parallelism for the dense layers and
    # spreads routed tokens across expert shards for the MoE layers
    shards = strat.devices
    t_compute = train_flops / shards / (hw.peak_flops * hw.mxu_eff)
    detail["compute"] = t_compute

    # ---- communication ----
    t_comm = 0.0
    # (a) DP gradient all-reduce (or reduce-scatter+all-gather under ZeRO).
    #     Under nested ep the expert grads are already ep-sharded — their
    #     reduction rides only the (slow) data axis at 1/ep the volume —
    #     while dense-layer grads additionally reduce over the model axis
    #     (its shards saw different batch slices).
    exp_bytes = meta.expert_param_bytes if ep > 1 else 0.0
    grad_bytes = (meta.param_bytes - exp_bytes) * meta.grad_factor / (tp * pp)
    if dp > 1:
        t_dp = all_reduce_time(grad_bytes, dp, hw.bw_for_axis("data"))
        if ep > 1 and exp_bytes:
            t_dp += all_reduce_time(exp_bytes * meta.grad_factor / (ep * pp),
                                    dp, hw.bw_for_axis("data"))
        t_dp *= (1.0 - overlap)
        t_comm += t_dp
        detail["dp_allreduce"] = t_dp
    if ep > 1 and tp == 1:
        # dense grads reduce across the ep shards (fast model axis)
        t_ep_ar = all_reduce_time(grad_bytes, ep, hw.bw_for_axis("model"))
        t_ep_ar *= (1.0 - overlap)
        t_comm += t_ep_ar
        detail["ep_dense_allreduce"] = t_ep_ar
    # (a') expert dispatch/combine all-to-all bridges: 2 forward + 2
    #      backward per MoE layer, each moving the routed-token buffer
    #      (batch-sharded over dp) across the ep group on the model axis
    if ep > 1 and meta.n_moe_layers and meta.moe_dispatch_bytes:
        n_a2a = 4 * max(meta.n_moe_layers // pp, 1)
        t_a2a = n_a2a * all_to_all_time(meta.moe_dispatch_bytes / dp, ep,
                                        hw.bw_for_axis("model"))
        t_comm += t_a2a
        detail["ep_all_to_all"] = t_a2a
    # (b) ZeRO-3 param all-gather each fwd+bwd (2×) over dp — under
    #     nested ep the expert weights are already ep-sharded, so only
    #     1/ep of them is gathered (matching the memory model below)
    if strat.zero >= 3 and dp > 1:
        ag_bytes = ((meta.param_bytes - exp_bytes) / tp
                    + (exp_bytes / ep if ep > 1 else 0.0)) / pp
        t_ag = 2 * all_gather_time(ag_bytes, dp, hw.bw_for_axis("data"))
        t_comm += t_ag
        detail["fsdp_allgather"] = t_ag
    # (c) TP activation all-reduces: 2 per layer fwd, 2 per layer bwd
    #     (Megatron) each moving the layer activation bytes / (dp·pp)
    if tp > 1:
        act = meta.act_bytes_per_layer / dp
        n_ar = 4 * (meta.n_layers // pp)
        t_tp = n_ar * all_reduce_time(act, tp, hw.bw_for_axis("model"))
        t_comm += t_tp
        detail["tp_allreduce"] = t_tp
        if strat.vocab_split and meta.logits_bytes:
            # Fig-4 path: only 3 scalar-ish reductions per loss chunk — model
            # as 3 all-reduces of (B·S) fp32 rows (max/sumexp/correct).
            row_bytes = meta.logits_bytes / max(
                1, (meta.logits_bytes // (4 * meta.batch)) or 1)
            t_head = 3 * all_reduce_time(row_bytes / dp, tp,
                                         hw.bw_for_axis("model"))
            t_comm += t_head
            detail["vocab_split_head"] = t_head
        elif meta.logits_bytes:
            # without the split the full logits must be formed from a
            # replicated head — an all-gather of the logits over tp
            t_head = all_gather_time(meta.logits_bytes / dp, tp,
                                     hw.bw_for_axis("model"))
            t_comm += t_head
            detail["head_allgather"] = t_head
    # (d) pipeline p2p: 2 transfers (fwd + bwd) of the boundary activation
    #     per micro-batch per stage boundary
    if pp > 1:
        act_mb = meta.act_bytes_per_layer / dp / max(strat.micro_batches, 1)
        t_pp = 2 * (pp - 1) * strat.micro_batches * p2p_time(
            act_mb, hw.bw_for_axis("stage"))
        t_comm += t_pp
        detail["pipeline_p2p"] = t_pp
    detail["comm"] = t_comm

    # ---- pipeline bubble ----
    # (S−1)/(M+S−1) for both shipped schedules — 1F1B reorders work inside
    # the span, it does not shrink it (repro.core.schedule validates the
    # tick tables against this closed form)
    t_bubble = 0.0
    if pp > 1:
        from repro.core.schedule import bubble_fraction_closed_form
        m = max(strat.micro_batches, 1)
        t_bubble = t_compute * bubble_fraction_closed_form(pp, m)
    detail["bubble"] = t_bubble

    # ---- memory ----
    # params: sharded by tp (shardable part) & pp; zero-3 also by dp;
    # under nested ep the expert weights shard ep-ways instead (the M6
    # feasibility lever: flat DP replicates every expert on every device)
    if ep > 1 and meta.expert_param_bytes:
        exp = min(meta.expert_param_bytes, meta.tp_shardable_param_bytes)
        p_shard = (exp / ep + (meta.tp_shardable_param_bytes - exp) / tp
                   + (meta.param_bytes - meta.tp_shardable_param_bytes)) / pp
        sharded_bytes = exp / ep + (meta.param_bytes - exp) / tp
    else:
        p_shard = (meta.tp_shardable_param_bytes / tp
                   + (meta.param_bytes - meta.tp_shardable_param_bytes)) / pp
        sharded_bytes = meta.param_bytes / tp
    if strat.zero >= 3:
        p_shard /= dp
    opt_factor = 0.05 if strat.opt_factored else meta.opt_state_factor
    opt = sharded_bytes * opt_factor / pp
    if strat.zero >= 1:
        opt /= dp
    grads = sharded_bytes * meta.grad_factor / pp
    if strat.zero >= 2:
        grads /= dp
    # activations: with remat only ~1 layer's working set + per-layer
    # residuals are live; without, all layers.  Under nested ep with no
    # tensor split the model axis is extra data parallelism for the dense
    # layers, so the batch (and with it the activation working set)
    # shards over dp·ep; with ep == tp the model axis is doing tensor
    # parallelism and the batch stays dp-sharded (flat accounting).
    mb = max(strat.micro_batches, 1)
    act_dp = dp * (ep if (ep > 1 and tp == 1) else 1)
    act_live = meta.act_bytes_per_layer / act_dp / mb * (
        2.0 + (0 if strat.remat else meta.n_layers / pp))
    if pp > 1:
        # schedule-dependent in-flight micro-batches: GPipe must buffer all
        # M at its peak, 1F1B caps at min(M, S) (repro.core.schedule)
        from repro.core.schedule import in_flight_micro_batches
        act_live *= in_flight_micro_batches(pp, mb, strat.schedule)
    logits_live = 0.0
    if meta.logits_bytes:
        logits_live = meta.logits_bytes / act_dp / (
            tp if strat.vocab_split else 1)
        if strat.vocab_split:
            logits_live = min(logits_live, meta.logits_bytes / act_dp / tp)
    mem = p_shard + opt + grads + act_live + logits_live
    detail["mem"] = mem

    feasible = mem <= hw.hbm_bytes
    return CostBreakdown(compute=t_compute, comm=t_comm, bubble=t_bubble,
                         mem_bytes=mem, feasible=feasible, detail=detail)


# ---------------------------------------------------------------------------
# linear decomposition for profile-guided calibration (repro.core.calibrate)
# ---------------------------------------------------------------------------
#
# step_cost is *linear in the reciprocals* of the hardware parameters: every
# term is (a byte/FLOP volume that depends only on meta+strat) divided by
# one hardware rate.  step_cost_features extracts those volumes, so that
#
#     step_cost(meta, strat, hw).total
#         ≈ Σ_p  step_cost_features(...)[p] · hardware_reciprocals(hw)[p]
#
# (equality up to float re-association; tests/test_calibration.py guards the
# identity at 1e-9 relative).  calibrate.fit inverts this: given measured
# (features, wall-time) observations it least-squares-solves for the
# reciprocals — i.e. for the Hardware table itself.

CALIBRATION_PARAMS = ("eff_flops", "hbm_bw", "link_fast", "link_slow")


def hardware_reciprocals(hw: Hardware) -> dict:
    """The coordinates calibration solves for: ``param → 1/rate``.

    ``eff_flops`` is the *effective* matmul rate (peak × mxu_eff) — the
    only combination a wall-clock measurement can see; ``calibrate.fit``
    maps it back to ``peak_flops`` holding ``mxu_eff`` at its prior.
    """
    return {
        "eff_flops": 1.0 / (hw.peak_flops * hw.mxu_eff),
        "hbm_bw": 1.0 / hw.hbm_bw,
        "link_fast": 1.0 / hw.link_bw["fast"],
        "link_slow": 1.0 / hw.link_bw["slow"],
    }


def predict_step_time(features: Mapping[str, float], hw: Hardware) -> float:
    """Price a feature vector on ``hw``: features · reciprocals."""
    recips = hardware_reciprocals(hw)
    return sum(c * recips[p] for p, c in features.items() if c)


def step_cost_features(meta: WorkloadMeta, strat: StrategySpec, hw: Hardware,
                       *, overlap: float = 0.0) -> dict:
    """Per-hardware-parameter coefficients of one training step.

    Mirrors :func:`step_cost` term by term, accumulating *effective byte
    volumes* (ring-formula factors and overlap applied, bandwidth divided
    out) per link kind and the per-device FLOP volume (bubble factor
    applied) instead of times.  ``hw`` only contributes its ``axis_kind``
    mapping — which mesh axis rides the fast vs the slow link — never a
    rate, so the same features can be priced on any candidate table.

    ``hbm_bw`` stays 0 here: the training-step model has no explicit HBM
    term.  It is fed by per-kernel observations
    (:meth:`repro.runtime.profiler.Profiler.record_kernel`, with byte
    volumes from ``launch/hlo_analysis.py::hbm_traffic_bytes``) and by the
    serving rooflines, which are HBM-bound.
    """
    dp, tp, pp, ep = strat.dp, strat.tp, strat.pp, strat.ep
    feats = dict.fromkeys(CALIBRATION_PARAMS, 0.0)

    def kind(axis: str) -> str:
        return "link_" + hw.axis_kind.get(axis, "fast")

    # ---- compute (+ pipeline bubble, which scales the compute term) ----
    train_flops = meta.fwd_flops * (4.0 if strat.remat else 3.0)
    bubble = 0.0
    if pp > 1:
        from repro.core.schedule import bubble_fraction_closed_form
        bubble = bubble_fraction_closed_form(pp, max(strat.micro_batches, 1))
    feats["eff_flops"] = train_flops / strat.devices * (1.0 + bubble)

    # ---- communication (same accounting as step_cost, bw = 1) ----
    exp_bytes = meta.expert_param_bytes if ep > 1 else 0.0
    grad_bytes = (meta.param_bytes - exp_bytes) * meta.grad_factor / (tp * pp)
    if dp > 1:
        b = all_reduce_time(grad_bytes, dp, 1.0)
        if ep > 1 and exp_bytes:
            b += all_reduce_time(exp_bytes * meta.grad_factor / (ep * pp),
                                 dp, 1.0)
        feats[kind("data")] += b * (1.0 - overlap)
    if ep > 1 and tp == 1:
        feats[kind("model")] += (all_reduce_time(grad_bytes, ep, 1.0)
                                 * (1.0 - overlap))
    if ep > 1 and meta.n_moe_layers and meta.moe_dispatch_bytes:
        n_a2a = 4 * max(meta.n_moe_layers // pp, 1)
        feats[kind("model")] += n_a2a * all_to_all_time(
            meta.moe_dispatch_bytes / dp, ep, 1.0)
    if strat.zero >= 3 and dp > 1:
        ag_bytes = ((meta.param_bytes - exp_bytes) / tp
                    + (exp_bytes / ep if ep > 1 else 0.0)) / pp
        feats[kind("data")] += 2 * all_gather_time(ag_bytes, dp, 1.0)
    if tp > 1:
        act = meta.act_bytes_per_layer / dp
        n_ar = 4 * (meta.n_layers // pp)
        feats[kind("model")] += n_ar * all_reduce_time(act, tp, 1.0)
        if strat.vocab_split and meta.logits_bytes:
            row_bytes = meta.logits_bytes / max(
                1, (meta.logits_bytes // (4 * meta.batch)) or 1)
            feats[kind("model")] += 3 * all_reduce_time(row_bytes / dp, tp,
                                                        1.0)
        elif meta.logits_bytes:
            feats[kind("model")] += all_gather_time(meta.logits_bytes / dp,
                                                    tp, 1.0)
    if pp > 1:
        act_mb = meta.act_bytes_per_layer / dp / max(strat.micro_batches, 1)
        feats[kind("stage")] += (2 * (pp - 1) * strat.micro_batches
                                 * p2p_time(act_mb, 1.0))
    return feats


def throughput(meta: WorkloadMeta, strat: StrategySpec, hw: Hardware,
               **kw) -> float:
    """Samples/sec for the workload's global batch under the strategy."""
    c = step_cost(meta, strat, hw, **kw)
    if not c.feasible:
        return 0.0
    return meta.batch / c.total


# NOTE: the deprecated ``lm_workload_meta`` shim was removed — build a
# segment-aware ModelGraph via repro.models.lm.model_graph(cfg, batch, seq)
# (or Model.graph()) and flatten with .workload_meta() if a flat
# WorkloadMeta is really needed.


# ---------------------------------------------------------------------------
# serving (inference) pricing: prefill is FLOPs-bound, decode is HBM-bound
# ---------------------------------------------------------------------------
#
# The training cost above prices one *synchronous step*; serving needs two
# different per-group quantities (DESIGN.md §9, the HexiScale lens):
#
# - **prefill**: one prompt's forward is a dense matmul pass — compute-bound,
#   so a group's prefill rate tracks its effective FLOP/s.
# - **decode**: one token per sequence per step — every step re-reads the
#   weights plus the live KV cache from HBM while doing ~2 FLOPs per byte,
#   so a group's decode rate tracks its aggregate HBM bandwidth.
#
# Both are max(flops-term, bytes-term) rooflines on the same Hardware
# tables the training model uses; the prefill/decode router
# (repro.serving.router) prices cluster partitions with exactly these two
# functions, which is what makes "prefill on the compute-rich pool, decode
# on the bandwidth-rich pool" fall out of the tables instead of being
# hard-coded.


@dataclasses.dataclass(frozen=True)
class ServingMeta:
    """Per-token metadata of one LM for inference pricing.

    Like :class:`WorkloadMeta` everything is pure arithmetic over the
    config — nothing is executed.  ``flops_per_token`` covers the linear
    (weight) matmuls; attention-over-context adds
    ``attn_flops_per_ctx_token`` per (new token × cached token) pair.
    """
    name: str
    flops_per_token: float           # weight-matmul fwd FLOPs per token
    attn_flops_per_ctx_token: float  # score+value FLOPs per context token
    param_bytes: float               # serving weights (act dtype, e.g. bf16)
    kv_bytes_per_token: float        # KV-cache bytes per cached token, all layers
    d_model: int
    n_layers: int


def lm_serving_meta(cfg, *, param_dtype_bytes: int = 2,
                    kv_dtype_bytes: int = 2) -> ServingMeta:
    """Analytic serving metadata for one LMCfg (attention families)."""
    E, L, hd = cfg.d_model, cfg.n_layers, cfg.hd
    H, K, V = cfg.n_heads, cfg.n_kv_heads, cfg.padded_vocab
    proj = 2 * E * (H * hd) + 2 * 2 * E * (K * hd) + 2 * (H * hd) * E
    mlp = 2 * E * cfg.d_ff * (3 if cfg.gated_mlp else 2)
    head = 2 * E * V
    flops_per_token = L * (proj + mlp) + head
    # per (new token, cached token): one q·k dot + one p·v accumulate per head
    attn_per_ctx = L * 2 * H * hd * 2
    param_count = (L * (E * (H * hd) * 2 + E * (K * hd) * 2
                        + E * cfg.d_ff * (3 if cfg.gated_mlp else 2))
                   + V * E * (1 if cfg.tie_embeddings else 2))
    kv_per_token = L * 2 * K * hd * kv_dtype_bytes
    return ServingMeta(
        name=cfg.name, flops_per_token=float(flops_per_token),
        attn_flops_per_ctx_token=float(attn_per_ctx),
        param_bytes=float(param_count * param_dtype_bytes),
        kv_bytes_per_token=float(kv_per_token),
        d_model=E, n_layers=L)


def prefill_time(meta: ServingMeta, group: DeviceGroup,
                 prompt_len: int, batch: int = 1) -> float:
    """Wall time for one prefill of ``batch`` prompts on ``group``.

    FLOPs-bound roofline: dense matmuls over the whole prompt, floored by
    one streaming pass over the (group-sharded) weights.
    """
    T = batch * prompt_len
    flops = T * meta.flops_per_token \
        + batch * (prompt_len * prompt_len / 2) * meta.attn_flops_per_ctx_token
    t_flops = flops / group.group_flops
    t_bytes = meta.param_bytes / (group.n_devices * group.hw.hbm_bw)
    return max(t_flops, t_bytes)


def decode_step_time(meta: ServingMeta, group: DeviceGroup,
                     active: int, ctx_tokens: float) -> float:
    """Wall time of ONE decode step advancing ``active`` sequences on
    ``group``, with ``ctx_tokens`` total KV-cache tokens *read* that step.

    HBM-bound roofline: every step streams the weights plus the live KV.
    ``ctx_tokens`` is where paged beats dense: a dense cache reads its
    full ``slots × max_len`` reservation, a paged cache only the tokens
    actually cached (the block table never materialises the gap pages).
    """
    if active <= 0:
        return 0.0
    bytes_ = meta.param_bytes + ctx_tokens * meta.kv_bytes_per_token
    t_bytes = bytes_ / (group.n_devices * group.hw.hbm_bw)
    flops = active * meta.flops_per_token \
        + ctx_tokens * meta.attn_flops_per_ctx_token
    t_flops = flops / group.group_flops
    return max(t_bytes, t_flops)


def kv_handoff_time(meta: ServingMeta, prompt_len: int, bw: float) -> float:
    """Moving one prompt's KV cache between disaggregated pools."""
    return prompt_len * meta.kv_bytes_per_token / bw


def serving_page_budget(meta: ServingMeta, group: DeviceGroup,
                        page_size: int, *, reserve: float = 0.2) -> int:
    """How many KV pages a decode pool can hold: group HBM minus the
    (sharded) weights minus a ``reserve`` fraction for activations."""
    free = group.n_devices * group.hw.hbm_bytes * (1.0 - reserve) \
        - meta.param_bytes
    page_bytes = page_size * meta.kv_bytes_per_token
    return max(int(free // page_bytes), 0)

"""Int8 error-feedback gradient compression for cross-pod (DCN) all-reduce.

The multi-pod default strategy only sends *gradients* across the slow pod
axis.  Quantizing them to int8 with per-tensor scales cuts DCN bytes 4×;
1-bit-style error feedback (the residual of quantisation is carried to the
next step and re-added) keeps SGD convergence unaffected to first order
(Seide et al., 2014; Karimireddy et al., 2019).

Used inside a ``shard_map`` that is manual over the ``pod`` axis (see
``planner.jit_train_step(compress_pod=True)``): the psum operates on int32
(the sum of ≤256 int8 shards fits easily), then dequantises with the summed
scales.  The Pallas ``quant`` kernel is the fused on-chip encode; this module
is the jnp reference used under GSPMD (bit-identical semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, err: jax.Array | None = None):
    """x (+ carried error) → (int8 q, f32 scale, new error).

    Symmetric per-tensor scaling: q = round(x / s), s = max|x| / 127.
    """
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis: str, err: jax.Array | None = None,
                    *, mean: bool = True):
    """Error-feedback int8 psum over a manual shard_map axis.

    Every shard quantises with its own scale; the int32 sums of (q · 127)
    normalised values are combined with the max scale so the dequantised sum
    is exact up to int8 resolution.  Returns (reduced f32, new error).
    """
    q, scale, new_err = quantize_int8(x, err)
    # common scale: use the max over shards so all quanta are comparable —
    # requantise against it (error feedback absorbs the difference)
    smax = jax.lax.pmax(scale, axis)
    q2 = jnp.clip(jnp.round(dequantize_int8(q, scale) / smax),
                  -127, 127).astype(jnp.int8)
    # residual from requantisation also goes to the error carry
    new_err = new_err + dequantize_int8(q, scale) - dequantize_int8(q2, smax)
    total = jax.lax.psum(q2.astype(jnp.int32), axis)
    out = total.astype(jnp.float32) * smax
    if mean:
        from repro.core.jax_compat import axis_size
        out = out / axis_size(axis)
    return out.astype(x.dtype), new_err.astype(jnp.float32)


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads, axis: str, err_tree, *, mean: bool = True):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    outs = [compressed_psum(g, axis, e, mean=mean)
            for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, new_e

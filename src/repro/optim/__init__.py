from repro.optim.optimizer import (Optimizer, Schedule, adafactor,  # noqa: F401
                                   adamw, clip_by_global_norm, get_optimizer,
                                   global_norm, sgd)

"""Optimizers with sharding-aware state and dtype policies.

Minimal optax-like interface (no optax dependency):

    opt = adamw(lr=..., moment_dtype="bfloat16")
    state = opt.init(params)
    params, state = opt.apply(grads, state, params, step)
    state_axes = opt.state_axes(param_axes)   # for the planner's ZeRO sharding

AdamW state dtype is configurable (bf16 moments for the giant archs);
Adafactor keeps factored second moments (O(N/d) state — the production choice
for grok-scale models on 16 GB HBM parts, see configs/grok_1_314b.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def _tmap(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


@dataclasses.dataclass(frozen=True)
class Schedule:
    base_lr: float = 3e-4
    warmup: int = 100
    decay_steps: int = 10000
    min_ratio: float = 0.1

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(self.warmup, 1), 1.0)
        frac = jnp.clip((step - self.warmup)
                        / jnp.maximum(self.decay_steps - self.warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return self.base_lr * warm * (self.min_ratio + (1 - self.min_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                 grads), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    apply: Callable                 # (grads, state, params, step) -> (params, state)
    state_axes: Callable            # param_axes -> state axes tree
    name: str = "opt"


def adamw(lr: Schedule | float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          moment_dtype: str = "float32", max_grad_norm: float = 1.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda s: jnp.asarray(lr, jnp.float32))
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        zeros = _tmap(lambda p: jnp.zeros(p.shape, mdt), params)
        return {"mu": zeros,
                "nu": _tmap(lambda p: jnp.zeros(p.shape, mdt), params)}

    def apply(grads, state, params, step):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        c1 = 1 - b1 ** t
        c2 = 1 - b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
            nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
            u = (mu_n / c1) / (jnp.sqrt(nu_n / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            p_n = p.astype(jnp.float32) - lr_t * u
            return p_n.astype(p.dtype), mu_n.astype(mdt), nu_n.astype(mdt)

        out = _tmap(upd, grads, state["mu"], state["nu"], params)
        new_params = _tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = _tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = _tmap(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}

    def state_axes(param_axes):
        return {"mu": param_axes, "nu": param_axes}

    return Optimizer(init=init, apply=apply, state_axes=state_axes, name="adamw")


def adafactor(lr: Schedule | float = 3e-4, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, max_grad_norm: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern) — O(N/d) state."""
    sched = lr if callable(lr) else (lambda s: jnp.asarray(lr, jnp.float32))

    def _factored(shape) -> bool:
        # ndim-based so it matches state_axes (which only sees axis names);
        # size-1 dims factor fine (vr/vc just carry the singleton)
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": _tmap(one, params)}

    def apply(grads, state, params, step):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay)
        lr_t = sched(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in v:
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                rms = (vr[..., None] * vc[..., None, :]
                       / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(rms + eps)
                v_new = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(vv + eps)
                v_new = {"v": vv}
            if clip_threshold:
                un = jnp.sqrt(jnp.mean(u * u))
                u = u / jnp.maximum(1.0, un / clip_threshold)
            p_n = p.astype(jnp.float32) - lr_t * u
            return p_n.astype(p.dtype), v_new

        leaves, treedef = jax.tree.flatten(params)
        gl = treedef.flatten_up_to(grads)
        vl = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(gl, vl, leaves)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_params, {"v": new_v}

    def state_axes(param_axes):
        def one(names):
            names = tuple(names)
            if len(names) >= 2:
                return {"vr": names[:-1], "vc": names[:-2] + names[-1:]}
            return {"v": names}
        return {"v": jax.tree.map(one, param_axes,
                                  is_leaf=lambda t: isinstance(t, tuple))}

    return Optimizer(init=init, apply=apply, state_axes=state_axes,
                     name="adafactor")


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {}

    def apply(grads, state, params, step):
        return _tmap(lambda p, g: (p.astype(jnp.float32)
                                   - lr * g.astype(jnp.float32)).astype(p.dtype),
                     params, grads), state

    def state_axes(param_axes):
        return {}

    return Optimizer(init=init, apply=apply, state_axes=state_axes, name="sgd")


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](**kw)

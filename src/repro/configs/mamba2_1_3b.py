"""mamba2-1.3b — attention-free SSD (state-space duality), ssm_state=128.
[arXiv:2405.21060; unverified]

d_inner = 2·d_model = 4096, headdim 64 → 64 SSD heads (TP target).
O(1)-state decode ⇒ the only non-skipped `long_500k` cells are this arch
and jamba.
"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab=50280,
    ssd_headdim=64,
    ssd_state=128,
    d_conv=4,
    ssd_chunk=256,
    norm="rms",
    tie_embeddings=True,
    remat="full",
)

SMOKE = shrink(CONFIG)

"""gemma-2b — MQA (kv=1), GeGLU, head_dim=256, 256k vocab, tied embeddings.
[arXiv:2403.08295; hf]

8 query heads don't divide the 16-way model axis → attention runs
sequence-sharded (MQA context parallelism, see models/attention.py);
the 256k-vocab head is the paper-Fig-4 split-softmax showcase.
"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    norm="rms",
    act="gelu",
    tie_embeddings=True,
    remat="full",
)

SMOKE = shrink(CONFIG, n_kv_heads=1)

"""Assigned input-shape cells and their ShapeDtypeStruct input specs.

Four cells per LM arch (40 total):
  train_4k      train_step   seq 4,096   global batch 256
  prefill_32k   prefill      seq 32,768  global batch 32
  decode_32k    serve_step   KV 32,768   global batch 128
  long_500k     serve_step   KV 524,288  global batch 1   (ssm/hybrid only)

``long_500k`` is skipped (and recorded as skipped) for pure full-attention
archs per the assignment; all ten archs are decoder-bearing so ``decode_*``
applies everywhere.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.lm import LMCfg, Model

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    step: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: LMCfg, shape: str) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; (False, reason) if skipped."""
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("full-attention arch: 500k dense-KV decode is "
                       "out of scope per assignment (needs sub-quadratic mixer)")
    return True, ""


def batch_specs(model: Model, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for a train/prefill batch."""
    cfg = model.cfg
    B, S = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        return {
            "frames": SDS((B, S, cfg.d_model), cfg.adtype),
            "tokens": SDS((B, S), jnp.int32),
        }
    specs = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = SDS((B, cfg.frontend_len, cfg.d_model),
                                    cfg.adtype)
    return specs


def decode_specs(model: Model, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for serve_step: (tokens, state)."""
    B = cell.global_batch
    state = model.decode_state_shapes(B, cell.seq_len)
    state = jax.tree.map(lambda s: SDS(s.shape, s.dtype), state)
    return {"tokens": SDS((B,), jnp.int32), "state": state}


def input_specs(model: Model, shape: str) -> dict:
    cell = SHAPES[shape]
    if cell.step in ("train", "prefill"):
        return batch_specs(model, cell)
    return decode_specs(model, cell)


def make_synthetic_batch(model: Model, cell: ShapeCell, key) -> dict:
    """Concrete random batch matching batch_specs (for smoke/integration)."""
    cfg = model.cfg
    specs = batch_specs(model, cell)
    out = {}
    for name, s in specs.items():
        k = jax.random.fold_in(key, hash(name) % (2 ** 31))
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype)
    return out

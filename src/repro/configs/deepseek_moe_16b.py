"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # dense-path width (unused: all layers MoE here)
    d_ff_expert=1408,          # fine-grained expert width
    n_experts=64,
    top_k=6,
    n_shared=2,
    vocab=102400,
    norm="rms",
    act="silu",
    remat="full",
)

SMOKE = shrink(CONFIG)

"""qwen2-vl-2b — VLM backbone: M-RoPE, GQA kv=2, stub vision frontend.
[arXiv:2409.12191; hf]

The vision tower is a STUB per the assignment — ``input_specs`` supplies
precomputed (B, 64, d_model) patch embeddings merged at the sequence head;
M-RoPE uses (t, h, w) grid positions over the patch prefix.
12 query heads don't divide the 16-way model axis → sequence-sharded attention.
"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    norm="rms",
    act="silu",
    mrope_sections=(16, 24, 24),   # t/h/w bands over head_dim//2 = 64
    tie_embeddings=True,
    frontend="vision",
    frontend_len=64,
    remat="full",
)

SMOKE = shrink(CONFIG, mrope_sections=(4, 6, 6))

"""jamba-v0.1-52b — hybrid: attn:mamba 1:7 interleave, MoE 16e top-2 on every
other layer.  [arXiv:2403.19887; hf]

Period-8 super-block (scan unit): position 4 is attention, the rest SSD;
odd positions carry the 16-expert MoE MLP (EP: exactly 1 expert per model
shard), even positions a dense MLP.  We use mamba2-SSD mixers in place of
Jamba's mamba-1 (DESIGN.md §9) — same O(1)-state decode, so `long_500k` runs.
"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    d_ff_expert=14336,
    n_experts=16,
    top_k=2,
    vocab=65536,
    attn_period=8,
    attn_offset=4,
    norm="rms",
    act="silu",
    remat="full",
)

SMOKE = shrink(CONFIG, attn_period=4, attn_offset=2, n_layers=4)

"""qwen3-1.7b — dense, GQA kv=8, per-head qk-norm, tied embeddings.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151936,
    norm="rms",
    act="silu",
    qk_norm=True,
    tie_embeddings=True,
    remat="full",
)

SMOKE = shrink(CONFIG)

"""stablelm-3b — dense, MHA (kv = heads), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab=50304,
    norm="ln",
    act="silu",
    remat="full",
)

SMOKE = shrink(CONFIG)

"""grok-1-314b — 8-expert top-2 MoE, GQA kv=8.  [hf:xai-org/grok-1; unverified]

Memory note: 314B params mandate ZeRO-3/FSDP over the data axis on top of
the model-axis expert tensor parallelism (8 experts don't divide the 16-way
model axis, so each expert's d_ff=32768 is sliced instead — see
models/moe.py).  Optimizer moments are kept in bf16 for this arch.
"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    d_ff_expert=32768,
    n_experts=8,
    top_k=2,
    n_shared=0,
    vocab=131072,
    norm="rms",
    act="gelu",
    remat="full",
)

SMOKE = shrink(CONFIG)

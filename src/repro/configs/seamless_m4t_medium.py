"""seamless-m4t-medium — encoder-decoder (12L + 12L), 256k vocab, audio stub.
[arXiv:2308.11596; hf]

The speech frontend is a STUB: ``input_specs`` supplies precomputed
(B, S_src, d_model) frame embeddings to the encoder.  RoPE replaces the
original relative positions (DESIGN.md §9).
"""
from repro.configs.base import LMCfg, shrink

CONFIG = LMCfg(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,                # 12 encoder + 12 decoder
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    norm="ln",
    act="relu",
    gated_mlp=False,
    frontend="audio",
    frontend_len=0,
    remat="full",
)

SMOKE = shrink(CONFIG)

"""Config base: re-exports LMCfg and provides the generic smoke-reduction.

Each assigned architecture lives in its own module (``repro/configs/<id>.py``)
exposing ``CONFIG`` (the exact published configuration) and ``SMOKE`` (a
reduced same-family variant for CPU tests).  ``repro.configs`` assembles the
registry.
"""
from __future__ import annotations

import dataclasses

from repro.models.lm import LMCfg  # noqa: F401  (re-export)


def shrink(cfg: LMCfg, **overrides) -> LMCfg:
    """Reduced same-family config: small widths, few layers/experts, tiny
    vocab — structure (GQA ratios, MoE top-k, hybrid pattern) preserved."""
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = max(1, heads * cfg.n_kv_heads // max(cfg.n_heads, 1)) if heads else 0
    pattern = cfg.attn_period if cfg.family == "hybrid" else \
        (cfg.moe_every if cfg.family == "moe" else 1)
    n_layers = max(2, pattern)
    if cfg.family == "hybrid":
        n_layers = cfg.attn_period
    small = dict(
        n_layers=n_layers,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32 if heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        n_shared=min(cfg.n_shared, 1),
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_dec_layers=2 if cfg.n_dec_layers else 0,
        frontend_len=16 if cfg.frontend_len else 0,
        ssd_headdim=32,
        ssd_state=16,
        ssd_chunk=32,
        loss_chunk=64,
        attn_block_q=64,
        attn_block_k=64,
        remat="none",
        dtype="float32",
        param_dtype="float32",
        vocab_pad_multiple=16,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

"""Registry of the ten assigned architectures (+ helpers)."""
from __future__ import annotations

import importlib

from repro.configs.base import LMCfg, shrink  # noqa: F401

_ARCH_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "grok-1-314b": "grok_1_314b",
    "stablelm-3b": "stablelm_3b",
    "gemma-2b": "gemma_2b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str, smoke: bool = False) -> LMCfg:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG

"""Continuous-batching server core: dense or paged KV cache.

Grown out of ``launch/serve.py`` (which is now the CLI around this): a
fixed batch of decode *slots* advanced in lock-step by the planner's
sharded ``serve_step``, with per-request prefill at admission.  Two cache
modes:

- ``cache="dense"`` — the original layout: every slot owns ``max_len``
  KV rows from admission to finish.
- ``cache="paged"`` — the block/paged cache of DESIGN.md §9: slots hold
  pages from a shared pool through a block table
  (:mod:`repro.serving.paged_cache`), admission is gated on page
  availability, pages are appended as decode crosses page boundaries,
  and pool exhaustion preempts the youngest slot (its request re-queues
  and restarts).  Decode reads go through
  :func:`repro.models.transformer.decode_stack_paged` — bit-identical to
  the dense path in fp32 (``tests/test_serving.py``).

Prefill jit discipline: prompts are right-padded to power-of-two buckets
(min 8) so the jit cache holds O(log max_len) entries instead of one per
distinct prompt length; ``last_idx`` keeps the padded prefill exact
(logits read at the true last token, pad KV zeroed).

Decode hot path does exactly **one** host sync per step: a single
``np.asarray`` of the argmax'd next tokens for every slot at once.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sharding import use_rules
from repro.serving.paged_cache import (BlockTable, PageAllocator,
                                       PagedCacheConfig)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0


def prompt_bucket(n: int, max_len: int, lo: int = 8) -> int:
    """Smallest power-of-two ≥ ``n`` (min ``lo``), capped at ``max_len`` —
    the padded prefill length.  Caps the jit cache at O(log max_len)."""
    if n > max_len:
        raise ValueError(f"prompt length {n} exceeds max_len {max_len}")
    b = lo
    while b < n:
        b <<= 1
    return min(b, max_len)


class Server:
    def __init__(self, model, plan, *, batch_slots: int, max_len: int,
                 eos_id: int = 1, cache: str = "dense", page_size: int = 0,
                 n_pages: int = 0, record_logits: bool = False):
        if cache not in ("dense", "paged"):
            raise ValueError(f"cache must be dense|paged, got {cache!r}")
        self.model = model
        self.plan = plan
        self.mesh = plan.mesh
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.cache = cache
        self.record_logits = record_logits
        self.last_logits: np.ndarray | None = None
        self._prefill_fns: dict = {}      # bucket → jitted prefill
        self.tokens = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: list = [None] * batch_slots
        self.requeued: list = []          # preempted requests (paged)
        self.steps = 0
        self._admit_seq = 0
        self._seq_of: dict = {}           # slot → admission sequence no.

        if cache == "paged":
            if not model.supports_paged:
                raise ValueError(
                    f"arch {model.cfg.family!r} does not support the paged "
                    f"KV cache")
            ps = page_size or plan.tiles_for(None).page_size
            if max_len % ps:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of the page "
                    f"size {ps}")
            max_pages = max_len // ps
            # default pool: full residency for every slot (no preemption)
            n_pages = n_pages or 1 + batch_slots * max_pages
            self.pcfg = PagedCacheConfig(n_pages, ps, max_pages)
            self.alloc = PageAllocator(self.pcfg)
            self.table = BlockTable(batch_slots, self.pcfg)
            with self.mesh:
                self.serve_step_fn = plan.jit_serve_step_paged(
                    batch_slots, n_pages, ps, max_pages, donate=False)
                specs = plan.paged_state_specs(batch_slots, n_pages, ps,
                                               max_pages)
                shapes = model.paged_state_shapes(batch_slots, n_pages, ps,
                                                  max_pages)
                shardings = jax.tree.map(
                    lambda s: jax.NamedSharding(self.mesh, s), specs,
                    is_leaf=_is_spec)
                self.pools = jax.tree.map(
                    lambda s, sh: jnp.zeros(s.shape, s.dtype, device=sh),
                    shapes["pools"], shardings["pools"])
        else:
            with self.mesh:
                self.serve_step_fn = plan.jit_serve_step(batch_slots, max_len,
                                                         donate=False)
                specs = plan.state_specs(batch_slots, max_len)
                self.state_shardings = jax.tree.map(
                    lambda s: jax.NamedSharding(self.mesh, s), specs,
                    is_leaf=_is_spec)
                self.state = jax.tree.map(
                    lambda s, sh: jnp.zeros(s.shape, s.dtype, device=sh),
                    model.decode_state_shapes(batch_slots, max_len),
                    self.state_shardings)

    # --- bucketed prefill (jit cache: one entry per pow2 bucket) ---
    @property
    def prefill_cache_size(self) -> int:
        return len(self._prefill_fns)

    def _prefill_fn(self, bucket: int):
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            gb = 0 if self.cache == "paged" else self.max_len - bucket
            model, rules = self.model, self.plan.rules

            def prefill(params, tokens, last_idx, gen_budget=gb):
                with use_rules(rules):
                    return model.prefill(params, {"tokens": tokens},
                                         gen_budget=gen_budget,
                                         last_idx=last_idx)

            fn = self._prefill_fns[bucket] = jax.jit(prefill)
        return fn

    def _run_prefill(self, params, prompt: np.ndarray):
        S = len(prompt)
        bucket = prompt_bucket(S, self.max_len)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :S] = prompt
        last_idx = jnp.asarray([S - 1], jnp.int32)
        with self.mesh:
            return self._prefill_fn(bucket)(params, jnp.asarray(tokens),
                                            last_idx)

    # --- admission ---
    def can_admit(self, req: Request) -> bool:
        """Admission control: slot capacity is checked by the caller via
        :meth:`free_slot`; paged mode additionally requires the prompt's
        pages *now* and bounds the sequence by the block-table width."""
        S = len(req.prompt)
        if S + req.max_new > self.max_len:
            return False
        if self.cache == "paged":
            return self.alloc.can_alloc(self.pcfg.pages_for(S))
        return True

    def admit(self, params, req: Request, slot: int) -> None:
        """Prefill ``req`` into ``slot``.  A request that finishes at
        admission (EOS from prefill, or a one-token budget) is marked
        ``done`` and never occupies the slot — the caller collects it."""
        S = len(req.prompt)
        logits, st = self._run_prefill(params, np.asarray(req.prompt))
        logits_np = np.asarray(logits[0, :self.model.cfg.vocab])
        if self.record_logits:
            req.first_logits = logits_np
        tok = int(logits_np.argmax())
        req.out_tokens.append(tok)
        if tok == self.eos or len(req.out_tokens) >= req.max_new:
            req.done = True
            return
        if self.cache == "paged":
            pages = self.alloc.alloc(slot, self.pcfg.pages_for(S))
            self._write_prompt_pages(st["cache"], pages)
            self.table.assign(slot, pages, pos=S)
        else:
            with self.mesh:
                self.state = jax.device_put(
                    _write_slot(self.state, st, slot,
                                self.model.state_axes()),
                    self.state_shardings)
        self.tokens = self.tokens.at[slot].set(tok)
        self.slots[slot] = req
        self._seq_of[slot] = self._admit_seq
        self._admit_seq += 1

    def _write_prompt_pages(self, cache, pages: list) -> None:
        """Scatter a batch-1 prefill KV cache into freshly allocated pages.

        ``.set`` overwrites whole pages, so this is also what *zeroes* them
        (prefill zeroed rows past ``last_idx``) — stale contents from a
        previous owner can never leak into the new sequence.
        """
        ps = self.pcfg.page_size
        rows = len(pages) * ps
        idx = jnp.asarray(pages)
        for name, kv in cache.items():
            for key in ("k", "v"):
                a = kv[key][:, 0]                    # (L, bucket, K, D)
                if a.shape[1] < rows:
                    a = jnp.pad(a, ((0, 0), (0, rows - a.shape[1]),
                                    (0, 0), (0, 0)))
                else:
                    a = a[:, :rows]
                a = a.reshape(a.shape[0], len(pages), ps, *a.shape[2:])
                pool = self.pools[name][key]
                self.pools[name][key] = pool.at[:, idx].set(
                    a.astype(pool.dtype))

    def _zero_pages(self, pages: list) -> None:
        idx = jnp.asarray(pages)
        for name in self.pools:
            for key in ("k", "v"):
                p = self.pools[name][key]
                self.pools[name][key] = p.at[:, idx].set(0)

    # --- paged bookkeeping ---
    def _preempt_victim(self, needy_slot: int) -> None:
        """Free the youngest-admitted active slot's pages; its request
        restarts from scratch via :attr:`requeued`."""
        candidates = [b for b, r in enumerate(self.slots)
                      if r is not None and b != needy_slot]
        victim = (max(candidates, key=lambda b: self._seq_of[b])
                  if candidates else needy_slot)
        req = self.slots[victim]
        req.out_tokens = []
        req.done = False
        req.preemptions += 1
        self.alloc.free_slot(victim)
        self.table.clear(victim)
        self.slots[victim] = None
        self._seq_of.pop(victim, None)
        self.requeued.append(req)

    def _grow_tables(self) -> None:
        """Append a page to every active slot whose next write would land
        on an unallocated (trash) page, preempting on exhaustion."""
        for b, req in enumerate(self.slots):
            if req is None or not self.table.needs_page(b):
                continue
            while not self.alloc.can_alloc(1):
                self._preempt_victim(b)
                if self.slots[b] is None:      # preempted ourselves
                    break
            if self.slots[b] is None:
                continue
            page = self.alloc.alloc(b, 1)[0]
            self._zero_pages([page])
            self.table.append_page(b, page)

    # --- decode ---
    def step(self, params) -> list:
        """Advance every active slot one token; returns the requests that
        finished this step.

        Finished requests must be *returned*, not just freed: the slot is
        recycled in the same pass (``self.slots[b] = None``), so a caller
        scanning ``server.slots`` afterwards can never observe a done
        request — the pre-fix driver collected exactly that way and its
        ``done`` list stayed empty forever.
        """
        if self.cache == "paged":
            self._grow_tables()
            state = {"pools": self.pools,
                     "block_table": jnp.asarray(self.table.table),
                     "pos": jnp.asarray(self.table.pos)}
            with self.mesh:
                logits, state = self.serve_step_fn(params, self.tokens,
                                                   state)
            self.pools = state["pools"]
        else:
            with self.mesh:
                logits, self.state = self.serve_step_fn(params, self.tokens,
                                                        self.state)
        vocab = self.model.cfg.vocab
        # ONE host sync for the whole batch (was: one int() per slot)
        nxt = np.asarray(jnp.argmax(logits[:, :vocab], axis=-1))
        if self.record_logits:
            self.last_logits = np.asarray(logits[:, :vocab])
        self.tokens = jnp.asarray(nxt.astype(np.int32))
        self.steps += 1
        finished = []
        for b, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if self.cache == "paged":
                self.table.pos[b] += 1
            tok = int(nxt[b])
            req.out_tokens.append(tok)
            if tok == self.eos or len(req.out_tokens) >= req.max_new:
                req.done = True
                self.slots[b] = None          # recycle the slot …
                self._seq_of.pop(b, None)
                if self.cache == "paged":
                    self.alloc.free_slot(b)
                    self.table.clear(b)
                finished.append(req)          # … but hand the request back
        return finished

    def free_slot(self) -> int | None:
        for b, s in enumerate(self.slots):
            if s is None:
                return b
        return None

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def take_requeued(self) -> list:
        out, self.requeued = self.requeued, []
        return out


def _is_spec(t) -> bool:
    return isinstance(t, jax.sharding.PartitionSpec)


def _write_slot(state, st_one, slot: int, axes) -> dict:
    """Write a batch-1 prefill state into slot ``slot`` of the batch state."""
    def one(big, small, names):
        names = tuple(names)
        if "batch" not in names:
            return big
        b_ax = names.index("batch")
        idx = [0] * big.ndim
        idx[b_ax] = slot
        sl = small
        if small.shape[b_ax] != 1:
            sl = jnp.expand_dims(small, b_ax)
        # pad/crop the kv_seq dim to the slot buffer
        for d, nm in enumerate(names):
            if nm == "kv_seq" and sl.shape[d] != big.shape[d]:
                pad = big.shape[d] - sl.shape[d]
                if pad > 0:
                    cfgpad = [(0, 0)] * sl.ndim
                    cfgpad[d] = (0, pad)
                    sl = jnp.pad(sl, cfgpad)
                else:
                    sl = jax.lax.slice_in_dim(sl, 0, big.shape[d], axis=d)
        return jax.lax.dynamic_update_slice(big, sl.astype(big.dtype), idx)

    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    cache = jax.tree.map(one, state["cache"], st_one["cache"], axes["cache"],
                         is_leaf=is_axes)
    return {"cache": cache,
            "pos": state["pos"].at[slot].set(st_one["pos"][0])}

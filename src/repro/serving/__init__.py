"""Production serving tier (DESIGN.md §9).

- :mod:`repro.serving.paged_cache` — block/paged KV cache: fixed-size
  pages, slot→page block tables, host-side free-list allocation.
- :mod:`repro.serving.router` — prefill/decode disaggregation over a
  mixed :class:`~repro.core.cost_model.ClusterSpec`.
- :mod:`repro.serving.traffic` — open-loop heavy-tail (Pareto) arrivals.
- :mod:`repro.serving.metrics` — per-request TTFT/TPOT/e2e accounting.
- :mod:`repro.serving.sim` — the analytic discrete-event serving
  simulator behind ``benchmarks/fig_serve.py``.
"""
from repro.serving.metrics import RequestTiming, ServeMetrics, percentile
from repro.serving.paged_cache import PageAllocator, PagedCacheConfig
from repro.serving.router import DisaggPlan, route
from repro.serving.traffic import Arrival, TrafficCfg, make_trace

__all__ = [
    "Arrival", "DisaggPlan", "PageAllocator", "PagedCacheConfig",
    "RequestTiming", "ServeMetrics", "TrafficCfg", "make_trace",
    "percentile", "route",
]

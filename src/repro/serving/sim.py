"""Analytic discrete-event serving simulator: colocated-dense vs
disaggregated-paged.

The same open-loop Pareto trace (:mod:`repro.serving.traffic`) is played
against two deployments of the same model on the same cluster, with step
times from the serving cost model (:mod:`repro.core.cost_model`) — no
jax execution, so ``benchmarks/fig_serve.py`` can gate it in CI:

- **colocated dense** (the baseline ``launch/serve.py`` shipped before
  this tier): every device group runs prefill *and* decode; each admitted
  slot reserves ``max_len`` KV rows, and every decode step *reads* the
  full reservation (``active × max_len`` context tokens); a prefill
  blocks the group's decode batch head-of-line.
- **disaggregated paged**: :func:`repro.serving.router.route` splits the
  groups into a prefill pool and a decode pool; prompts prefill FIFO on
  the compute-rich pool, the KV crosses the slow link
  (:func:`~repro.core.cost_model.kv_handoff_time`), and the decode pool
  runs a paged cache — admission is gated on the page budget
  (:func:`~repro.core.cost_model.serving_page_budget`) and a step reads
  only the tokens actually cached.

Both arms are work-conserving and use the identical per-request
:class:`~repro.serving.metrics.RequestTiming` accounting; TTFT in both is
arrival → end of the prefill that produces token 1.  Requests are
dispatched to parallel groups/pools statically (weighted least-loaded),
which keeps the event loops per-group and deterministic.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.cost_model import (ClusterSpec, ServingMeta, decode_step_time,
                                   prefill_time, serving_page_budget)
from repro.serving.metrics import RequestTiming, ServeMetrics
from repro.serving.router import DisaggPlan, route
from repro.serving.traffic import Arrival, TrafficCfg, make_trace


@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """One cluster + model + traffic shape to play both arms over."""
    name: str
    spec: ClusterSpec
    traffic: TrafficCfg
    batch_slots: int = 16
    page_size: int = 64
    max_len: int = 2048          # dense arm's per-slot reservation
    seed: int = 0


@dataclasses.dataclass
class _Live:
    """One request mid-decode inside a group loop."""
    tm: RequestTiming
    left: int                    # decode tokens still to emit
    ctx: int                     # KV rows actually cached (paged reads this)
    pages: int = 0               # pages held (paged arm bookkeeping)


def _dispatch(arrivals, groups, weight) -> dict:
    """Static weighted least-loaded assignment of requests to groups.

    Deterministic stand-in for a load balancer: each request goes to the
    group minimising (assigned work / weight).  Returns {group.name: [..]}.
    """
    load = {g.name: 0.0 for g in groups}
    w = {g.name: max(weight(g), 1e-30) for g in groups}
    out = {g.name: [] for g in groups}
    for a in arrivals:
        gname = min(load, key=lambda n: (load[n] / w[n], n))
        out[gname].append(a)
        load[gname] += a.prompt_len + a.gen_len
    return out


# ---------------------------------------------------------------------------
# arm 1: colocated dense
# ---------------------------------------------------------------------------

def _colocated_group(meta: ServingMeta, g, arrivals, *, batch_slots: int,
                     max_len: int) -> list:
    """One group serving prefill+decode with a dense max_len-per-slot cache."""
    t = 0.0
    queue = deque(arrivals)
    active: list = []
    out = []
    while queue or active:
        if queue and len(active) < batch_slots and queue[0].t <= t:
            # prefill blocks the whole group (the colocated pathology)
            a = queue.popleft()
            tm = RequestTiming(rid=a.rid, arrival=a.t, admitted=t)
            t += prefill_time(meta, g, a.prompt_len)
            tm.first_token = t
            tm.n_tokens = 1
            if a.gen_len <= 1:
                tm.finished = t
                out.append(tm)
            else:
                active.append(_Live(tm=tm, left=a.gen_len - 1,
                                    ctx=a.prompt_len + 1))
            continue
        if active:
            # dense decode reads every slot's FULL reservation
            t += decode_step_time(meta, g, len(active),
                                  len(active) * max_len)
            finished = []
            for r in active:
                r.tm.n_tokens += 1
                r.left -= 1
                r.ctx += 1
                if r.left == 0:
                    r.tm.finished = t
                    finished.append(r)
            for r in finished:
                active.remove(r)
                out.append(r.tm)
            continue
        t = max(t, queue[0].t)       # idle: jump to the next arrival
    return out


def simulate_colocated(meta: ServingMeta, sc: ServeScenario) -> dict:
    """Every group runs the dense colocated server; merged metrics."""
    trace = make_trace(sc.traffic, seed=sc.seed)
    assignment = _dispatch(trace, sc.spec.groups, lambda g: g.group_flops)
    metrics = ServeMetrics()
    for g in sc.spec.groups:
        for tm in _colocated_group(meta, g, assignment[g.name],
                                   batch_slots=sc.batch_slots,
                                   max_len=sc.max_len):
            metrics.add(tm)
    return metrics.summary()


# ---------------------------------------------------------------------------
# arm 2: disaggregated prefill/decode + paged decode cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Handoff:
    """A prefilled request en route to the decode pool."""
    arrival: Arrival
    tm: RequestTiming
    ready_t: float               # prefill end + KV handoff


def _prefill_pool(meta: ServingMeta, groups, arrivals,
                  handoff_s: float) -> tuple:
    """FIFO multi-server prefill queue; emits token 1 of every request.

    Returns (finished_timings, handoffs) — gen_len<=1 requests finish at
    prefill and never cross to the decode pool.
    """
    clocks = {g.name: 0.0 for g in groups}
    by_name = {g.name: g for g in groups}
    done, handoffs = [], []
    for a in arrivals:               # FIFO: arrival order
        gname = min(clocks, key=lambda n: (max(clocks[n], a.t), n))
        g = by_name[gname]
        start = max(clocks[gname], a.t)
        end = start + prefill_time(meta, g, a.prompt_len)
        clocks[gname] = end
        tm = RequestTiming(rid=a.rid, arrival=a.t, admitted=start,
                           first_token=end, n_tokens=1)
        if a.gen_len <= 1:
            tm.finished = end
            done.append(tm)
        else:
            handoffs.append(_Handoff(arrival=a, tm=tm,
                                     ready_t=end + handoff_s))
    handoffs.sort(key=lambda h: (h.ready_t, h.arrival.rid))
    return done, handoffs


def _paged_decode_group(meta: ServingMeta, g, items, *, batch_slots: int,
                        page_size: int, reserve: float = 0.2) -> list:
    """One decode group over a paged cache with page-budget admission."""
    budget = serving_page_budget(meta, g, page_size, reserve=reserve)
    pending = deque(items)
    free = budget
    t = 0.0
    active: list = []
    out = []

    def pages_for(n):
        return -(-n // page_size)

    while pending or active:
        if pending and len(active) < batch_slots:
            h = pending[0]
            need = pages_for(h.arrival.prompt_len + h.arrival.gen_len)
            if need > budget:
                raise ValueError(
                    f"request {h.arrival.rid} needs {need} pages but group "
                    f"{g.name}'s whole budget is {budget} — it can never "
                    f"be admitted")
            if h.ready_t <= t and need <= free:
                pending.popleft()
                free -= need
                active.append(_Live(tm=h.tm, left=h.arrival.gen_len - 1,
                                    ctx=h.arrival.prompt_len + 1,
                                    pages=need))
                continue
        if active:
            # paged decode reads only the tokens actually cached
            ctx = sum(r.ctx for r in active)
            t += decode_step_time(meta, g, len(active), ctx)
            finished = []
            for r in active:
                r.tm.n_tokens += 1
                r.left -= 1
                r.ctx += 1
                if r.left == 0:
                    r.tm.finished = t
                    finished.append(r)
            for r in finished:
                active.remove(r)
                free += r.pages
                out.append(r.tm)
            continue
        t = max(t, pending[0].ready_t)   # idle: wait for the next handoff
    return out


def simulate_disagg(meta: ServingMeta, sc: ServeScenario,
                    plan: DisaggPlan | None = None) -> tuple:
    """Disaggregated + paged arm.  Returns (summary, plan)."""
    if plan is None:
        mean_prompt = int(sum(sc.traffic.prompt_lens)
                          / len(sc.traffic.prompt_lens))
        mean_gen = int(sum(sc.traffic.gen_lens) / len(sc.traffic.gen_lens))
        plan = route(meta, sc.spec, mean_prompt=mean_prompt,
                     mean_gen=mean_gen, page_size=sc.page_size,
                     batch_slots=sc.batch_slots)
    trace = make_trace(sc.traffic, seed=sc.seed)
    metrics = ServeMetrics()
    done, handoffs = _prefill_pool(meta, plan.prefill.groups, trace,
                                   plan.handoff_s)
    for tm in done:
        metrics.add(tm)
    # decode-pool dispatch weighted by memory bandwidth (what decode buys)
    by_group = _dispatch(
        [h.arrival for h in handoffs], plan.decode.groups,
        lambda g: g.n_devices * g.hw.hbm_bw)
    by_rid = {h.arrival.rid: h for h in handoffs}
    for g in plan.decode.groups:
        items = sorted((by_rid[a.rid] for a in by_group[g.name]),
                       key=lambda h: (h.ready_t, h.arrival.rid))
        for tm in _paged_decode_group(meta, g, items,
                                      batch_slots=sc.batch_slots,
                                      page_size=sc.page_size):
            metrics.add(tm)
    return metrics.summary(), plan


def compare(meta: ServingMeta, sc: ServeScenario) -> dict:
    """Both arms on one scenario + the headline ratios fig_serve gates."""
    base = simulate_colocated(meta, sc)
    ours, plan = simulate_disagg(meta, sc)
    return {
        "scenario": sc.name,
        "colocated": base,
        "disagg": ours,
        "plan": plan.describe(),
        "tokens_per_s_ratio": ours["tokens_per_s"]
        / max(base["tokens_per_s"], 1e-12),
        "ttft_p99_ratio": ours["ttft_p99_s"] / max(base["ttft_p99_s"], 1e-12),
    }

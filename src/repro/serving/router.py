"""Prefill/decode disaggregation router over a mixed ClusterSpec.

Whale balances *training* work across GPU generations; serving has a
sharper version of the same problem because its two phases stress
different silicon (the HexiScale observation, PAPERS.md):

- **prefill** is one dense forward over the whole prompt — FLOPs-bound,
  priced by :func:`repro.core.cost_model.prefill_time`;
- **decode** re-reads the weights + live KV cache every token — HBM-
  bandwidth-bound, priced by :func:`~repro.core.cost_model.decode_step_time`.

A colocated deployment runs both phases on every group, so prefill
bursts stall decode batches and the bandwidth-poor groups drag the token
rate.  The router instead partitions the cluster's device groups into a
prefill pool and a decode pool (group-granular —
:func:`repro.core.hetero.partition_cluster`), prices every one of the
``2^G − 2`` partitions with the serving cost model, and picks the one
with the highest *serviceable request rate* — the min of what the
prefill pool can admit and what the decode pool can emit, KV handoff
riding the bottleneck cross-pool link in between.

Nothing about "V100s do decode" is hard-coded: the assignment falls out
of the Hardware tables (V100: 900 GB/s HBM → bandwidth-rich, decode;
T4: 65 TFLOP/s against 300 GB/s → relatively compute-rich, prefill).
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core.cost_model import (ClusterSpec, ServingMeta, decode_step_time,
                                   kv_handoff_time, prefill_time,
                                   serving_page_budget)
from repro.core.hetero import partition_cluster


@dataclasses.dataclass(frozen=True)
class DisaggPlan:
    """One priced prefill/decode partition of a cluster."""
    prefill: ClusterSpec
    decode: ClusterSpec
    prefill_req_rate: float      # prompts/s the prefill pool sustains
    decode_tok_rate: float       # tokens/s the decode pool sustains
    handoff_s: float             # per-request KV handoff latency
    page_budget: int             # decode-pool KV pages (admission control)
    concurrency: int             # steady-state decode sequences

    @property
    def request_rate(self) -> float:
        """Serviceable requests/s at the scenario's mean gen length —
        the bottleneck of admission (prefill) and emission (decode)."""
        return min(self.prefill_req_rate, self._decode_req_rate)

    # set by route(); stored so request_rate stays self-contained
    _decode_req_rate: float = 0.0

    def describe(self) -> str:
        pf = "+".join(f"{g.n_devices}×{g.hw.name}" for g in self.prefill.groups)
        dc = "+".join(f"{g.n_devices}×{g.hw.name}" for g in self.decode.groups)
        return (f"prefill[{pf}] → decode[{dc}]  "
                f"{self.prefill_req_rate:.1f} req/s in, "
                f"{self.decode_tok_rate:.0f} tok/s out, "
                f"handoff {self.handoff_s * 1e3:.1f} ms, "
                f"{self.page_budget} pages")


def _cross_pool_bw(prefill: ClusterSpec, decode: ClusterSpec) -> float:
    """KV handoff rides the slow (inter-server) link; bottleneck of the
    two pools' slow-link bandwidths."""
    return min(min(g.hw.link_bw["slow"] for g in prefill.groups),
               min(g.hw.link_bw["slow"] for g in decode.groups))


def price_partition(meta: ServingMeta, prefill: ClusterSpec,
                    decode: ClusterSpec, *, mean_prompt: int, mean_gen: int,
                    page_size: int, batch_slots: int,
                    reserve: float = 0.2) -> DisaggPlan:
    """Price one (prefill pool, decode pool) split of the cluster."""
    pf_rate = sum(1.0 / prefill_time(meta, g, mean_prompt)
                  for g in prefill.groups)
    # steady-state decode: each decode group runs batch_slots slots capped
    # by its page budget at the mean live context (prompt + half the gen)
    mean_ctx = mean_prompt + mean_gen / 2.0
    pages_per_seq = -(-int(mean_ctx) // page_size)
    tok_rate = 0.0
    budget = 0
    conc_total = 0
    for g in decode.groups:
        pages = serving_page_budget(meta, g, page_size, reserve=reserve)
        budget += pages
        conc = min(batch_slots, max(pages // max(pages_per_seq, 1), 0))
        if conc <= 0:
            continue
        step = decode_step_time(meta, g, conc, conc * mean_ctx)
        tok_rate += conc / step
        conc_total += conc
    handoff = kv_handoff_time(meta, mean_prompt,
                              _cross_pool_bw(prefill, decode))
    return DisaggPlan(
        prefill=prefill, decode=decode, prefill_req_rate=pf_rate,
        decode_tok_rate=tok_rate, handoff_s=handoff, page_budget=budget,
        concurrency=conc_total,
        _decode_req_rate=tok_rate / max(mean_gen, 1))


def route(meta: ServingMeta, spec: ClusterSpec, *, mean_prompt: int,
          mean_gen: int, page_size: int, batch_slots: int,
          reserve: float = 0.2) -> DisaggPlan:
    """Best prefill/decode partition of ``spec`` for the workload shape.

    Exhaustive over the ``2^G − 2`` group partitions (G is small — a
    cluster has a handful of hardware kinds, not a handful of devices).
    Raises on a single-group spec: there is nothing to disaggregate —
    the caller should run colocated instead.
    """
    names = [g.name for g in spec.groups]
    if len(names) < 2:
        raise ValueError(
            f"disaggregation needs >= 2 device groups, got {names}; run "
            f"the colocated server on a single-group cluster")
    best = None
    for r in range(1, len(names)):
        for picked in itertools.combinations(names, r):
            prefill, decode = partition_cluster(spec, picked)
            plan = price_partition(
                meta, prefill, decode, mean_prompt=mean_prompt,
                mean_gen=mean_gen, page_size=page_size,
                batch_slots=batch_slots, reserve=reserve)
            if plan.page_budget <= 0 or plan.concurrency <= 0:
                continue                 # decode pool cannot hold any KV
            if best is None or plan.request_rate > best.request_rate:
                best = plan
    if best is None:
        raise ValueError(
            f"no partition of {names} yields a feasible decode pool "
            f"(weights alone exhaust every candidate pool's HBM)")
    return best

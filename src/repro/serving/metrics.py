"""Per-request serving latency accounting: TTFT / TPOT / e2e.

One :class:`RequestTiming` per request records the four timestamps the
standard serving SLOs are built from; :class:`ServeMetrics` aggregates a
run into the headline numbers (p50/p99 TTFT, mean TPOT, tokens/s) that
``benchmarks/fig_serve.py`` gates and ``launch/serve.py --traffic``
prints.  Pure python — shared by the real driver (wall-clock timestamps)
and the analytic simulator (simulated-clock timestamps).
"""
from __future__ import annotations

import dataclasses


def percentile(xs, p: float) -> float:
    """Linear-interpolated percentile (numpy's default method), p ∈ [0, 100]."""
    if not xs:
        raise ValueError("percentile of an empty sequence")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (len(s) - 1) * p / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


@dataclasses.dataclass
class RequestTiming:
    """Lifecycle timestamps of one request (seconds on the caller's clock)."""
    rid: int
    arrival: float
    admitted: float | None = None        # prefill started
    first_token: float | None = None     # prefill done, token 1 emitted
    finished: float | None = None
    n_tokens: int = 0                    # tokens generated (incl. the first)
    preemptions: int = 0

    @property
    def ttft(self) -> float:
        """Time to first token: arrival → first emitted token (includes
        admission queueing — the p99 of this is the gated SLO)."""
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        """Time per output token over the decode phase."""
        if self.n_tokens <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.n_tokens - 1)

    @property
    def e2e(self) -> float:
        return self.finished - self.arrival


class ServeMetrics:
    """Aggregate a run's RequestTimings into the headline serving numbers."""

    def __init__(self):
        self.requests: list = []

    def add(self, t: RequestTiming):
        if t.finished is None or t.first_token is None:
            raise ValueError(f"request {t.rid} recorded before finishing")
        self.requests.append(t)

    def summary(self) -> dict:
        rs = self.requests
        if not rs:
            return {"completed": 0}
        t0 = min(r.arrival for r in rs)
        t1 = max(r.finished for r in rs)
        total_tokens = sum(r.n_tokens for r in rs)
        ttfts = [r.ttft for r in rs]
        tpots = [r.tpot for r in rs if r.n_tokens > 1]
        return {
            "completed": len(rs),
            "tokens": total_tokens,
            "makespan_s": t1 - t0,
            "tokens_per_s": total_tokens / max(t1 - t0, 1e-12),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "ttft_mean_s": sum(ttfts) / len(ttfts),
            "tpot_mean_s": (sum(tpots) / len(tpots)) if tpots else 0.0,
            "e2e_p99_s": percentile([r.e2e for r in rs], 99),
            "preemptions": sum(r.preemptions for r in rs),
        }

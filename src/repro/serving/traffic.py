"""Open-loop traffic generation: heavy-tail (Pareto) arrivals.

Closed-loop drivers (admit the next request when a slot frees) hide
queueing collapse — an open-loop generator keeps arriving at the offered
rate whether or not the server keeps up, which is what makes TTFT tails
meaningful.  Interarrival gaps are Pareto (the classic heavy-tail model
for request traffic): bursts of near-simultaneous arrivals separated by
long idle gaps, at a configured *mean* rate.

Deterministic: everything derives from ``numpy.random.default_rng(seed)``
so the simulator, the real driver, and CI replay identical traces.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficCfg:
    rate: float                  # mean arrivals per second
    n_requests: int
    alpha: float = 2.5           # Pareto shape; smaller → heavier tail
    prompt_lens: tuple = (16, 32, 64, 128)   # sampled uniformly
    gen_lens: tuple = (16, 32, 64)

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"rate must be positive, got {self.rate}")
        if self.alpha <= 1.0:
            raise ValueError(
                f"alpha must exceed 1 (finite mean), got {self.alpha}")


@dataclasses.dataclass(frozen=True)
class Arrival:
    rid: int
    t: float                     # arrival time, seconds from trace start
    prompt_len: int
    gen_len: int


def pareto_interarrivals(rng, rate: float, n: int,
                         alpha: float = 2.5) -> np.ndarray:
    """``n`` Pareto gaps with mean ``1/rate``.

    Pareto(x_m, α) has mean x_m·α/(α−1); solving for the scale gives
    x_m = (α−1)/(α·rate) so the long-run arrival rate is exactly ``rate``
    while individual gaps are bursty/heavy-tailed.
    """
    xm = (alpha - 1.0) / (alpha * rate)
    u = rng.random(n)
    return xm * np.power(1.0 - u, -1.0 / alpha)


def make_trace(cfg: TrafficCfg, seed: int = 0) -> list:
    """Deterministic arrival trace: ``n_requests`` :class:`Arrival`\\ s."""
    rng = np.random.default_rng(seed)
    gaps = pareto_interarrivals(rng, cfg.rate, cfg.n_requests, cfg.alpha)
    times = np.cumsum(gaps)
    prompts = rng.choice(np.asarray(cfg.prompt_lens), cfg.n_requests)
    gens = rng.choice(np.asarray(cfg.gen_lens), cfg.n_requests)
    return [Arrival(rid=i, t=float(times[i]), prompt_len=int(prompts[i]),
                    gen_len=int(gens[i]))
            for i in range(cfg.n_requests)]

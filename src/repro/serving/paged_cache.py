"""Paged (block) KV cache: fixed-size pages + slot→page block tables.

The dense serving cache reserves ``max_len`` KV rows per decode slot the
moment a request is admitted — a 16-token prompt generating 16 tokens
holds (and every decode step *reads*) a 2048-row reservation.  The paged
cache (the vLLM idea, adapted to JAX static shapes) splits the cache into
fixed-size **pages** shared by all slots:

- the device holds per-layer page **pools** ``(n_rep, n_pages, page_size,
  K, D)`` plus a ``(slots, max_pages)`` int32 **block table** mapping each
  slot's logical page index to a physical page;
- pages are allocated on demand — at admission enough pages to cover the
  prompt, then one more every ``page_size`` decode steps — from a
  host-side free list (:class:`PageAllocator`);
- physical page 0 is the **trash page**: never allocated, every
  unallocated block-table entry points at it, and *inactive* slots write
  their garbage KV into it — so the one-hot scatter that keeps decode
  jit-shaped can run for all slots unconditionally without an active mask.

Allocation state machine (admission control — DESIGN.md §9):

    ADMIT    pages_for(prompt) available?  → alloc (all-or-nothing)
             else                          → request stays queued
    DECODE   pos crossed a page boundary?  → alloc 1 page (zeroed)
             pool exhausted?               → PREEMPT a victim slot
                                             (pages freed, request re-queued)
    FINISH   → free the slot's pages (contents left stale — the next
               owner zeroes pages at allocation, which is what makes
               slot-recycle safe under the one-hot ADD decode write)

Everything here is host-side bookkeeping over numpy arrays; the device
arrays (pools / block table / pos) are owned by the caller
(``launch/serve.py``) and updated with the jitted helpers in
``repro.models`` — this module never imports jax.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of one paged cache."""
    n_pages: int                 # physical pages in the pool (incl. trash)
    page_size: int               # KV rows per page
    max_pages: int               # logical pages per slot (block-table width)

    def __post_init__(self):
        if self.page_size <= 0:
            raise ValueError(f"page_size must be positive, got "
                             f"{self.page_size}")
        if self.n_pages < 2:
            raise ValueError(
                f"need >= 2 physical pages (page 0 is the trash page), "
                f"got {self.n_pages}")
        if self.max_pages <= 0:
            raise ValueError(f"max_pages must be positive, got "
                             f"{self.max_pages}")

    @property
    def max_len(self) -> int:
        """Longest sequence one slot can hold."""
        return self.max_pages * self.page_size

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1          # page 0 is reserved

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV rows."""
        return -(-n_tokens // self.page_size)


class PageAllocator:
    """Free-list allocator over the physical pages of one pool.

    All-or-nothing allocation (a request either gets every page it asked
    for or none), per-slot ownership tracking, and loud errors on every
    misuse — double-free and foreign-free bugs corrupt *other requests'*
    caches, which is the worst silent failure a serving tier can have.
    """

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self._free = list(range(cfg.n_pages - 1, 0, -1))  # pop() → page 1 first
        self._owned: dict = {}           # slot → [physical pages]

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.cfg.usable_pages - len(self._free)

    def owned(self, slot: int) -> list:
        return list(self._owned.get(slot, []))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, slot: int, n: int) -> list:
        """Give ``slot`` ``n`` more pages (all-or-nothing)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: slot {slot} asked for {n} pages, "
                f"{len(self._free)}/{self.cfg.usable_pages} free")
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(slot, []).extend(pages)
        return pages

    def free_slot(self, slot: int) -> list:
        """Release every page ``slot`` owns; returns them (stale contents)."""
        pages = self._owned.pop(slot, [])
        for p in pages:
            if p in self._free:
                raise RuntimeError(
                    f"double free of page {p} (slot {slot}) — the free "
                    f"list is corrupt")
        self._free.extend(reversed(pages))
        return pages

    def reset(self):
        self._free = list(range(self.cfg.n_pages - 1, 0, -1))
        self._owned = {}


class BlockTable:
    """Host-side mirror of the device block table + per-slot positions.

    The device copy is just ``jnp.asarray`` of these arrays each step (a
    few KiB); keeping the mutable source of truth on the host avoids a
    device round-trip per admission/page-allocation.
    """

    def __init__(self, slots: int, cfg: PagedCacheConfig):
        self.cfg = cfg
        self.table = np.zeros((slots, cfg.max_pages), np.int32)  # 0 = trash
        self.pos = np.zeros((slots,), np.int32)

    def assign(self, slot: int, pages: list, pos: int):
        """Point ``slot`` at ``pages`` (logical order) starting empty."""
        if len(pages) > self.cfg.max_pages:
            raise ValueError(
                f"{len(pages)} pages exceed the block-table width "
                f"{self.cfg.max_pages}")
        self.table[slot] = 0
        self.table[slot, :len(pages)] = pages
        self.pos[slot] = pos

    def append_page(self, slot: int, page: int):
        idx = int(np.argmax(self.table[slot] == 0))
        if self.table[slot, idx] != 0:
            raise ValueError(f"slot {slot} block table is full")
        self.table[slot, idx] = page

    def clear(self, slot: int):
        self.table[slot] = 0
        self.pos[slot] = 0

    def needs_page(self, slot: int) -> bool:
        """Does the *next* decode write land on an unallocated page?"""
        idx = int(self.pos[slot]) // self.cfg.page_size
        if idx >= self.cfg.max_pages:
            return False                 # out of table — caller enforces max_len
        return self.table[slot, idx] == 0

"""repro — Whale (unified multi-strategy distributed training) in JAX.

``import repro as wh`` gives the paper's API surface (cluster / replica /
split / stage / pipeline / auto-parallel scopes, the engine, cost model).
"""
from repro.core import *  # noqa: F401,F403
from repro.models.lm import model_graph  # noqa: F401  (segment-aware meta)

from repro.data.pipeline import DataCfg, PipelineState, TokenPipeline  # noqa: F401
